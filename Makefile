PY := PYTHONPATH=src python

.PHONY: test test-fast test-kernels test-serve-families test-serve-mesh \
	test-sparse-serve test-spec-decode test-chunked-prefill test-scores \
	analyze ci bench bench-serving serve

# tier-1 gate: every test file must collect and pass (includes the
# serve-engine and paged-KV suites: tests/test_serve.py, tests/test_paging.py)
test:
	$(PY) -m pytest -x -q

# CI lane: skip the multi-process SPMD tests (slow marker); the paged
# attention / allocator tests are NOT slow-marked, so they run here too
test-fast:
	$(PY) -m pytest -x -q -m "not slow"

# kernel lane: the Pallas kernels (interpret mode on CPU) + the paged-pool
# allocator/registry suites — the fast loop when touching kernels/ or
# serve/paging.py
test-kernels:
	$(PY) -m pytest -x -q tests/test_kernels.py tests/test_paged_attention.py \
	    tests/test_paging.py

# family lane: SSM / hybrid / VLM serving decode-parity (the spec-driven
# slot-state matrix) on forced CPU with XLA_FLAGS cleared, so a dryrun
# shell's fake-device flags can never leak into the parity run
test-serve-families:
	env -u XLA_FLAGS JAX_PLATFORMS=cpu $(PY) -m pytest -x -q \
	    tests/test_serve_families.py

# sparse-serve lane: 2:4 pack/unpack properties + compressed-vs-masked-vs-
# dense engine parity (forced CPU, like the family lane) — the fast loop
# when touching kernels/sparse_matmul24.py or the compressed serve path
test-sparse-serve:
	env -u XLA_FLAGS JAX_PLATFORMS=cpu $(PY) -m pytest -x -q \
	    tests/test_sparse_serve.py

# spec-decode lane: self-speculation with a 2:4-pruned drafter — greedy
# bit-exactness vs target-only, exact rejection sampling (draft == target
# accepts everything), draft-arena/admission headroom contracts (forced
# CPU, like the family lane)
test-spec-decode:
	env -u XLA_FLAGS JAX_PLATFORMS=cpu $(PY) -m pytest -x -q \
	    tests/test_spec_decode.py

# chunked-prefill lane: the unified step program — Sq>1 kernel-mode
# parity, chunked-vs-waved greedy bit-exactness (engine + scheduler +
# spec-decode), TTFT/TPOT attribution, eligibility pins, and the
# zero-retrace trace cells (forced CPU, like the family lane)
test-chunked-prefill:
	env -u XLA_FLAGS JAX_PLATFORMS=cpu $(PY) -m pytest -x -q \
	    tests/test_chunked_prefill.py

# score-zoo lane: the core/scores.py registry (parity vs the hand-rolled
# wanda path, valid 2:4 from every score, RO survival) + the engine's
# live calibration taps (snapshot-vs-offline stats parity, greedy
# bit-exactness, reprune/repack round-trip) — the fast loop when touching
# core/scores.py, core/regional.py or the calib_taps plumbing
test-scores:
	env -u XLA_FLAGS JAX_PLATFORMS=cpu $(PY) -m pytest -x -q \
	    tests/test_scores.py

# mesh lane: sharded-vs-single-device serving parity (slow-marked subprocess
# tests; each child forces an 8-device CPU host itself, so the parent env is
# scrubbed of any leaked XLA flags and pinned to CPU)
test-serve-mesh:
	env -u XLA_FLAGS JAX_PLATFORMS=cpu $(PY) -m pytest -x -q \
	    tests/test_serve_distributed.py

# static-analysis lane (pure CPU, no slow marker): jit-safety lint vs the
# checked-in baseline, the sharding-contract matrix (device-free AxisMesh
# geometries) + trace-count pins + bf16-upcast check, and the Pallas VMEM
# budget verifier. Exits non-zero on any unsuppressed finding.
analyze:
	env -u XLA_FLAGS JAX_PLATFORMS=cpu $(PY) -m repro.analysis

ci: analyze test-fast

bench:
	$(PY) -m benchmarks.run

bench-serving:
	$(PY) -m benchmarks.run table9

serve:
	$(PY) -m repro.launch.serve --arch qwen3-8b --smoke --batch 8 \
	    --prompt-len 32 --gen 32
