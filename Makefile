PY := PYTHONPATH=src python

.PHONY: test test-fast bench bench-serving serve

# tier-1 gate: every test file must collect and pass (includes tests/test_serve.py)
test:
	$(PY) -m pytest -x -q

# skip the multi-process SPMD tests (slow marker)
test-fast:
	$(PY) -m pytest -x -q -m "not slow"

bench:
	$(PY) -m benchmarks.run

bench-serving:
	$(PY) -m benchmarks.run table9

serve:
	$(PY) -m repro.launch.serve --arch qwen3-8b --smoke --batch 8 \
	    --prompt-len 32 --gen 32
