"""Shared benchmark substrate.

A small LLaMA-family LM is trained once on the synthetic C4-like stream
(cached on disk) and reused by every table. Pruning-method *orderings* and
relative improvements are then measured exactly as the paper does, just at
laptop scale — see EXPERIMENTS.md for the claim-by-claim comparison.
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.store import load_pytree, save_pytree
from repro.configs import get_config
from repro.configs.base import PruneConfig, TrainConfig
from repro.core.pruner import prune_model
from repro.data import calibration_batch, eval_batch, synthetic_lm_stream
from repro.launch.steps import init_train_state, make_train_step
from repro.models.model import Model

CACHE = os.path.join(os.path.dirname(__file__), "_cache")

BENCH_CFG = dict(num_layers=4, d_model=128, num_heads=4, num_kv_heads=4,
                 head_dim=32, d_ff=256, vocab_size=512)
TRAIN_STEPS = 1200
BATCH, SEQ = 16, 64


def bench_model():
    cfg = get_config("llama1-7b").reduced(**BENCH_CFG)
    return Model(cfg)


def trained_params(steps: int = TRAIN_STEPS, force: bool = False):
    """Train (or load) the benchmark LM. Deterministic."""
    model = bench_model()
    cfg = model.cfg
    path = os.path.join(CACHE, f"lm_{steps}")
    params0 = model.init(jax.random.PRNGKey(0))
    if not force and os.path.isdir(path):
        return model, load_pytree(path, params0)
    tc = TrainConfig(learning_rate=1e-3, total_steps=steps,
                     warmup_steps=steps // 10, weight_decay=0.01)
    step = jax.jit(make_train_step(model, tc), donate_argnums=(0,))
    state = init_train_state(model, params0, tc)
    stream = synthetic_lm_stream(cfg.vocab_size, BATCH, SEQ, seed=0)
    t0 = time.time()
    for i, data in zip(range(steps), stream):
        state, m = step(state, {"tokens": data["tokens"],
                                "labels": data["labels"]})
        if i % 100 == 0:
            print(f"  [bench-train] step {i} loss {float(m['loss']):.3f}")
    print(f"  [bench-train] {steps} steps in {time.time() - t0:.0f}s, "
          f"final loss {float(m['loss']):.3f}")
    params = state["params"]
    os.makedirs(CACHE, exist_ok=True)
    save_pytree(path, params)
    return model, params


def perplexity(model, params, n: int = 32, seq: int = SEQ, seed: int = 0):
    ev = eval_batch(model.cfg.vocab_size, n, seq, seed=seed)
    loss = float(model.loss(params, ev)[0])
    return float(jnp.exp(loss))


# Benchmark-scale hyperparameters. The paper's defaults (alpha=100,
# ro_lr=3e-7) are tuned for 3B-70B models; Table 8 shows alpha is
# model-specific, and the RO step size must scale with how far the weights
# are from convergence. Selected by the sweep logged in EXPERIMENTS.md §Repro.
BENCH_ALPHA = 10.0
BENCH_RO_LR = 1e-3


def prune_with(model, params, method: str, pattern: str = "2:4",
               sparsity: float = 0.5, n_calib: int = 32, calib_len: int = SEQ,
               ro_iters: int = 5, alpha: float = BENCH_ALPHA, seed: int = 0,
               ro_lr: float = BENCH_RO_LR):
    """Returns (pruned params, seconds)."""
    pcfg = PruneConfig(method=method, pattern=pattern, sparsity=sparsity,
                       alpha=alpha, n_calib=n_calib, calib_len=calib_len,
                       ro_iters=ro_iters, ro_samples=min(16, n_calib),
                       ro_lr=ro_lr, seed=seed)
    calib = calibration_batch(model.cfg.vocab_size, n_calib, calib_len)
    t0 = time.time()
    pruned, _ = prune_model(model, params, calib, pcfg)
    return pruned, time.time() - t0


def emit(rows, header=("name", "us_per_call", "derived")):
    print(",".join(header))
    for r in rows:
        print(",".join(str(x) for x in r))
