"""Paper Fig. 3: perplexity as N:M pruning sweeps over more decoder blocks.

Reproduces the qualitative claim: Wanda++ 2:4 tracks (or beats) Wanda 4:8,
and the Wanda++-vs-Wanda margin grows with the number of pruned blocks.
"""
from __future__ import annotations

import jax

from benchmarks.common import emit, perplexity, trained_params
from repro.configs.base import PruneConfig
from repro.core.pruner import (make_block_fn, prune_block, tree_get)
from repro.data import calibration_batch
from repro.models import blocks as B


def _prune_first_k(model, params, k: int, method: str, pattern: str):
    """Prune only the first k blocks (paper's progressive sweep)."""
    cfg = model.cfg
    pcfg = PruneConfig(method=method, pattern=pattern, ro_iters=2,
                       ro_samples=8, n_calib=16)
    calib = calibration_batch(cfg.vocab_size, pcfg.n_calib, 64)
    import jax.numpy as jnp
    xs = jnp.take(params["embed"], calib, axis=0)
    block_fn = make_block_fn(cfg)
    prop = jax.jit(lambda b, x: block_fn(b, x))
    blocks = params["blocks"]
    key = jax.random.PRNGKey(0)
    prunable = B.prunable_table(cfg)
    for l in range(k):
        bp = jax.tree_util.tree_map(lambda a: a[l], blocks)
        key, sub = jax.random.split(key)
        bp, _ = prune_block(block_fn, bp, xs, pcfg, prunable, sub)
        blocks = jax.tree_util.tree_map(lambda a, b_: a.at[l].set(b_), blocks, bp)
        xs = prop(bp, xs)
    out = dict(params)
    out["blocks"] = blocks
    return out


def run(model=None, params=None):
    if model is None:
        model, params = trained_params()
    L = model.cfg.num_layers
    rows = []
    for method in ("wanda", "wanda++"):
        for pattern in ("2:4", "4:8"):
            for k in range(0, L + 1):
                pruned = _prune_first_k(model, params, k, method, pattern)
                ppl = perplexity(model, pruned)
                rows.append((f"fig3/{method}/{pattern}/blocks_{k}", 0,
                             f"ppl={ppl:.3f}"))
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
