"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run table1 fig3

Prints ``name,us_per_call,derived`` CSV rows per table and a final summary of
paper-claim checks (orderings / relative improvements).
"""
from __future__ import annotations

import sys
import time

from benchmarks import (fig3_blockwise, table1_perplexity, table2_zeroshot,
                        table3_cost, table4_lora, table5_high_sparsity,
                        table6_structured, table7_latency, table8_alpha,
                        table9_serving, table10_scores)
from benchmarks.common import trained_params

ALL = {
    "table1": table1_perplexity,
    "fig3": fig3_blockwise,
    "table2": table2_zeroshot,
    "table3": table3_cost,
    "table4": table4_lora,
    "table5": table5_high_sparsity,
    "table6": table6_structured,
    "table7": table7_latency,
    "table8": table8_alpha,
    "table9": table9_serving,
    "table10": table10_scores,
}


def main() -> None:
    names = [a for a in sys.argv[1:] if a in ALL] or list(ALL)
    print(f"== benchmark suite: {names}")
    model, params = trained_params()
    results = {}
    for name in names:
        t0 = time.time()
        print(f"\n== {name} ({ALL[name].__doc__.splitlines()[0].strip()})")
        mod = ALL[name]
        if name == "table7":
            results[name] = mod.run()
        else:
            results[name] = mod.run(model, params)
        print(f"== {name} done in {time.time() - t0:.0f}s")

    # ---- paper-claim verdicts ----------------------------------------------
    print("\n== claim checks")
    if "table1" in results:
        r = results["table1"]
        w, wpp = r[("2:4", "wanda")], r[("2:4", "wanda++")]
        rgs = r[("2:4", "wanda++rgs")]
        print(f"claim,table1_wanda++_beats_wanda_2:4,{wpp < w}")
        print(f"claim,table1_ro_helps(w++<w++rgs),{wpp <= rgs}")
        print(f"claim,table1_rel_improvement_2:4,{(w - wpp) / w * 100:.1f}%")
        u, u_pp = r[("unstructured", "wanda")], r[("unstructured", "wanda++")]
        print(f"claim,table1_gain_larger_at_2:4_than_unstructured,"
              f"{(w - wpp) / w >= (u - u_pp) / u}")
    if "table5" in results:
        r = results["table5"]
        ok = all(r[(s, 'wanda++')] <= r[(s, 'wanda')] * 1.05 for s in (0.6, 0.7, 0.8))
        print(f"claim,table5_wanda++_<=_wanda_at_high_sparsity,{ok}")
    if "table6" in results:
        r = results["table6"]
        ok = all(r[(s, 'wanda++-SP')] <= r[(s, 'wanda-SP')] for s in (0.3, 0.5))
        print(f"claim,table6_wanda++SP_beats_wandaSP,{ok}")
    if "table4" in results:
        r = results["table4"]
        ok = (r["wanda++"][1] < r["wanda++"][0]) and (r["wanda"][1] < r["wanda"][0])
        print(f"claim,table4_lora_improves_both,{ok}")
        print(f"claim,table4_wanda++_still_ahead_after_lora,"
              f"{r['wanda++'][1] <= r['wanda'][1]}")
    if "table8" in results:
        r = results["table8"]
        mid = min(r[a] for a in (0.1, 1.0, 10.0))
        print(f"claim,table8_extreme_alpha_worse_than_blend,"
              f"{r[10000.0] >= mid and r[0.0] >= mid * 0.98}")
    if "table9" in results:
        r = results["table9"]
        print(f"claim,table9_engine_2x_over_token_loop,{r['speedup'] >= 2.0}")
        print(f"claim,table9_engine_speedup,{r['speedup']:.1f}x")
        if "paged_slots_ratio" in r:
            print(f"claim,table9_paged_2x_slots_at_equal_hbm,"
                  f"{r['paged_slots_ratio'] >= 2.0}")
            print(f"claim,table9_paged_slots_ratio,"
                  f"{r['paged_slots_ratio']:.1f}x")
        if "paged_attn_bytes" in r:
            # kernel KV traffic must follow cached tokens and undercut the
            # gather's fixed n_slots * max_blocks * page_size ceiling
            b = r["paged_attn_bytes"]
            ok = b[25] < b[50] < b[100] <= r["gather_bytes"]
            print(f"claim,table9_paged_attn_bytes_scale_with_cached,{ok}")
            print(f"claim,table9_paged_attn_bytes_25pct_frac,"
                  f"{b[25] / r['gather_bytes']:.2f}")
        if "mesh_kv_ratio" in r:
            # sharding the KV arena over the model axis must actually cut
            # per-device KV bytes (TP=2 on the 4x2 bench mesh => ~0.5x)
            print(f"claim,table9_mesh_splits_kv_per_device,"
                  f"{r['mesh_kv_ratio'] <= 0.75}")
            print(f"claim,table9_mesh_kv_bytes_ratio,{r['mesh_kv_ratio']:.2f}")
        if "compressed24" in r:
            # build-time 2:4 packing must beat re-masking dense weights in
            # flight at equal output tokens (greedy parity is asserted
            # inside the benchmark itself)
            c = r["compressed24"]
            print(f"claim,table9_compressed24_beats_masked_dense,"
                  f"{c['beats_masked']}")
            print(f"claim,table9_compressed24_speedup_vs_masked,"
                  f"{c['compressed_tok_per_s'] / c['masked_tok_per_s']:.2f}x")
            print(f"claim,table9_compressed24_weight_ratio_bf16,"
                  f"{c['packed_ratio_bf16']:.4f}")
        if "spec" in r:
            # the HARD spec-decode gate: drafting with the wanda++ 2:4
            # artifact must beat target-only decode in the streaming
            # regime at bit-exact greedy output (equality is asserted
            # inside the benchmark; a low-quality drafter fails here
            # through its accept rate, not through wrong tokens)
            s = r["spec"]
            print(f"claim,table9_spec_decode_beats_target_only,"
                  f"{s['beats_target_only']}")
            print(f"claim,table9_spec_decode_speedup,{s['speedup']:.2f}x")
            print(f"claim,table9_spec_decode_mean_accepted,"
                  f"{s['mean_accepted']:.2f}_of_{s['best_k']}")
        if "chunked" in r:
            # chunked prefill's whole point is the TTFT tail: streaming
            # the prompt through the decode scan's chunk lane must halve
            # waved admission-to-first-token p95 in executed forward
            # rows at equal-or-better rows-per-token, at bit-exact
            # greedy output (asserted inside the benchmark). Rows, not
            # CPU wall: on serving hardware decode is weight-bound and
            # rows are time; XLA-CPU's per-step fixed cost inverts that
            # regime, so wall numbers are reported but do not gate.
            ck = r["chunked"]
            print(f"claim,table9_chunked_prefill_ttft,"
                  f"{ck['beats_waved_ttft']}")
            print(f"claim,table9_chunked_ttft_p95_ratio,"
                  f"{ck['ttft_p95_ratio']:.2f}")
            print(f"claim,table9_chunked_rows_per_tok,"
                  f"{ck['chunked_rows_per_tok']:.1f}_vs_waved_"
                  f"{ck['waved_rows_per_tok']:.1f}")
            print(f"claim,table9_chunked_stream_tok_per_s,"
                  f"{ck['chunked_stream_tok_per_s']:.0f}_vs_waved_"
                  f"{ck['waved_stream_tok_per_s']:.0f}")
    if "table10" in results:
        r = results["table10"]
        z = r["zoo"]
        # every registered score must produce a working 2:4 artifact (the
        # zoo gate: no registry entry is allowed to silently break pruning)
        finite = all(v == v and v != float("inf") for v in z.values())
        print(f"claim,table10_all_registered_scores_prune,"
              f"{finite}_({len(z)}_scores)")
        best = min(z, key=z.get)
        print(f"claim,table10_best_2:4_score,{best}_ppl={z[best]:.3f}")
        o = r["online"]
        # the HARD online-calibration gate: re-pruning from live shifted
        # traffic must not lose to the generic offline calibration on that
        # traffic (bit-exact tap parity + pinned trace_counts are asserted
        # inside the benchmark itself)
        print(f"claim,table10_online_beats_offline,"
              f"{o['online'] <= o['offline']}")
        print(f"claim,table10_online_vs_offline_ppl_{o['method']},"
              f"{o['online']:.2f}_vs_{o['offline']:.2f}")
        if "online_wanda" in o:
            print(f"claim,table10_online_beats_offline_wanda,"
                  f"{o['online_wanda'] <= o['offline_wanda']}")


if __name__ == "__main__":
    main()
