"""Paper extension (§12): pruning-score zoo + online calibration vs offline.

Part 1 — the score zoo: every score registered in core/scores.py (magnitude,
wanda, wanda++ variants, gblm, stade, connect) pruned at 2:4 through the
Table 1 harness. One registry drives the pruner, the CLI and this table, so
a newly registered score lands in the benchmark with zero wiring.

Part 2 — online calibration under distribution shift: the deployment
scenario EngineConfig.calib_taps exists for. The shifted serving traffic
walks the SAME learned Markov chain the model was trained on, but starts
and restarts (at an elevated rate) inside the rare-token band — a covariate
shift over learned structure, the regime where calibration choice matters.
(A fully foreign chain is useless here: the model has no structure to
preserve on it, so every mask is equally bad — see benchmarks/PROTOCOL.md.)

An offline-calibrated 2:4 artifact (standard seed-0 calibration stream)
serves that shifted traffic; the tap-enabled engine accumulates per-channel
input statistics from it inside the unchanged jitted step programs.
Re-scoring the dense weights against the snapshot (``reprune_from_stats``)
and hot-swapping via ``Engine.repack`` yields a mask calibrated to what the
engine actually serves. Gates asserted here (checked again in
benchmarks/run.py claims):

  * greedy output with taps on is bit-exact vs taps off, at identical
    ``trace_counts`` (statistics are free — no retrace, no extra sync);
  * ``repack`` does not retrace, and a fresh engine built on the re-pruned
    weights emits the same tokens as the hot-swapped one;
  * online-recalibrated perplexity on the shifted stream <= the offline
    artifact's (matching-method comparison — same score both sides, only
    the calibration distribution differs).
"""
from __future__ import annotations

import json
import os

import numpy as np

import jax.numpy as jnp

from benchmarks.common import (BENCH_ALPHA, emit, perplexity, prune_with,
                               trained_params)
from repro.configs.base import PruneConfig
from repro.core import scores as SC
from repro.core.pruner import reprune_from_stats
from repro.data.calibration import SyntheticLM
from repro.serve import Engine, EngineConfig, SamplingConfig

N_PROMPTS, PROMPT_LEN, GEN, SLOTS = 32, 64, 8, 8
OUT_JSONL = os.path.join(os.path.dirname(__file__), os.pardir, "results",
                         "table10_scores.jsonl")
BAND_LO_FRAC, RESTART = 0.75, 0.3  # rare-token band, elevated restart rate
ONLINE_METHOD = "wanda++rgs"  # matching-method cell: same score both sides


def shifted_sample(vocab: int, n: int, seq: int, stream_seed: int):
    """Traffic from the learned chain, state-biased to the rare-token band.

    Same succ/sp tables as the training stream (SyntheticLM seed 0), but
    the walk starts — and restarts with probability ``RESTART`` instead of
    the stream's 0.1 — from the unigram renormalized over ranks
    [0.75 V, V). Transitions the model knows, channel statistics it rarely
    saw during generic calibration."""
    gen = SyntheticLM(vocab, seed=0)
    uni, succ, sp = gen._tables()
    lo = int(vocab * BAND_LO_FRAC)
    p = uni.copy()
    p[:lo] = 0.0
    p /= p.sum()
    rng = np.random.default_rng((0, stream_seed, 77))
    out = np.empty((n, seq), np.int32)
    cur = rng.choice(vocab, size=n, p=p)
    out[:, 0] = cur
    for t in range(1, seq):
        u = rng.random(n)
        choice = (rng.random(n)[:, None] < np.cumsum(sp[cur], -1)).argmax(-1)
        nxt = succ[cur, choice]
        r = u < RESTART
        if r.any():
            nxt[r] = rng.choice(vocab, size=int(r.sum()), p=p)
        out[:, t] = nxt
        cur = nxt
    return out


def _ppl_on(model, params, toks):
    ev = {"tokens": jnp.asarray(toks[:, :-1]),
          "labels": jnp.asarray(toks[:, 1:])}
    return float(jnp.exp(model.loss(params, ev)[0]))


def _engine(model, params, calib_taps):
    ecfg = EngineConfig(n_slots=SLOTS, max_len=PROMPT_LEN + GEN,
                        chunk=GEN - 1, prefill_buckets=(PROMPT_LEN,),
                        calib_taps=calib_taps)
    return Engine(model, params, ecfg, SamplingConfig())


def run(model=None, params=None):
    if model is None:
        model, params = trained_params()
    rows = [("table10/dense", 0, f"ppl={perplexity(model, params):.3f}")]
    results = {}

    # ---- part 1: the zoo, every registered score at 2:4 --------------------
    zoo = {}
    for method in SC.available():
        pruned, secs = prune_with(model, params, method, "2:4", 0.5)
        ppl = perplexity(model, pruned)
        zoo[method] = ppl
        rows.append((f"table10/2:4/{method}",
                     round(secs * 1e6 / max(model.cfg.num_layers, 1)),
                     f"ppl={ppl:.3f}"))
    results["zoo"] = zoo

    # ---- part 2: online vs offline calibration under shift -----------------
    vocab = model.cfg.vocab_size
    offline, _ = prune_with(model, params, ONLINE_METHOD, "2:4", 0.5)
    ev_toks = shifted_sample(vocab, 32, PROMPT_LEN + 1, stream_seed=2)
    ppl_dense_shift = _ppl_on(model, params, ev_toks)
    ppl_offline = _ppl_on(model, offline, ev_toks)

    # serve shifted traffic on the offline artifact, taps on vs off
    eng = _engine(model, offline, calib_taps=True)
    ref = _engine(model, offline, calib_taps=False)
    prompts = shifted_sample(vocab, N_PROMPTS, PROMPT_LEN, stream_seed=3)
    for i in range(0, N_PROMPTS, SLOTS):
        out = eng.generate(prompts[i:i + SLOTS], GEN)
        out_ref = ref.generate(prompts[i:i + SLOTS], GEN)
        assert np.array_equal(out, out_ref), \
            "calib taps changed greedy output"
    assert eng.trace_counts == ref.trace_counts, \
        (eng.trace_counts, ref.trace_counts)
    snap = eng.calibration_snapshot()
    traces_before = dict(eng.trace_counts)

    # re-score the DENSE weights against the live statistics; the regional
    # gradient replays a window of the shifted traffic itself
    online = reprune_from_stats(
        model, params, snap["stats"],
        PruneConfig(method=ONLINE_METHOD, pattern="2:4", alpha=BENCH_ALPHA),
        calib=jnp.asarray(prompts[:8]))
    ppl_online = _ppl_on(model, online, ev_toks)

    # second cell, stats-only score: the snapshot is method-independent, so
    # the same live statistics re-score wanda with no extra serving
    offline_w, _ = prune_with(model, params, "wanda", "2:4", 0.5)
    online_w = reprune_from_stats(model, params, snap["stats"],
                                  PruneConfig(method="wanda", pattern="2:4"))
    ppl_offline_w = _ppl_on(model, offline_w, ev_toks)
    ppl_online_w = _ppl_on(model, online_w, ev_toks)

    # hot-swap: repack must not retrace, and must match a fresh build
    eng.repack(online)
    out_swapped = eng.generate(prompts[:SLOTS], GEN)
    assert dict(eng.trace_counts) == traces_before, \
        "repack retraced the step programs"
    fresh = _engine(model, online, calib_taps=False)
    assert np.array_equal(out_swapped, fresh.generate(prompts[:SLOTS], GEN)), \
        "hot-swapped engine diverges from fresh build on re-pruned weights"

    rows += [
        ("table10/shift/dense", 0, f"ppl={ppl_dense_shift:.3f}"),
        (f"table10/shift/offline_{ONLINE_METHOD}", 0,
         f"ppl={ppl_offline:.3f}"),
        (f"table10/shift/online_{ONLINE_METHOD}", 0,
         f"ppl={ppl_online:.3f}"),
        ("table10/shift/online_vs_offline", 0,
         f"delta={(ppl_offline - ppl_online) / ppl_offline * 100:.1f}%"),
        ("table10/shift/offline_wanda", 0, f"ppl={ppl_offline_w:.3f}"),
        ("table10/shift/online_wanda", 0, f"ppl={ppl_online_w:.3f}"),
        ("table10/shift/live_tokens", int(snap["tokens"]), ""),
    ]
    results["online"] = {
        "method": ONLINE_METHOD,
        "dense": ppl_dense_shift,
        "offline": ppl_offline,
        "online": ppl_online,
        "offline_wanda": ppl_offline_w,
        "online_wanda": ppl_online_w,
        "tokens": float(snap["tokens"]),
    }
    emit(rows)
    try:
        os.makedirs(os.path.dirname(os.path.abspath(OUT_JSONL)),
                    exist_ok=True)
        with open(OUT_JSONL, "w") as f:
            f.write(json.dumps({"dense_ppl": perplexity(model, params),
                                "zoo": zoo, "online": results["online"]})
                    + "\n")
    except OSError:
        pass
    return results


if __name__ == "__main__":
    run()
