"""Paper Table 1: perplexity of all methods across sparsity patterns.

Validates (at benchmark scale) the paper's headline orderings:
  dense < wanda++ < wanda++RO < wanda++RGS ~ gblm < wanda  (2:4)
and that Wanda++ improves over Wanda by a meaningful relative margin.
"""
from __future__ import annotations

from benchmarks.common import emit, perplexity, prune_with, trained_params

METHODS = ["magnitude", "sparsegpt", "wanda", "gblm",
           "wanda++rgs", "wanda++ro", "wanda++"]
PATTERNS = [("unstructured", 0.5), ("2:4", 0.5), ("4:8", 0.5)]


def run(model=None, params=None):
    if model is None:
        model, params = trained_params()
    base_ppl = perplexity(model, params)
    rows = [("table1/dense", 0, f"ppl={base_ppl:.3f}")]
    results = {}
    for pattern, sp in PATTERNS:
        for method in METHODS:
            pruned, secs = prune_with(model, params, method, pattern, sp)
            ppl = perplexity(model, pruned)
            results[(pattern, method)] = ppl
            rows.append((f"table1/{pattern}/{method}",
                         round(secs * 1e6 / max(model.cfg.num_layers, 1)),
                         f"ppl={ppl:.3f}"))
    # paper's headline relative improvement (2:4): Wanda++ vs Wanda
    w, wpp = results[("2:4", "wanda")], results[("2:4", "wanda++")]
    rel = (w - wpp) / (w - 1e-9) * 100
    rows.append(("table1/rel_improvement_2:4", 0,
                 f"wanda++_vs_wanda={rel:.1f}%"))
    emit(rows)
    return results


if __name__ == "__main__":
    run()
