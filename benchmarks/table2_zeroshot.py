"""Paper Table 2: downstream (zero-shot) probes after 2:4 pruning.

No Harness in this container; we probe generalization with synthetic tasks
that ask the paper's actual question — does RO (trained only on the
calibration reconstruction loss) hurt abilities plain perplexity misses?

  top1 / top5  : next-token accuracy on held-out text
  tail-acc     : accuracy restricted to rare (tail-of-Zipf) targets
  bigram       : accuracy on positions where the Markov transition is
                 near-deterministic (the "easy facts" probe)
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, prune_with, trained_params
from repro.data import eval_batch


def probes(model, params, n=32, seq=64):
    ev = eval_batch(model.cfg.vocab_size, n, seq, seed=3)
    logits, _ = model.forward(params, ev)
    labels = np.asarray(ev["labels"])
    lg = np.asarray(logits, np.float32)
    top1 = (lg.argmax(-1) == labels).mean()
    top5 = (np.argsort(-lg, -1)[..., :5] == labels[..., None]).any(-1).mean()
    tail = labels >= (model.cfg.vocab_size // 4)
    tail_acc = (lg.argmax(-1) == labels)[tail].mean() if tail.any() else 0.0
    return {"top1": top1, "top5": top5, "tail_acc": tail_acc}


def run(model=None, params=None):
    if model is None:
        model, params = trained_params()
    rows = []
    results = {}
    for name, method in [("dense", None), ("wanda", "wanda"),
                         ("wanda++rgs", "wanda++rgs"), ("wanda++", "wanda++")]:
        p = params if method is None else prune_with(model, params, method)[0]
        pr = probes(model, p)
        results[name] = pr
        rows.append((f"table2/{name}", 0,
                     ";".join(f"{k}={v:.4f}" for k, v in pr.items())))
    emit(rows)
    return results


if __name__ == "__main__":
    run()
