"""Paper Table 3: pruning time and memory by method.

Validates the cost ordering the paper reports:
    wanda < wanda++RGS < wanda++(M) <~ sparsegpt << gblm
and the O(one-block) peak-memory property of regional methods vs the
O(full-model) gradient of GBLM (measured analytically + by wall time here;
the paper's absolute numbers are H100 wall-clock).
"""
from __future__ import annotations

from benchmarks.common import emit, prune_with, trained_params


def run(model=None, params=None):
    if model is None:
        model, params = trained_params()
    cfg = model.cfg
    import jax
    block_params = sum(
        int(l[0].size) if hasattr(l, "size") else 0
        for l in [jax.tree_util.tree_leaves(
            jax.tree_util.tree_map(lambda a: a[0], params["blocks"]))]
        for l in [l])
    block_n = sum(x[0].size for x in
                  [jax.tree_util.tree_leaves(
                      jax.tree_util.tree_map(lambda a: a[0], params["blocks"]))]
                  for x in [x])
    rows, times = [], {}
    for method in ("wanda", "wanda++rgs", "wanda++", "sparsegpt", "gblm"):
        _, secs = prune_with(model, params, method)
        times[method] = secs
        # regional methods touch one block of grads at a time; gblm all L
        grad_mem = "O(block)" if method != "gblm" else "O(model)"
        rows.append((f"table3/{method}", round(secs * 1e6),
                     f"seconds={secs:.2f};grad_mem={grad_mem}"))
    ok = times["wanda"] <= times["wanda++rgs"] <= times["wanda++"] * 1.5
    rows.append(("table3/ordering_wanda<rgs<wanda++", 0, f"holds={ok}"))
    emit(rows)
    return times


if __name__ == "__main__":
    run()
