"""Paper Table 4: LoRA fine-tuning is orthogonal to Wanda++.

Prune with Wanda and Wanda++ (2:4), LoRA-fine-tune both on the training
stream (q,v adapters, base weights frozen so sparsity is preserved), and
check both improve while Wanda++ stays ahead.
"""
from __future__ import annotations

import jax

from benchmarks.common import BATCH, SEQ, emit, perplexity, prune_with, trained_params
from repro.configs.base import TrainConfig
from repro.core.lora import add_lora, lora_trainable
from repro.data import synthetic_lm_stream
from repro.launch.steps import init_train_state, make_train_step


def lora_finetune(model, params, steps=150):
    lp = add_lora(params, jax.random.PRNGKey(7), rank=8)
    tc = TrainConfig(learning_rate=5e-4, total_steps=steps,
                     warmup_steps=10, weight_decay=0.0)
    # no donation: the LoRA state aliases the pruned/base param buffers,
    # which later tables still read
    step = jax.jit(make_train_step(model, tc, trainable=lora_trainable(lp)))
    state = init_train_state(model, lp, tc)
    stream = synthetic_lm_stream(model.cfg.vocab_size, BATCH, SEQ, seed=0,
                                start_step=50_000)
    for i, data in zip(range(steps), stream):
        state, m = step(state, {"tokens": data["tokens"],
                                "labels": data["labels"]})
    return state["params"]


def run(model=None, params=None):
    if model is None:
        model, params = trained_params()
    dense_ppl = perplexity(model, params)
    rows = [("table4/dense", 0, f"ppl={dense_ppl:.3f}")]
    results = {}
    for method in ("wanda", "wanda++"):
        pruned, _ = prune_with(model, params, method)
        before = perplexity(model, pruned)
        tuned = lora_finetune(model, pruned)
        after = perplexity(model, tuned)
        results[method] = (before, after)
        rel = (before - after) / before * 100
        rows.append((f"table4/{method}", 0,
                     f"pruned_ppl={before:.3f};lora_ppl={after:.3f};rel={rel:.0f}%"))
    emit(rows)
    return results


if __name__ == "__main__":
    run()
