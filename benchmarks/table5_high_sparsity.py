"""Paper Table 5: unstructured pruning at 0.6 / 0.7 / 0.8 sparsity."""
from __future__ import annotations

from benchmarks.common import emit, perplexity, prune_with, trained_params


def run(model=None, params=None):
    if model is None:
        model, params = trained_params()
    rows, results = [], {}
    for sp in (0.6, 0.7, 0.8):
        for method in ("gblm", "wanda", "wanda++"):
            pruned, _ = prune_with(model, params, method,
                                   pattern="unstructured", sparsity=sp)
            ppl = perplexity(model, pruned)
            results[(sp, method)] = ppl
            rows.append((f"table5/s{sp}/{method}", 0, f"ppl={ppl:.3f}"))
    emit(rows)
    return results


if __name__ == "__main__":
    run()
