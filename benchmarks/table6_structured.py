"""Paper Table 6 (Sec. 6): row-wise structured pruning, Wanda-SP vs
Wanda++-SP at 0.1 / 0.3 / 0.5 ratios."""
from __future__ import annotations

from benchmarks.common import emit, perplexity, prune_with, trained_params


def run(model=None, params=None):
    if model is None:
        model, params = trained_params()
    rows, results = [], {}
    for sp in (0.1, 0.3, 0.5):
        for method, label in (("wanda", "wanda-SP"), ("wanda++", "wanda++-SP")):
            pruned, _ = prune_with(model, params, method, pattern="row",
                                   sparsity=sp)
            ppl = perplexity(model, pruned)
            results[(sp, label)] = ppl
            rows.append((f"table6/r{sp}/{label}", 0, f"ppl={ppl:.3f}"))
    emit(rows)
    return results


if __name__ == "__main__":
    run()
