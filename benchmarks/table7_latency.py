"""Paper Table 7/9 (Appendix B): deployment latency impact of 2:4 sparsity.

No TPU wall clock in this container, so we report the TPU roofline
projection (the quantity that *causes* the paper's measured TTFT/TPOT wins)
plus CPU microbenchmarks of the actual Pallas kernels in interpret mode for
correctness-of-plumbing timing only.

The projection mirrors the paper's FP16-vs-FP8 observation: decode (TPOT)
is weight-bandwidth-bound, so halving weight bytes with 2:4 compaction gives
~1.8x on the weight term; prefill (TTFT) is compute-bound on TPU (MXU has no
sparse path) so 2:4 gives ~0 FLOP win — the paper saw the same asymmetry
under FP8 where their GPUs became compute-bound (Table 9).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.configs import get_config
from repro.distributed.roofline import HW
from repro.kernels import ops


def _time(f, *args, n=5):
    f(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(n):
        out = f(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / n * 1e6  # us


def run(model=None, params=None):
    rows = []
    # --- roofline projection for a real config (llama1-7b decode) ----------
    cfg = get_config("llama1-7b")
    w_bytes = cfg.param_count() * 2  # bf16
    kv = 2 * 1 * 2048 * cfg.num_kv_heads * cfg.resolved_head_dim * 2 * cfg.num_layers
    t_dense = (w_bytes + kv) / HW.hbm_bw * 1e3
    # 2:4 on attn+mlp weights (embeddings/head stay dense, like the paper)
    body = cfg.num_layers * (4 * cfg.d_model * cfg.num_heads *
                             cfg.resolved_head_dim + 3 * cfg.d_model * cfg.d_ff)
    w_sparse = (cfg.param_count() - body) * 2 + body * 2 * 0.5625  # vals+idx
    t_sparse = (w_sparse + kv) / HW.hbm_bw * 1e3
    rows.append(("table7/tpot_roofline_dense_ms", 0, f"{t_dense:.3f}"))
    rows.append(("table7/tpot_roofline_2:4_ms", 0, f"{t_sparse:.3f}"))
    rows.append(("table7/tpot_reduction", 0,
                 f"{(1 - t_sparse / t_dense) * 100:.1f}%"))
    # weight memory reduction (paper: 28% FP16)
    rows.append(("table7/weight_memory_reduction", 0,
                 f"{(1 - w_sparse / w_bytes) * 100:.1f}%"))

    # --- kernel microbench (interpret mode: plumbing only) ------------------
    M, K, N = 128, 1024, 512
    x = jax.random.normal(jax.random.PRNGKey(0), (M, K))
    w = jax.random.normal(jax.random.PRNGKey(1), (K, N))
    from repro.core.masks import nm_mask as core_nm
    mask = core_nm(jnp.abs(w.T), 2, 4).T
    ws = jnp.where(mask, w, 0)
    vals, idx = ops.compact24(ws)
    t_dense_mm = _time(jax.jit(lambda a, b: a @ b), x, ws)
    t_sparse_mm = _time(ops.sparse_matmul24, x, vals, idx)
    t_masked = _time(ops.masked_matmul, x, w, mask)
    rows.append(("table7/cpu_dense_matmul", round(t_dense_mm), "reference"))
    rows.append(("table7/cpu_sparse24_kernel_interpret", round(t_sparse_mm),
                 "correctness-path"))
    rows.append(("table7/cpu_masked_kernel_interpret", round(t_masked),
                 "correctness-path"))
    # HBM bytes the kernels would move on TPU
    dense_tile_bytes = K * N * 4
    sparse_tile_bytes = (K // 2) * N * 4 + (K // 2) * N  # vals f32 + idx i8
    rows.append(("table7/kernel_weight_bytes_ratio", 0,
                 f"{sparse_tile_bytes / dense_tile_bytes:.3f}"))
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
