"""Paper Table 8 (Appendix B.2): RGS scaling-factor alpha ablation.

Checks the qualitative finding: perplexity vs alpha is roughly U-shaped —
very large alpha (gradient-only) is worse than a moderate blend.
"""
from __future__ import annotations

from benchmarks.common import emit, perplexity, prune_with, trained_params

ALPHAS = [0.0, 0.1, 1.0, 10.0, 100.0, 10000.0]


def run(model=None, params=None):
    if model is None:
        model, params = trained_params()
    rows, results = [], {}
    for a in ALPHAS:
        pruned, _ = prune_with(model, params, "wanda++rgs", alpha=a)
        ppl = perplexity(model, pruned)
        results[a] = ppl
        rows.append((f"table8/alpha_{a:g}", 0, f"ppl={ppl:.3f}"))
    emit(rows)
    return results


if __name__ == "__main__":
    run()
