"""Serving throughput: continuous-batching engine vs the per-token loop.

The paper's deployment claim (Table 7 / Appendix B) is that 2:4 sparsity
pays off on the *decode* path. That is only measurable if decode latency
reflects the hardware rather than Python dispatch — the seed served one
token per Python-loop iteration (one XLA dispatch per token). This table
measures:

  1. per-token-loop decode throughput (the seed baseline),
  2. engine decode throughput (one jitted scan per generation) — the
     claim check requires >= 2x over (1) at batch 8,
  3. dense vs wanda++ 2:4-pruned weights through the same engine
     (CPU parity of plumbing + the TPU weight-traffic projection that
     produces the paper's TPOT win),
  4. a mixed-length request stream through the continuous-batching
     scheduler: requests/s, tokens/s, TTFT/TPOT p50/p95,
  5. dense KV pool vs the paged pool at EQUAL KV HBM: concurrent slots,
     bytes per concurrent request, tokens/s — plus shared-prefix admission
     (a registered system prompt is prefetched once; its pages are mapped,
     not recomputed, into every request that starts with it),
  6. the Pallas paged-attention decode kernel vs the materialising gather:
     greedy parity, decode-step wall time, and per-step KV bytes touched at
     25/50/100% pool occupancy — the kernel's traffic must scale with the
     tokens actually cached, the gather's is pinned at
     n_slots * max_blocks * page_size,
  7. the family matrix: SSM (mamba2), hybrid (zamba2), VLM (qwen2-vl) smoke
     configs through the SAME engine + scheduler — tokens/s, decode-state
     bytes per slot (CacheSpec accounting: fixed recurrent leaves vs a
     max_len KV row), and a greedy decode-parity assert of every completion
     against a per-request full forward,
  8. mesh-sharded decode: the same paged engine single-device vs sharded
     over a forced-host 4x2 (data, model) CPU mesh (subprocess — the parent
     process must keep seeing one device) — decode tokens/s, per-device KV
     arena bytes (the model axis splits KV heads, so each chip holds
     1/TP of the arena), and a greedy token-equality assert. CPU numbers
     measure plumbing overhead only; the HBM-per-chip split is the claim.
  9. compressed 2:4 serving: a 2:4-pruned model served from compacted
     (vals + packed 2-bit idx) storage vs the masked-dense reference
     (dense weights multiplied by an int8 mask every decode step —
     kernels/masked_matmul.py's semantic). Greedy tokens must match
     bit-exactly across compressed / masked / dense engines, measured
     packed bytes must hit compressed24_ratio, and compressed decode
     tok/s must beat masked-dense at equal output tokens — the claim
     that packing at engine build beats re-masking in flight.
 10. self-speculative decoding: the wanda++ 2:4-pruned copy of the
     target (section 3's artifact — only servable as a drafter because
     the fixed RO loop re-applies the mask after the final round) drafts
     draft_k tokens per macro step; the target verifies all of them in
     one batched forward. Measured in the streaming regime speculative
     decoding exists for — every decoded token surfaced to the host as
     soon as it is available: target-only decode surfaces one token per
     device round-trip by construction, spec decode surfaces the whole
     accepted run. The claim gate requires spec streaming tok/s >
     target-only streaming tok/s at BIT-EXACT greedy output (asserted
     token-for-token), with the mean accepted length reported per
     draft_k — the accept rate IS the paper's quality story, restated
     as serving throughput.
 11. chunked prefill vs waved admission: the same mixed request list
     through the unified chunked step program (prompts stream through
     the decode scan's chunk lane; no prefill program exists) vs the
     waved fallback (every admission pauses decode for a bucket-padded
     prefill forward). TTFT on both paths is admission of the request's
     first chunk to its first emitted token (the scheduler's per-chunk
     attribution; slot queueing is capacity, which chunking does not
     change), measured in the deterministic unit both paths share:
     forward rows the engine computed in between (Completion.ttft_rows,
     from the executed schedules). Greedy tokens must match per
     request, and the claim gate requires chunked TTFT p95 < 0.5x waved
     in rows at equal-or-better rows-per-emitted-token — head-of-line
     blocking restated as tail latency. CPU wall clocks are reported
     alongside but do not gate (section 8's precedent: XLA-CPU's
     per-step fixed cost inverts the weight-bound regime the rows
     model the claim targets).

Rows land in the usual CSV; a JSONL record for results/report.py
--serving is written next to the other results.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, prune_with, trained_params
from repro.core.pruner import model_sparsity_report
from repro.data import calibration_batch
from repro.distributed.roofline import HW
from repro.serve import Engine, EngineConfig, Request, SamplingConfig
from repro.serve.scheduler import Scheduler, percentile as _pct

BATCH, PROMPT, GEN = 8, 32, 32
OUT_JSONL = os.path.join(os.path.dirname(__file__), os.pardir, "results",
                         "table9_serving.jsonl")


def seed_loop_decode(model, params, prompts, gen):
    """The seed's serving loop: prefill, then one decode_step dispatch per
    token from Python. Returns (tokens (B, gen), decode_seconds)."""
    prefill = jax.jit(lambda p, b: model.forward(p, b, return_cache=True))
    logits, _, cache_s = prefill(params, {"tokens": prompts})
    first = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
    B, P = prompts.shape
    cache = model.init_cache(B, P + gen)
    ck = jax.lax.dynamic_update_slice(cache[0], cache_s[0], (0, 0, 0, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache[1], cache_s[1], (0, 0, 0, 0, 0))
    cache = (ck, cv)
    step = jax.jit(lambda p, c, i: model.decode_step(p, i, c))
    # warm the trace so both contenders time steady-state dispatch
    _ = step(params, cache, {"token": first, "pos": jnp.int32(P)})
    toks, tok = [first], first
    t0 = time.perf_counter()
    for i in range(gen - 1):
        logits, cache = step(params, cache,
                             {"token": tok, "pos": jnp.int32(P + i)})
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        toks.append(tok)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    return np.asarray(jnp.stack(toks, axis=1)), dt


def engine_decode(model, params, prompts, gen):
    """Engine path: prefill wave + ONE jitted scan. Returns (tokens, dt).

    Pins the dense pool: this section isolates jitted-scan vs per-token
    Python dispatch (same cache layout as the seed loop); section 5 measures
    what paging buys on top."""
    B, P = prompts.shape
    eng = Engine(model, params,
                 EngineConfig(n_slots=B, max_len=P + gen, chunk=gen - 1,
                              prefill_buckets=(P,), paged=False))
    first = eng.admit_wave(list(np.asarray(prompts)), list(range(B)),
                           [gen] * B)
    _ = eng.harvest(*eng.decode_chunk())  # warm the decode trace
    eng.reset()
    first = eng.admit_wave(list(np.asarray(prompts)), list(range(B)),
                           [gen] * B)
    t0 = time.perf_counter()
    toks, valid = eng.decode_chunk(gen - 1)
    t, _, _, _ = eng.harvest(toks, valid)
    dt = time.perf_counter() - t0
    out = np.concatenate([first[:, None], t[:, :B].T], axis=1)
    assert eng.trace_counts["decode"] == 1, "decode must be a single program"
    return out, dt


def family_stream(arch, n_requests=12, n_slots=4, gen=8):
    """One SSM/hybrid/VLM smoke config through the spec-driven engine: a
    mixed-length scheduler stream (slot reuse included), with EVERY
    completion asserted bit-exact against a per-request full forward —
    the deployment story the dense/MoE sections tell, now family-wide.
    Returns tokens/s and the CacheSpec's decode-state bytes per slot."""
    from repro.configs import get_config
    from repro.models.model import Model

    cfg = get_config(arch).reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    vis_p = cfg.vision_patches if cfg.frontend == "vision" else 0
    max_len = vis_p + PROMPT + gen
    eng = Engine(model, params, EngineConfig(
        n_slots=n_slots, max_len=max_len, chunk=4,
        prefill_buckets=(PROMPT // 2, PROMPT)))
    rng = np.random.default_rng(23)

    reqs = []
    for i in range(n_requests):
        toks = rng.integers(
            0, cfg.vocab_size,
            int(rng.integers(PROMPT // 2, PROMPT + 1))).astype(np.int32)
        vis = rng.standard_normal(
            (vis_p, cfg.d_model)).astype(np.float32) if vis_p else None
        reqs.append(Request(i, toks, int(rng.integers(gen // 2, gen + 1)),
                            vision_embeds=vis))

    # warm with the IDENTICAL request list so every traced shape (both
    # prefill buckets, every pow-2 wave size the stream produces) is
    # compiled before timing; Scheduler.run resets the engine each run
    Scheduler(eng).run(reqs)
    t0 = time.perf_counter()
    comps = Scheduler(eng).run(reqs)
    wall = time.perf_counter() - t0
    n_tok = sum(len(c.tokens) for c in comps)
    # decode parity: every completion is the exact greedy continuation of a
    # full forward over [vision? | prompt | generated]
    for c in comps:
        r = reqs[c.rid]
        seq = np.concatenate([r.tokens, c.tokens])[None].astype(np.int32)
        inputs = {"tokens": jnp.asarray(seq)}
        if r.vision_embeds is not None:
            inputs["vision_embeds"] = jnp.asarray(r.vision_embeds[None])
        logits, _ = model.forward(params, inputs)
        ref = np.asarray(jnp.argmax(logits[0], axis=-1))
        off = r.n_vis + len(r.tokens) - 1
        assert all(t == ref[off + i] for i, t in enumerate(c.tokens)), \
            f"{arch}: engine diverged from the full-forward reference"
    return {"family": cfg.family, "arch": arch, "tok_per_s": n_tok / wall,
            "state_bytes_per_slot": model.cache_spec.slot_state_bytes(max_len),
            "paged": eng.paged}


def mesh_worker(data_ax=4, model_ax=2, out=sys.stdout):
    """Section 8's subprocess body (``--mesh-worker``): runs under a forced
    multi-device CPU host, builds the smoke dense arch's paged engine twice
    — single-device and (data, model)-meshed — times one warm decode chunk
    through each, and prints a single JSON line. Per-device KV bytes come
    from the arena leaves' actual shard sizes, so the number reports what
    the mesh really buys: each device holds 1/TP of the KV heads (and the
    dense slot axis would further split over data)."""
    from repro.configs import get_config
    from repro.launch.mesh import make_dev_mesh
    from repro.models.model import Model

    cfg = get_config("qwen3-8b").reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, P, G = BATCH, PROMPT, GEN
    prompts = list(np.asarray(
        calibration_batch(cfg.vocab_size, B, P, seed=7)))

    def run_one(mesh):
        eng = Engine(model, params, EngineConfig(
            n_slots=B, max_len=P + G, chunk=G - 1, prefill_buckets=(P,),
            paged=True, page_size=8, mesh=mesh))
        eng.admit_wave(prompts, list(range(B)), [G] * B)
        _ = eng.harvest(*eng.decode_chunk())  # warm the decode trace
        eng.reset()
        first = eng.admit_wave(prompts, list(range(B)), [G] * B)
        t0 = time.perf_counter()
        toks, valid = eng.decode_chunk(G - 1)
        t, _, _, _ = eng.harvest(toks, valid)
        dt = time.perf_counter() - t0
        per_dev = {}
        for leaf in jax.tree_util.tree_leaves(eng.cache):
            for sh in leaf.addressable_shards:
                did = sh.device.id
                per_dev[did] = per_dev.get(did, 0) + sh.data.nbytes
        tokens = np.concatenate([first[:, None], t[:, :B].T], axis=1)
        return tokens, B * (G - 1) / dt, max(per_dev.values())

    toks_1, tps_1, kv_1 = run_one(None)
    toks_m, tps_m, kv_m = run_one(make_dev_mesh(data_ax, model_ax))
    rec = {"mesh": [data_ax, model_ax], "devices": jax.device_count(),
           "single_tok_per_s": tps_1, "sharded_tok_per_s": tps_m,
           "kv_bytes_per_device_single": kv_1,
           "kv_bytes_per_device_sharded": kv_m,
           "greedy_match": bool((toks_1 == toks_m).all())}
    print(json.dumps(rec), file=out, flush=True)
    return rec


def compressed_section():
    """Section 9: compressed 2:4 decode vs the masked-dense reference.

    Uses its own config — wide enough (d_model 256, d_ff 2048, 8 layers)
    that per-step weight handling dominates Python dispatch, with short
    chunks (2) so the masked engine re-materialises ``w * mask`` once per
    decode call rather than having XLA hoist it out of one long scan.
    Weights are magnitude-pruned to exact 2:4 along the reduction axis, so
    every projection passes ``sparsity_check24`` and the compressed engine's
    auto-detect packs all of them."""
    from repro.configs import get_config
    from repro.core.masks import nm_mask as core_nm
    from repro.core.pruner import tree_get, tree_set
    from repro.kernels.ops import compressed24_ratio
    from repro.models.blocks import prunable_table
    from repro.models.model import Model

    cfg9 = get_config("llama1-7b").reduced(
        d_model=256, d_ff=2048, num_layers=8, num_heads=4, num_kv_heads=4,
        head_dim=64)
    model = Model(cfg9)
    params = model.init(jax.random.PRNGKey(0))
    blocks, dense_bytes = params["blocks"], 0
    for _, path in prunable_table(cfg9).items():
        if path[-1] != "w":
            continue
        w = tree_get(blocks, path)
        if w is None or w.ndim != 3:
            continue
        mask = jax.vmap(lambda wl: core_nm(jnp.abs(wl.T), 2, 4).T)(w)
        blocks = tree_set(blocks, path, jnp.where(mask, w, 0))
        dense_bytes += w.size * w.dtype.itemsize
    params = dict(params, blocks=blocks)

    # Measurement discipline: off-TPU the compressed engine serves a
    # build-time dense copy of the packed weights, so its per-step compute
    # graph is IDENTICAL to compressed24="off" — the gate below is purely a
    # timing measurement of the masked engine's per-call ``w * mask``
    # re-materialisation. At a short decode span, best-of-2 CPU wall times
    # sit inside scheduler jitter and the gate flips sign run-to-run (a
    # recorded beats_masked=false at 352-vs-377 tok/s was exactly that);
    # 64 decode tokens + best-of-5 lifts the re-masking overhead above
    # per-run jitter, and the rounds INTERLEAVE the three modes so a slow
    # machine phase (the full benchmark suite drifts over minutes) lands
    # on all of them equally instead of biasing whichever mode's block
    # it overlaps.
    B9, P9, G9, CH9 = 8, 16, 65, 2  # first token + 64 decode = 32 chunks of 2
    prompts = list(np.asarray(
        calibration_batch(cfg9.vocab_size, B9, P9, seed=29)))
    n_chunks = (G9 - 1) // CH9

    def mk(mode):
        eng = Engine(model, params, EngineConfig(
            n_slots=B9, max_len=P9 + G9, chunk=CH9, prefill_buckets=(P9,),
            paged=True, page_size=8, compressed24=mode))
        eng.admit_wave(prompts, list(range(B9)), [G9] * B9)
        _ = eng.harvest(*eng.decode_chunk(CH9))  # warm the decode trace
        return eng

    def one_run(eng):
        eng.reset()
        first = eng.admit_wave(prompts, list(range(B9)), [G9] * B9)
        chunks = []
        t0 = time.perf_counter()
        for _ in range(n_chunks):
            toks, valid = eng.decode_chunk(CH9)
            t, _, _, _ = eng.harvest(toks, valid)
            chunks.append(t[:, :B9].T)
        dt = time.perf_counter() - t0
        return np.concatenate([first[:, None]] + chunks, axis=1), dt

    engines = {m: mk(m) for m in ("auto", "masked", "off")}
    best = {m: float("inf") for m in engines}
    toks = {}
    for _ in range(5):
        for m, eng in engines.items():
            toks[m], dt = one_run(eng)
            best[m] = min(best[m], dt)
    tps = {m: B9 * n_chunks * CH9 / best[m] for m in engines}
    eng_c, toks_c, tps_c = engines["auto"], toks["auto"], tps["auto"]
    eng_m, toks_m, tps_m = engines["masked"], toks["masked"], tps["masked"]
    toks_d, tps_d = toks["off"], tps["off"]
    assert eng_c.compressed24 == eng_m.compressed24 > 0, \
        "auto-detect missed 2:4 projections"
    assert (toks_c == toks_m).all() and (toks_c == toks_d).all(), \
        "compressed decode diverged from the masked-dense reference"

    # storage accounting: packed leaves only (what a TPU serve would keep
    # in HBM; the CPU fallback's build-time dense copy is scratch)
    packed_bytes = 0
    for _, path in prunable_table(cfg9).items():
        if path[-1] != "w":
            continue
        p = tree_get(eng_c.params["blocks"], path[:-1])
        if p is None or "w24_vals" not in p:
            continue
        packed_bytes += sum(int(np.prod(p[k].shape)) * p[k].dtype.itemsize
                            for k in ("w24_vals", "w24_idx"))
    ratio = packed_bytes / dense_bytes
    assert abs(ratio - compressed24_ratio(4)) < 1e-6, \
        f"packed ratio {ratio} != {compressed24_ratio(4)} (f32)"
    return {"n_proj": eng_c.compressed24,
            "compressed_tok_per_s": tps_c, "masked_tok_per_s": tps_m,
            "dense_tok_per_s": tps_d, "greedy_match": True,
            "packed_ratio_f32": ratio,
            "packed_ratio_bf16": compressed24_ratio(2),
            "beats_masked": bool(tps_c > tps_m)}


def spec_section(model, params, drafter):
    """Section 10: self-speculative decoding with the wanda++ 2:4 drafter.

    Streaming regime (harvest after every chunk, i.e. every token is
    surfaced to the host as soon as it exists): the target-only engine
    runs chunk=1 — one device round-trip per token, the finest streaming
    granularity it supports — while the spec engine runs one macro step
    per chunk and surfaces the accepted run (1..draft_k+1 tokens) per
    round-trip. Output must be bit-exact per token; the win is real
    exactly when the drafter's accept rate is high, which is the paper's
    near-dense-quality claim measured as serving throughput."""
    cfg = model.cfg
    B, P, G = BATCH, PROMPT, GEN + 1  # first token + GEN decode tokens
    prompts = list(np.asarray(
        calibration_batch(cfg.vocab_size, B, P, seed=7)))

    def stream_wave(k, draft):
        eng = Engine(model, params, EngineConfig(
            n_slots=B, max_len=P + G + k, chunk=(k + 1) if k else 1,
            prefill_buckets=(P,), paged=True, page_size=8, draft_k=k),
            SamplingConfig(), draft_params=draft)
        if k:
            assert eng.compressed24_draft > 0, \
                "drafter must serve through the compressed24 path"
        eng.generate(np.asarray(prompts), G)  # warm every trace
        eng.reset()
        first = eng.admit_wave(prompts, list(range(B)), [G] * B)
        ts, vs = [], []
        t0 = time.perf_counter()
        while True:
            t, v, fin, _ = eng.harvest(*eng.decode_chunk())
            ts.append(t[:, :B])
            vs.append(v[:, :B])
            if fin[:B].all():
                break
        dt = time.perf_counter() - t0
        t, v = np.concatenate(ts, 0), np.concatenate(vs, 0)
        toks = np.stack([np.concatenate([[first[b]], t[v[:, b], b]])
                         for b in range(B)])
        # mean accepted length: tokens per (slot, macro step) minus the
        # always-emitted bonus/correction token, over live macro steps
        acc = None
        if k:
            per = v.reshape(v.shape[0] // (k + 1), k + 1, B).sum(axis=1)
            acc = float((per[per > 0] - 1).mean())
        return toks, B * (G - 1) / dt, acc

    ref, tps_t, _ = stream_wave(0, None)
    by_k = {}
    for k in (2, 3, 4):
        toks, tps, acc = stream_wave(k, drafter)
        assert (toks == ref).all(), \
            f"spec decode k={k} diverged from target-only greedy decode"
        by_k[k] = {"tok_per_s": tps, "mean_accepted": acc}
    best = max(by_k, key=lambda k: by_k[k]["tok_per_s"])
    return {"target_stream_tok_per_s": tps_t, "by_k": by_k,
            "best_k": best,
            "spec_stream_tok_per_s": by_k[best]["tok_per_s"],
            "mean_accepted": by_k[best]["mean_accepted"],
            "speedup": by_k[best]["tok_per_s"] / tps_t,
            "greedy_match": True,
            "beats_target_only": bool(by_k[best]["tok_per_s"] > tps_t)}


def chunked_section():
    """Section 11: chunked prefill vs waved admission — tail TTFT.

    Both engines get the identical EngineConfig apart from
    ``chunked_prefill``, including ONE prefill bucket — a small
    compiled-program surface is the operating point this PR targets (a
    finer ladder is exactly the per-shape prefill zoo the unified step
    program deletes), and it is what the waved fallback pads to. The
    workload is mixed long-tail prompts (13%..98% of the bucket) at 16x
    more requests than slots with an equal decode budget.

    TTFT is the ISSUE's definition on BOTH paths: admission of the
    request's first chunk to its first emitted token (wave formation
    counts as first-chunk admission on the waved path) — slot-capacity
    queueing is identical by construction and factored out. The gate
    measures it in the deterministic unit both engines share: FORWARD
    ROWS the engine computed between a request's admission and its first
    token (``Completion.ttft_rows``, counted from the executed schedules
    — the waved path charges every wave member its wave's full
    bucket-padded prefill; the chunked path charges the unified steps
    through the first-token row at their traced width). The throughput
    leg gates on rows per emitted token (``Scheduler.rows_computed`` /
    tokens — padding waste restated), chunked <= waved. Same precedent
    as sections 3/6/8: on serving hardware decode steps are
    weight-bound, so lane rows ride the step's weight pass ~free and
    rows ARE time; XLA-CPU inverts that regime (its per-step fixed cost
    makes every lane row ~linear wall cost while the batched padded
    prefill is its most efficient program), so CPU wall clocks — also
    measured and reported below, best of N_RUNS post-warm runs — show
    the plumbing, not the claim. Greedy tokens must still match
    bit-exactly per request on this host: chunking is a pure scheduling
    change, and that assert is wall-clock-independent.
    """
    from repro.configs import get_config
    from repro.models.model import Model

    cfg11 = get_config("llama1-7b").reduced(
        d_model=256, d_ff=1024, num_layers=2, num_heads=4, num_kv_heads=4,
        head_dim=64, vocab_size=512)
    model = Model(cfg11)
    params = model.init(jax.random.PRNGKey(11))

    n_slots, n_req, gen = 4, 64, 14
    bucket = 256  # every waved prefill pads to this; chunks never pad
    rng = np.random.default_rng(23)
    reqs = [Request(i,
                    rng.integers(0, cfg11.vocab_size,
                                 int(rng.integers(33, bucket - 5)),
                                 ).astype(np.int32),
                    gen)
            for i in range(n_req)]
    N_RUNS = 2

    def drive(chunked):
        eng = Engine(model, params, EngineConfig(
            n_slots=n_slots, max_len=bucket + gen, chunk=4,
            prefill_buckets=(bucket,), paged=True, page_size=8,
            chunked_prefill=chunked, chunk_size=48))
        assert eng.chunked_prefill == chunked
        best = {"ttft_p95_s": float("inf"), "ttft_p50_s": float("inf"),
                "tok_per_s": 0.0}
        stats = toks = None
        for it in range(N_RUNS + 1):  # run 0 compiles; stats from the rest
            sched = Scheduler(eng)
            t0 = time.perf_counter()
            comps = sched.run(
                [Request(r.rid, r.tokens.copy(), r.max_new) for r in reqs])
            wall = time.perf_counter() - t0
            toks = {c.rid: c.tokens.tolist() for c in comps}
            n_tok = sum(len(c.tokens) for c in comps)
            # row accounting is schedule-determined — identical every run
            stats = {"ttft_p95_rows": _pct([c.ttft_rows for c in comps], .95),
                     "rows_per_tok": sched.rows_computed / n_tok}
            if it == 0:
                continue
            ttfts = [c.ttft_s - c.admit_s for c in comps]
            best["ttft_p95_s"] = min(best["ttft_p95_s"], _pct(ttfts, .95))
            best["ttft_p50_s"] = min(best["ttft_p50_s"], _pct(ttfts, .5))
            best["tok_per_s"] = max(best["tok_per_s"], n_tok / wall)
        return toks, dict(best, **stats)

    toks_w, w = drive(False)
    toks_c, c = drive(True)
    assert toks_w.keys() == toks_c.keys() == set(range(n_req))
    assert toks_w == toks_c, \
        "chunked prefill diverged from the waved baseline"
    ratio = c["ttft_p95_rows"] / w["ttft_p95_rows"]
    return {"waved_ttft_p95_rows": w["ttft_p95_rows"],
            "chunked_ttft_p95_rows": c["ttft_p95_rows"],
            "ttft_p95_ratio": ratio,
            "waved_rows_per_tok": w["rows_per_tok"],
            "chunked_rows_per_tok": c["rows_per_tok"],
            "waved_ttft_p50_s": w["ttft_p50_s"],
            "waved_ttft_p95_s": w["ttft_p95_s"],
            "chunked_ttft_p50_s": c["ttft_p50_s"],
            "chunked_ttft_p95_s": c["ttft_p95_s"],
            "waved_stream_tok_per_s": w["tok_per_s"],
            "chunked_stream_tok_per_s": c["tok_per_s"],
            "greedy_match": True,
            "beats_waved_ttft": bool(
                ratio < 0.5 and
                c["rows_per_tok"] <= w["rows_per_tok"])}


def mesh_section():
    """Spawn the forced-host 4x2 mesh worker and parse its JSON line (the
    parent benchmark process must keep its single CPU device, exactly like
    tests/test_distributed.py's subprocess pattern)."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.path.join(root, "src") + os.pathsep + root
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.table9_serving", "--mesh-worker"],
        capture_output=True, text=True, env=env, cwd=root, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def run(model=None, params=None):
    if model is None:
        model, params = trained_params()
    cfg = model.cfg
    rows, rec = [], {"table": "table9", "batch": BATCH, "prompt": PROMPT,
                    "gen": GEN, "arch": cfg.name}
    prompts = jnp.asarray(
        calibration_batch(cfg.vocab_size, BATCH, PROMPT, seed=7))
    n_decode_tok = BATCH * (GEN - 1)

    # 1+2: per-token loop vs jitted-scan engine ------------------------------
    loop_toks, loop_dt = seed_loop_decode(model, params, prompts, GEN)
    eng_toks, eng_dt = engine_decode(model, params, prompts, GEN)
    assert (loop_toks == eng_toks).all(), "engine diverged from the seed loop"
    loop_tps = n_decode_tok / loop_dt
    eng_tps = n_decode_tok / eng_dt
    speedup = eng_tps / loop_tps
    rows.append(("table9/loop_decode_tok_per_s", round(loop_dt / n_decode_tok * 1e6),
                 f"{loop_tps:.0f}"))
    rows.append(("table9/engine_decode_tok_per_s", round(eng_dt / n_decode_tok * 1e6),
                 f"{eng_tps:.0f}"))
    rows.append(("table9/engine_speedup_vs_loop", 0, f"{speedup:.1f}x"))
    rec.update(loop_tok_per_s=loop_tps, engine_tok_per_s=eng_tps,
               engine_speedup=speedup)

    # 3: dense vs 2:4-pruned through the same engine -------------------------
    pruned, psec = prune_with(model, params, "wanda++", "2:4", ro_iters=1,
                              n_calib=16)
    sp = model_sparsity_report(model, pruned)
    _, pruned_dt = engine_decode(model, pruned, prompts, GEN)
    pruned_tps = n_decode_tok / pruned_dt
    rows.append(("table9/pruned_engine_tok_per_s",
                 round(pruned_dt / n_decode_tok * 1e6), f"{pruned_tps:.0f}"))
    rows.append(("table9/pruned_sparsity_mean", 0,
                 f"{np.mean(list(sp.values())):.3f}"))
    # TPU projection: decode is weight-traffic-bound; 2:4 compaction moves
    # compressed24_ratio(2) = 0.5625x the prunable-body bytes (bf16 vals +
    # packed 2-bit idx) => TPOT win. Body matches cfg.param_count()'s
    # GQA-aware attention formula and the PRUNABLE table (attn + mlp
    # matmuls; embeddings/head stay dense).
    from repro.kernels.ops import compressed24_ratio
    d, f, hd = cfg.d_model, cfg.d_ff, cfg.resolved_head_dim
    qd, kvd = cfg.num_heads * hd, cfg.num_kv_heads * hd
    attn = d * qd + 2 * d * kvd + qd * d
    mlp = (3 if cfg.act == "silu" else 2) * d * f
    body = cfg.num_layers * (attn + mlp)
    w_bytes = cfg.param_count() * 2
    w_sparse = (cfg.param_count() - body) * 2 + body * 2 * compressed24_ratio(2)
    rows.append(("table9/tpu_projected_tpot_ratio", 0,
                 f"{w_sparse / w_bytes:.3f}"))
    rec.update(pruned_tok_per_s=pruned_tps,
               sparsity=float(np.mean(list(sp.values()))),
               tpu_weight_ratio=w_sparse / w_bytes, prune_seconds=psec)

    # 4: continuous-batching request stream ----------------------------------
    eng = Engine(model, params,
                 EngineConfig(n_slots=BATCH, max_len=PROMPT + GEN,
                              chunk=8, prefill_buckets=(PROMPT // 2, PROMPT)))
    rng = np.random.default_rng(3)
    reqs = [Request(i,
                    rng.integers(0, cfg.vocab_size,
                                 int(rng.integers(PROMPT // 2, PROMPT + 1)),
                                 ).astype(np.int32),
                    int(rng.integers(GEN // 2, GEN + 1)))
            for i in range(2 * BATCH)]
    sched = Scheduler(eng)
    sched.run(reqs[:2])  # warm prefill/decode traces
    t0 = time.perf_counter()
    comps = Scheduler(eng).run(reqs)
    wall = time.perf_counter() - t0
    n_tok = sum(len(c.tokens) for c in comps)
    ttfts = [c.ttft_s for c in comps]
    tpots = [t for c in comps for t in c.tpot_s]
    rows.append(("table9/stream_req_per_s", 0, f"{len(comps) / wall:.1f}"))
    rows.append(("table9/stream_tok_per_s", 0, f"{n_tok / wall:.0f}"))
    rows.append(("table9/stream_ttft_p50_ms", 0, f"{_pct(ttfts, .5) * 1e3:.0f}"))
    rows.append(("table9/stream_ttft_p95_ms", 0, f"{_pct(ttfts, .95) * 1e3:.0f}"))
    rows.append(("table9/stream_tpot_p50_ms", 0, f"{_pct(tpots, .5) * 1e3:.1f}"))
    rows.append(("table9/stream_tpot_p95_ms", 0, f"{_pct(tpots, .95) * 1e3:.1f}"))
    rec.update(req_per_s=len(comps) / wall, stream_tok_per_s=n_tok / wall,
               ttft_p50_s=_pct(ttfts, .5), ttft_p95_s=_pct(ttfts, .95),
               tpot_p50_s=_pct(tpots, .5), tpot_p95_s=_pct(tpots, .95))

    # 5: paged pool — concurrency + bytes/slot at EQUAL KV HBM ---------------
    ps = 8
    max_len = PROMPT + GEN
    plen_s, gen_s = PROMPT // 2, GEN // 2  # typical request: ~half the cap

    def kv_stream(paged, n_slots, n_pages=None, prefix=None, seed=5):
        eng = Engine(model, params, EngineConfig(
            n_slots=n_slots, max_len=max_len, chunk=8,
            prefill_buckets=(plen_s, PROMPT), paged=paged, page_size=ps,
            n_pages=n_pages))
        if prefix is not None:
            eng.register_prefix(prefix)
        rng = np.random.default_rng(seed)
        reqs = []
        for i in range(4 * BATCH):
            body = rng.integers(0, cfg.vocab_size, plen_s).astype(np.int32)
            toks = body if prefix is None else np.concatenate([prefix, body])
            reqs.append(Request(i, toks, gen_s))
        Scheduler(eng).run(reqs[:2])  # warm the prefill/decode traces
        sched = Scheduler(eng)
        t0 = time.perf_counter()
        comps = sched.run(reqs)
        wall = time.perf_counter() - t0
        kv_bytes = sum(int(np.prod(x.shape)) * x.dtype.itemsize
                       for x in jax.tree_util.tree_leaves(eng.cache))
        n_tok = sum(len(c.tokens) for c in comps)
        return {"tok_per_s": n_tok / wall, "peak_slots": sched.peak_live,
                "kv_bytes": kv_bytes,
                "bytes_per_slot": kv_bytes / max(sched.peak_live, 1),
                "shared_tokens_saved": eng.stats["shared_tokens_saved"]}

    # dense baseline: BATCH slots x max_len; paged gets the SAME arena bytes
    # (BATCH * max_len tokens worth of pages) but can pack ~2x the requests
    # because a request only holds ceil(total/ps) pages, not a max_len row
    equal_pages = BATCH * max_len // ps
    d = kv_stream(False, BATCH)
    p = kv_stream(True, 2 * BATCH, n_pages=equal_pages)
    assert p["kv_bytes"] == d["kv_bytes"], "not an equal-HBM comparison"
    slots_ratio = p["peak_slots"] / d["peak_slots"]
    rows.append(("table9/dense_pool_bytes_per_slot", 0,
                 f"{d['bytes_per_slot'] / 1e3:.0f}KB"))
    rows.append(("table9/paged_pool_bytes_per_slot", 0,
                 f"{p['bytes_per_slot'] / 1e3:.0f}KB"))
    rows.append(("table9/paged_slots_at_equal_hbm", 0,
                 f"{p['peak_slots']} vs {d['peak_slots']} ({slots_ratio:.1f}x)"))
    rows.append(("table9/paged_stream_tok_per_s", 0, f"{p['tok_per_s']:.0f}"))
    prefix = np.asarray(calibration_batch(cfg.vocab_size, 1, 2 * ps,
                                          seed=11))[0]
    s = kv_stream(True, 2 * BATCH, n_pages=equal_pages, prefix=prefix)
    rows.append(("table9/shared_prefix_tokens_skipped", 0,
                 f"{s['shared_tokens_saved']}"))
    rec.update(dense_bytes_per_slot=d["bytes_per_slot"],
               paged_bytes_per_slot=p["bytes_per_slot"],
               dense_concurrent_slots=d["peak_slots"],
               paged_concurrent_slots=p["peak_slots"],
               paged_slots_ratio=slots_ratio,
               paged_stream_tok_per_s=p["tok_per_s"],
               shared_prefix_tokens_skipped=s["shared_tokens_saved"])

    # 6: paged-attention kernel vs gather decode -----------------------------
    ps6 = 8
    max_len6 = PROMPT + GEN
    mk6 = lambda kernel: Engine(model, params, EngineConfig(
        n_slots=BATCH, max_len=max_len6, chunk=4,
        prefill_buckets=(max(PROMPT // 4, 1), PROMPT // 2, PROMPT),
        paged=True, page_size=ps6, paged_kernel=kernel))
    eng_k, eng_g = mk6(True), mk6(False)
    MB6 = eng_k.cfg.max_blocks
    kv_itemsize = jax.tree_util.tree_leaves(eng_k.cache)[0].dtype.itemsize
    page_bytes = (ps6 * cfg.num_kv_heads * cfg.resolved_head_dim
                  * kv_itemsize * 2 * cfg.num_layers)  # K+V, all layers
    def admit_at_occupancy(eng, frac):
        """Fill every slot so the pool holds ~frac of its pages; returns
        per-step KV bytes the kernel actually walks (ceil((pos+1)/ps) pages
        per slot — cached tokens, NOT the max_blocks*page_size ceiling).
        Seeded per occupancy so the kernel and gather engines see
        IDENTICAL prompts (their decodes are asserted token-equal)."""
        eng.reset()
        rng6 = np.random.default_rng(19 + int(frac * 100))
        total = max(int(frac * max_len6), ps6)
        plen = max(total - 4, 1)
        prompts = [rng6.integers(0, cfg.vocab_size, plen).astype(np.int32)
                   for _ in range(BATCH)]
        eng.admit_wave(prompts, list(range(BATCH)), [5] * BATCH)
        pos = np.asarray(eng.state.pos)
        return int(np.ceil((pos + 1) / ps6).sum()) * page_bytes

    occ_bytes = {int(f * 100): admit_at_occupancy(eng_k, f)
                 for f in (0.25, 0.5, 1.0)}
    gather_bytes = BATCH * MB6 * page_bytes
    for occ, kb in occ_bytes.items():
        rows.append((f"table9/paged_attn_step_kv_bytes_{occ}pct", 0,
                     f"{kb / 1e3:.0f}KB (gather {gather_bytes / 1e3:.0f}KB)"))
        rec[f"paged_attn_step_kv_bytes_{occ}"] = kb
    rec["gather_step_kv_bytes"] = gather_bytes

    def time_decode(eng):
        admit_at_occupancy(eng, 0.5)
        _ = eng.harvest(*eng.decode_chunk(4))  # warm the trace
        admit_at_occupancy(eng, 0.5)
        t0 = time.perf_counter()
        toks, valid = eng.decode_chunk(4)
        t, _, _, _ = eng.harvest(toks, valid)
        return t, time.perf_counter() - t0

    toks_k, dt_k = time_decode(eng_k)
    toks_g, dt_g = time_decode(eng_g)
    assert (toks_k == toks_g).all(), "kernel decode diverged from gather"
    tps_k = BATCH * 4 / dt_k
    tps_g = BATCH * 4 / dt_g
    rows.append(("table9/paged_attn_kernel_tok_per_s", 0, f"{tps_k:.0f}"))
    rows.append(("table9/paged_attn_gather_tok_per_s", 0, f"{tps_g:.0f}"))
    rec.update(paged_attn_tok_per_s=tps_k, gather_decode_tok_per_s=tps_g)

    # 7: family matrix — SSM / hybrid / VLM through the same engine ----------
    rec["family_serving"] = {}
    for arch in ("mamba2-1.3b", "zamba2-7b", "qwen2-vl-2b"):
        fam = family_stream(arch)
        rows.append((f"table9/{fam['family']}_stream_tok_per_s", 0,
                     f"{fam['tok_per_s']:.0f}"))
        rows.append((f"table9/{fam['family']}_state_bytes_per_slot", 0,
                     f"{fam['state_bytes_per_slot'] / 1e3:.0f}KB"))
        rec["family_serving"][arch] = fam

    # 8: mesh-sharded decode — forced-host 4x2 CPU mesh (subprocess) ---------
    m8 = mesh_section()
    assert m8["greedy_match"], "sharded decode diverged from single-device"
    kv_ratio = m8["kv_bytes_per_device_sharded"] / m8["kv_bytes_per_device_single"]
    rows.append(("table9/mesh_sharded_tok_per_s", 0,
                 f"{m8['sharded_tok_per_s']:.0f} (1-dev "
                 f"{m8['single_tok_per_s']:.0f}; 4x2 CPU mesh measures "
                 "plumbing, not speed)"))
    rows.append(("table9/mesh_kv_bytes_per_device", 0,
                 f"{m8['kv_bytes_per_device_sharded'] / 1e3:.0f}KB vs "
                 f"{m8['kv_bytes_per_device_single'] / 1e3:.0f}KB "
                 f"({kv_ratio:.2f}x)"))
    rec["mesh_serving"] = m8

    # 9: compressed 2:4 decode vs masked-dense reference ---------------------
    c9 = compressed_section()
    assert c9["greedy_match"]
    rows.append(("table9/compressed24_tok_per_s", 0,
                 f"{c9['compressed_tok_per_s']:.0f} (masked "
                 f"{c9['masked_tok_per_s']:.0f}, dense "
                 f"{c9['dense_tok_per_s']:.0f})"))
    rows.append(("table9/compressed24_weight_ratio", 0,
                 f"{c9['packed_ratio_f32']:.5f} f32 measured "
                 f"({c9['packed_ratio_bf16']:.4f} bf16 projected)"))
    rows.append(("table9/compressed24_beats_masked_dense", 0,
                 str(c9["beats_masked"])))
    rec["compressed24_serving"] = c9

    # 10: self-speculative decoding with the section-3 2:4 drafter --------
    s10 = spec_section(model, params, pruned)
    assert s10["greedy_match"]
    accs = ", ".join(f"k={k}: {v['mean_accepted']:.2f}"
                     for k, v in sorted(s10["by_k"].items()))
    rows.append(("table9/spec_decode_stream_tok_per_s", 0,
                 f"{s10['spec_stream_tok_per_s']:.0f} (target-only "
                 f"{s10['target_stream_tok_per_s']:.0f}, "
                 f"{s10['speedup']:.1f}x, draft_k={s10['best_k']})"))
    rows.append(("table9/spec_decode_mean_accepted", 0, accs))
    rows.append(("table9/spec_decode_beats_target_only", 0,
                 str(s10["beats_target_only"])))
    rec["spec_serving"] = s10

    # 11: chunked prefill vs waved admission — tail TTFT ------------------
    c11 = chunked_section()
    assert c11["greedy_match"]
    rows.append(("table9/chunked_ttft_p95_rows", 0,
                 f"{c11['chunked_ttft_p95_rows']:.0f} (waved "
                 f"{c11['waved_ttft_p95_rows']:.0f}, "
                 f"{c11['ttft_p95_ratio']:.2f}x)"))
    rows.append(("table9/chunked_rows_per_tok", 0,
                 f"{c11['chunked_rows_per_tok']:.1f} (waved "
                 f"{c11['waved_rows_per_tok']:.1f})"))
    rows.append(("table9/chunked_ttft_p95_ms", 0,
                 f"{c11['chunked_ttft_p95_s'] * 1e3:.0f} (waved "
                 f"{c11['waved_ttft_p95_s'] * 1e3:.0f}; CPU wall, "
                 "reported not gated)"))
    rows.append(("table9/chunked_stream_tok_per_s", 0,
                 f"{c11['chunked_stream_tok_per_s']:.0f} (waved "
                 f"{c11['waved_stream_tok_per_s']:.0f}; CPU wall, "
                 "reported not gated)"))
    rows.append(("table9/chunked_prefill_ttft", 0,
                 str(c11["beats_waved_ttft"])))
    rec["chunked_serving"] = c11

    emit(rows)
    try:
        os.makedirs(os.path.dirname(os.path.abspath(OUT_JSONL)), exist_ok=True)
        with open(OUT_JSONL, "w") as f:
            f.write(json.dumps(rec) + "\n")
    except OSError:
        pass
    return {"speedup": speedup, "paged_slots_ratio": slots_ratio,
            "paged_attn_bytes": occ_bytes, "gather_bytes": gather_bytes,
            "mesh_kv_ratio": kv_ratio, "compressed24": c9, "spec": s10,
            "chunked": c11, "rows": rows, "record": rec}


if __name__ == "__main__":
    if "--mesh-worker" in sys.argv:
        mesh_worker()
    else:
        run()
