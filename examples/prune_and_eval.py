"""Compare every pruning method on a trained LM (mini Table 1).

    PYTHONPATH=src python examples/prune_and_eval.py [--arch llama1-7b]
                                                     [--pattern 2:4]
"""
import argparse

from benchmarks.common import perplexity, prune_with, trained_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pattern", default="2:4")
    ap.add_argument("--sparsity", type=float, default=0.5)
    args = ap.parse_args()

    model, params = trained_params()
    print(f"dense ppl: {perplexity(model, params):.3f}")
    for method in ("magnitude", "wanda", "sparsegpt", "gblm",
                   "wanda++rgs", "wanda++ro", "wanda++"):
        pruned, secs = prune_with(model, params, method, args.pattern,
                                  args.sparsity)
        print(f"{method:12s} ppl={perplexity(model, pruned):8.3f} "
              f"({secs:.1f}s)")


if __name__ == "__main__":
    main()
