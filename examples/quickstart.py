"""Quickstart: prune a model with Wanda++ in ~20 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs import get_config
from repro.configs.base import PruneConfig
from repro.core.pruner import model_sparsity_report, prune_model
from repro.data import calibration_batch, eval_batch
from repro.models.model import Model

# any of the 10 assigned archs (+ llama1-7b) works here; reduced() gives a
# laptop-size config with the same code paths
cfg = get_config("llama1-7b").reduced()
model = Model(cfg)
params = model.init(jax.random.PRNGKey(0))

# the paper's recipe: RGS scoring + Regional Optimization, 2:4 sparsity
pcfg = PruneConfig(method="wanda++", pattern="2:4", n_calib=16, calib_len=64,
                   ro_iters=2, ro_samples=8)
calib = calibration_batch(cfg.vocab_size, pcfg.n_calib, pcfg.calib_len)
pruned, reports = prune_model(model, params, calib, pcfg)

ev = eval_batch(cfg.vocab_size, 16, 64)
print("dense  loss:", float(model.loss(params, ev)[0]))
print("pruned loss:", float(model.loss(pruned, ev)[0]))
print("sparsity:", model_sparsity_report(model, pruned))
