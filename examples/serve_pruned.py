"""Serve a Wanda++-pruned model with batched requests + the 2:4 kernel path.

    PYTHONPATH=src python examples/serve_pruned.py [--arch qwen3-8b]

Runs the serving launcher (prefill + greedy decode with KV cache) on a
pruned reduced config, then demonstrates the Pallas 2:4 compacted-weight
path on one of the pruned matrices: identical outputs, ~0.56x weight bytes.
"""
import argparse

import jax
import jax.numpy as jnp

from repro.launch.serve import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    args = ap.parse_args()

    # batched serving of the pruned model
    serve(args.arch, batch=4, prompt_len=32, gen=12, smoke=True, pruned="2:4")

    # kernel path: compact a 2:4 weight and compare against dense matmul
    from repro.core.masks import nm_mask
    from repro.kernels import ops
    w = jax.random.normal(jax.random.PRNGKey(0), (512, 256))
    mask = nm_mask(jnp.abs(w.T), 2, 4).T
    ws = jnp.where(mask, w, 0)
    vals, idx = ops.compact24(ws)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 512))
    y_sparse = ops.sparse_matmul24(x, vals, idx)
    y_dense = x @ ws
    err = float(jnp.abs(y_sparse - y_dense).max())
    dense_bytes = ws.size * 2
    sparse_bytes = vals.size * 2 + idx.size
    assert sparse_bytes / dense_bytes == ops.compressed24_ratio(2)
    print(f"[kernel] 2:4 compacted matmul max err vs dense: {err:.2e}")
    print(f"[kernel] weight bytes: {sparse_bytes / dense_bytes:.3f}x of dense "
          f"(bf16 vals + packed 2-bit idx)")


if __name__ == "__main__":
    main()
