"""End-to-end driver: train -> Wanda++ prune -> sparsity-aware fine-tune.

    PYTHONPATH=src python examples/train_prune_finetune.py \
        [--train-steps 300] [--ft-steps 150] [--ckpt-dir /tmp/e2e]

Demonstrates the full production lifecycle on one box:
  1. pretrain an LM on the synthetic stream (checkpointed, resumable)
  2. prune with Wanda++ (2:4)
  3. recover quality two ways, as in paper Sec 5.6:
     a. LoRA adapters (base weights frozen => sparsity preserved)
     b. masked full fine-tuning (grad_mask keeps the 2:4 pattern exact)
  4. verify the 2:4 pattern survived and perplexity recovered

Scale knobs: --d-model/--layers go up to real sizes under a mesh; on this
CPU container the defaults stay laptop-sized.
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import PruneConfig, TrainConfig
from repro.core.lora import add_lora, lora_trainable
from repro.core.pruner import model_sparsity_report, prune_model
from repro.data import calibration_batch, eval_batch, synthetic_lm_stream
from repro.launch.steps import init_train_state, make_train_step
from repro.launch.train import train_loop
from repro.models.model import Model


def ppl(model, params, seed=0):
    ev = eval_batch(model.cfg.vocab_size, 16, 64, seed=seed)
    return float(jnp.exp(model.loss(params, ev)[0]))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--train-steps", type=int, default=300)
    ap.add_argument("--ft-steps", type=int, default=100)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    # 1. pretrain (fault-tolerant loop from the production launcher)
    state, losses = train_loop(
        "llama1-7b", args.train_steps, ckpt_dir=args.ckpt_dir, smoke=True,
        batch=16, seq_len=64,
        tc=TrainConfig(learning_rate=1e-3, total_steps=args.train_steps,
                       warmup_steps=30, weight_decay=0.01))
    model = Model(get_config("llama1-7b").reduced())
    params = state["params"]
    print(f"[e2e] trained: ppl={ppl(model, params):.3f}")

    # 2. prune with Wanda++
    pcfg = PruneConfig(method="wanda++", pattern="2:4", n_calib=32,
                       calib_len=64, ro_iters=3, ro_samples=8)
    calib = calibration_batch(model.cfg.vocab_size, pcfg.n_calib, pcfg.calib_len)
    pruned, _ = prune_model(model, params, calib, pcfg)
    print(f"[e2e] pruned (wanda++ 2:4): ppl={ppl(model, pruned):.3f}")

    # 3a. LoRA recovery (paper Sec 5.6 setting: q,v adapters)
    lp = add_lora(pruned, jax.random.PRNGKey(7), rank=8)
    tc = TrainConfig(learning_rate=5e-4, total_steps=args.ft_steps,
                     warmup_steps=10, weight_decay=0.0)
    step = jax.jit(make_train_step(model, tc, trainable=lora_trainable(lp)))
    st = init_train_state(model, lp, tc)
    for i, d in zip(range(args.ft_steps),
                    synthetic_lm_stream(model.cfg.vocab_size, 16, 64, seed=0, start_step=50_000)):
        st, m = step(st, {"tokens": d["tokens"], "labels": d["labels"]})
    print(f"[e2e] + LoRA: ppl={ppl(model, st['params']):.3f}")

    # 3b. masked full fine-tune (sparsity-preserving)
    grad_mask = jax.tree_util.tree_map(lambda p: (p != 0), pruned)
    step2 = jax.jit(make_train_step(model, tc, grad_mask=grad_mask))
    st2 = init_train_state(model, pruned, tc)
    for i, d in zip(range(args.ft_steps),
                    synthetic_lm_stream(model.cfg.vocab_size, 16, 64, seed=0, start_step=60_000)):
        st2, m = step2(st2, {"tokens": d["tokens"], "labels": d["labels"]})
    print(f"[e2e] + masked-FT: ppl={ppl(model, st2['params']):.3f}")

    # 4. the 2:4 pattern must have survived masked FT exactly
    rep = model_sparsity_report(model, st2["params"])
    assert all(abs(v - 0.5) < 1e-6 for v in rep.values()), rep
    print("[e2e] 2:4 sparsity preserved through fine-tuning:", rep)


if __name__ == "__main__":
    main()
