"""Render dry-run JSONL records into the EXPERIMENTS.md roofline tables.

    PYTHONPATH=src python results/report.py results/dryrun_v2.jsonl [--mesh 16x16]
    PYTHONPATH=src python results/report.py results/table9_serving.jsonl --serving
    PYTHONPATH=src python results/report.py results/table10_scores.jsonl --scores
"""
import json
import sys


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}us"


def load(path):
    recs = {}
    for line in open(path):
        r = json.loads(line)
        recs[(r["arch"], r["shape"], r["mesh"])] = r
    return recs


def table(recs, mesh="16x16"):
    rows = []
    header = ("| arch | shape | status | peak HBM/chip | compute | memory | "
              "collective | bottleneck | MODEL/HLO flops | roofline frac |")
    rows.append(header)
    rows.append("|" + "---|" * 10)
    for (a, s, m), r in sorted(recs.items()):
        if m != mesh:
            continue
        st = r["status"]
        if st != "OK":
            rows.append(f"| {a} | {s} | {st.split(':')[0]} | - | - | - | - | - | - | - |")
            continue
        mem = r.get("memory", {}).get("peak_bytes") or 0
        rf = r.get("roofline", {})
        fit = "" if mem <= 16e9 else " ⚠"
        rows.append(
            f"| {a} | {s} | OK ({r.get('lower_compile_s', '?')}s) | "
            f"{mem / 1e9:.1f}GB{fit} | {fmt_s(rf.get('compute_s'))} | "
            f"{fmt_s(rf.get('memory_s'))} | {fmt_s(rf.get('collective_s'))} | "
            f"{rf.get('bottleneck', '-').replace('_s', '')} | "
            f"{rf.get('useful_flop_frac', 0):.2f} | "
            f"{rf.get('roofline_frac', 0) * 100:.1f}% |")
    return "\n".join(rows)


def serving_table(path):
    """Markdown table for benchmarks/table9_serving.py JSONL records."""
    rows = ["| arch | batch | loop tok/s | engine tok/s | speedup | "
            "pruned tok/s | 2:4 weight ratio | req/s | TTFT p50/p95 | "
            "TPOT p50/p95 | paged slots (equal HBM) | KV bytes/slot | "
            "prefix tokens skipped | KV B/step kernel@25/50/100% vs gather | "
            "family matrix (tok/s @ state KB/slot) | "
            "mesh KV B/device (4x2) | "
            "2:4 compressed tok/s (vs masked) | "
            "spec decode tok/s (vs target-only, accepted/k) | "
            "chunked TTFT p95 (vs waved) |",
            "|" + "---|" * 19]
    for line in open(path):
        r = json.loads(line)
        if "paged_concurrent_slots" in r:
            paged = (f"{r['paged_concurrent_slots']} vs "
                     f"{r['dense_concurrent_slots']} "
                     f"({r['paged_slots_ratio']:.1f}x)")
            bps = (f"{r['dense_bytes_per_slot'] / 1e3:.0f}KB → "
                   f"{r['paged_bytes_per_slot'] / 1e3:.0f}KB")
            skipped = str(r.get("shared_prefix_tokens_skipped", 0))
        else:
            paged = bps = skipped = "-"
        if "gather_step_kv_bytes" in r:
            # the paged-attention claim: per-step KV traffic follows the
            # cached tokens (25 < 50 < 100%), not the gather's fixed ceiling
            kb = "/".join(f"{r[f'paged_attn_step_kv_bytes_{o}'] / 1e3:.0f}"
                          for o in (25, 50, 100))
            attn = f"{kb}KB vs {r['gather_step_kv_bytes'] / 1e3:.0f}KB"
        else:
            attn = "-"
        if r.get("family_serving"):
            # SSM/hybrid/VLM through the same engine: tokens/s at the
            # CacheSpec's decode-state footprint per slot
            fam = ", ".join(
                f"{f['family']} {f['tok_per_s']:.0f}@"
                f"{f['state_bytes_per_slot'] / 1e3:.0f}KB"
                for f in r["family_serving"].values())
        else:
            fam = "-"
        if r.get("mesh_serving"):
            # tensor-parallel serving: each device of the model axis holds
            # 1/TP of the KV arena (the per-chip-HBM claim; CPU tok/s only
            # measures plumbing overhead)
            m = r["mesh_serving"]
            mesh = (f"{m['kv_bytes_per_device_sharded'] / 1e3:.0f}KB vs "
                    f"{m['kv_bytes_per_device_single'] / 1e3:.0f}KB")
        else:
            mesh = "-"
        if r.get("compressed24_serving"):
            # 2:4 packed (vals + 2-bit idx) decode vs the masked-dense
            # reference: same greedy tokens, fewer weight bytes per step
            c = r["compressed24_serving"]
            c24 = (f"{c['compressed_tok_per_s']:.0f} vs "
                   f"{c['masked_tok_per_s']:.0f} "
                   f"({c['compressed_tok_per_s'] / c['masked_tok_per_s']:.1f}x, "
                   f"{c['n_proj']} proj @ {c['packed_ratio_bf16']:.4f}x bf16)")
        else:
            c24 = "-"
        if r.get("spec_serving"):
            # self-speculation: the pruned artifact drafts, the target
            # verifies; streaming tok/s at bit-exact greedy output, with
            # the accept rate that carries the win
            s = r["spec_serving"]
            spec = (f"{s['spec_stream_tok_per_s']:.0f} vs "
                    f"{s['target_stream_tok_per_s']:.0f} "
                    f"({s['speedup']:.1f}x, "
                    f"{s['mean_accepted']:.2f}/{s['best_k']} accepted)")
        else:
            spec = "-"
        if r.get("chunked_serving"):
            # chunked prefill: the prompt rides the decode scan's chunk
            # lane, so admission never pauses decode — the TTFT tail is
            # the claim, in executed forward rows (deterministic; CPU
            # wall inverts the weight-bound regime and does not gate)
            ck = r["chunked_serving"]
            chunked = (f"{ck['chunked_ttft_p95_rows']:.0f} vs "
                       f"{ck['waved_ttft_p95_rows']:.0f} rows "
                       f"({ck['ttft_p95_ratio']:.2f}x, "
                       f"{ck['chunked_rows_per_tok']:.1f} vs "
                       f"{ck['waved_rows_per_tok']:.1f} rows/tok)")
        else:
            chunked = "-"
        rows.append(
            f"| {r['arch']} | {r['batch']} | {r['loop_tok_per_s']:.0f} | "
            f"{r['engine_tok_per_s']:.0f} | {r['engine_speedup']:.1f}x | "
            f"{r['pruned_tok_per_s']:.0f} | {r['tpu_weight_ratio']:.3f} | "
            f"{r['req_per_s']:.1f} | "
            f"{fmt_s(r['ttft_p50_s'])}/{fmt_s(r['ttft_p95_s'])} | "
            f"{fmt_s(r['tpot_p50_s'])}/{fmt_s(r['tpot_p95_s'])} | "
            f"{paged} | {bps} | {skipped} | {attn} | {fam} | {mesh} | "
            f"{c24} | {spec} | {chunked} |")
    return "\n".join(rows)


def scores_table(path):
    """Markdown table for benchmarks/table10_scores.py JSONL records."""
    rows = []
    for line in open(path):
        r = json.loads(line)
        rows.append("| score | 2:4 ppl (standard eval) |")
        rows.append("|---|---|")
        rows.append(f"| dense | {r['dense_ppl']:.3f} |")
        for name, ppl in sorted(r["zoo"].items(), key=lambda kv: kv[1]):
            rows.append(f"| {name} | {ppl:.3f} |")
        o = r.get("online")
        if o:
            rows.append("")
            rows.append("| shifted-traffic cell | ppl |")
            rows.append("|---|---|")
            rows.append(f"| dense | {o['dense']:.3f} |")
            rows.append(f"| offline {o['method']} | {o['offline']:.3f} |")
            rows.append(f"| online {o['method']} "
                        f"({o['tokens']:.0f} live tokens) | "
                        f"{o['online']:.3f} |")
            if "offline_wanda" in o:
                rows.append(f"| offline wanda | {o['offline_wanda']:.3f} |")
                rows.append(f"| online wanda | {o['online_wanda']:.3f} |")
    return "\n".join(rows)


def summary(recs):
    n_ok = sum(1 for r in recs.values() if r["status"] == "OK")
    n_skip = sum(1 for r in recs.values() if r["status"].startswith("SKIP"))
    n_fail = len(recs) - n_ok - n_skip
    over = [(a, s, m) for (a, s, m), r in recs.items()
            if r["status"] == "OK"
            and (r.get("memory", {}).get("peak_bytes") or 0) > 16e9]
    return (f"cells={len(recs)} ok={n_ok} rule-skips={n_skip} fail={n_fail} "
            f"over-16GB={len(over)}")


if __name__ == "__main__":
    if "--serving" in sys.argv:
        print(serving_table(sys.argv[1]))
        sys.exit(0)
    if "--scores" in sys.argv:
        print(scores_table(sys.argv[1]))
        sys.exit(0)
    recs = load(sys.argv[1])
    mesh = sys.argv[3] if len(sys.argv) > 3 else "16x16"
    if "--mesh" in sys.argv:
        mesh = sys.argv[sys.argv.index("--mesh") + 1]
    print(summary(recs))
    print()
    print(table(recs, mesh))
