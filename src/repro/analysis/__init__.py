"""Static-analysis subsystem: jit-safety lint, SPMD sharding contracts,
and Pallas VMEM budget verification (``python -m repro.analysis``).

Submodules (imported lazily by the CLI — ``common``/``jitlint`` are pure
stdlib-AST, ``contracts``/``vmem`` pull in jax + the model zoo):

* :mod:`repro.analysis.jitlint` — AST lint over ``src/repro`` (host syncs
  in jitted regions, pallas_call interpret/compiler-params contracts,
  jit-without-shardings in mesh-aware modules, f32 casts in bf16 paths)
  with a checked-in suppression baseline (``baseline.txt``).
* :mod:`repro.analysis.contracts` — device-free sharding-contract matrix
  (every assigned arch x mesh geometries), runtime trace-count pins, and
  the bf16-upcast StableHLO check.
* :mod:`repro.analysis.vmem` — static per-kernel VMEM footprint model
  checked against each kernel's declared ``vmem_limit_bytes``.
"""
from repro.analysis.common import (BaselineResult, Finding, apply_baseline,
                                   load_baseline, render_findings,
                                   render_report, sort_findings,
                                   write_baseline)

__all__ = ["BaselineResult", "Finding", "apply_baseline", "load_baseline",
           "render_findings", "render_report", "sort_findings",
           "write_baseline"]
