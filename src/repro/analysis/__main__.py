"""CLI driver: ``python -m repro.analysis`` (a.k.a. ``make analyze``).

Runs the three passes and exits non-zero on any unsuppressed finding:

* ``jitlint``  — AST lint, filtered through ``baseline.txt`` (stale
  baseline entries also fail: fixed violations must leave the baseline).
* ``contracts`` — sharding-contract matrix + bf16-upcast check +
  (unless ``--no-trace``) the runtime trace-count pins. No baseline:
  a contracts finding is a real bug.
* ``vmem``     — per-kernel VMEM plans over every assigned arch's real
  shapes. No baseline either.

``--write-baseline`` regenerates ``baseline.txt`` from the current jitlint
findings (review the diff — every entry is a suppressed decision).
"""
from __future__ import annotations

import argparse
import os
import sys

from repro.analysis.common import (apply_baseline, load_baseline,
                                   render_findings, render_report,
                                   write_baseline)

PASSES = ("jitlint", "contracts", "vmem")


def default_baseline_path() -> str:
    return os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "baseline.txt")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="jit-safety lint, sharding contracts, VMEM budgets")
    ap.add_argument("--only", choices=PASSES, default=None,
                    help="run a single pass")
    ap.add_argument("--baseline", default=default_baseline_path(),
                    help="jitlint suppression baseline path")
    ap.add_argument("--write-baseline", action="store_true",
                    help="regenerate the baseline from current findings")
    ap.add_argument("--no-trace", action="store_true",
                    help="skip the runtime trace-contract cells (pure "
                    "static run)")
    args = ap.parse_args(argv)
    passes = (args.only,) if args.only else PASSES
    failed = False

    if "jitlint" in passes:
        from repro.analysis import jitlint
        findings = jitlint.lint_tree()
        if args.write_baseline:
            write_baseline(
                args.baseline, findings,
                header=("jitlint suppression baseline — reviewed, "
                        "intentional findings.\n"
                        "One entry per (rule | path | scope | snippet); "
                        "line numbers never enter the key.\n"
                        "Regenerate with: python -m repro.analysis "
                        "--only jitlint --write-baseline"))
            print(f"wrote {len({f.key for f in findings})} baseline "
                  f"entries to {args.baseline}")
            return 0
        res = apply_baseline(findings, load_baseline(args.baseline))
        print(render_report("jitlint", res))
        failed |= bool(res.unsuppressed or res.stale)

    if "contracts" in passes:
        from repro.analysis import contracts
        findings = contracts.run_all(trace=not args.no_trace)
        print(render_findings("contracts", findings))
        failed |= bool(findings)

    if "vmem" in passes:
        from repro.analysis import vmem
        findings = vmem.run_default()
        print(render_findings("vmem", findings))
        failed |= bool(findings)

    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
