"""Shared plumbing for the static-analysis passes: findings, baselines,
and report rendering.

A :class:`Finding` is one rule violation. Findings are suppressed either
inline (a ``# lint: allow(<rule>)`` comment on the offending line — for
code whose intent is best documented at the site, e.g. the engine's single
documented host sync) or via the checked-in baseline file
(``src/repro/analysis/baseline.txt``) for pre-existing, reviewed findings.

Baseline entries are keyed by ``rule | relpath | scope | snippet`` — the
enclosing function qualname plus the normalized source line — NOT by line
number, so unrelated edits shifting code do not invalidate the baseline.
One entry suppresses every finding with the same key (a repeated idiom in
one function is one decision). Staleness is enforced both ways: an
unsuppressed finding fails the run, and a baseline entry matching zero
findings fails it too (so fixed violations must leave the baseline).
"""
from __future__ import annotations

import os
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

REPO_SRC_HINT = "src"  # paths in reports are repo-relative when possible


def _norm_snippet(text: str) -> str:
    """Normalize a source line for baseline matching: collapse whitespace
    (indentation changes and reflow must not invalidate entries)."""
    return " ".join(text.split())


@dataclass(frozen=True)
class Finding:
    rule: str  # rule id, e.g. "host-sync"
    path: str  # repo-relative posix path
    line: int  # 1-indexed
    scope: str  # enclosing function qualname ("<module>" at top level)
    snippet: str  # offending source line (stripped)
    message: str  # human explanation

    @property
    def key(self) -> str:
        return " | ".join((self.rule, self.path, self.scope,
                           _norm_snippet(self.snippet)))

    def render(self) -> str:
        return (f"{self.rule:<18} {self.path}:{self.line} "
                f"[{self.scope}] {self.message}")


def rel_path(path: str, root: Optional[str] = None) -> str:
    """Repo-relative posix path for reports and baseline keys."""
    path = os.path.abspath(path)
    if root:
        try:
            path = os.path.relpath(path, root)
        except ValueError:
            pass
    return path.replace(os.sep, "/")


# ---------------------------------------------------------------------------
# baseline file: "# comment" lines pass through; entries are finding keys
# ---------------------------------------------------------------------------

def load_baseline(path: str) -> List[str]:
    if not os.path.exists(path):
        return []
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line and not line.startswith("#"):
                out.append(line)
    return out


def write_baseline(path: str, findings: Sequence[Finding],
                   header: str = "") -> None:
    keys = sorted({f.key for f in findings})
    with open(path, "w") as f:
        if header:
            for ln in header.splitlines():
                f.write(f"# {ln}\n".replace("#  ", "# "))
        for k in keys:
            f.write(k + "\n")


@dataclass
class BaselineResult:
    unsuppressed: List[Finding] = field(default_factory=list)
    suppressed: List[Finding] = field(default_factory=list)
    stale: List[str] = field(default_factory=list)  # entries matching nothing


def apply_baseline(findings: Iterable[Finding],
                   baseline: Sequence[str]) -> BaselineResult:
    res = BaselineResult()
    entries = set(baseline)
    hit: Dict[str, int] = {e: 0 for e in entries}
    for f in findings:
        if f.key in entries:
            hit[f.key] += 1
            res.suppressed.append(f)
        else:
            res.unsuppressed.append(f)
    res.stale = sorted(e for e, n in hit.items() if n == 0)
    return res


# ---------------------------------------------------------------------------
# report rendering (stable ordering — golden-comparable in tests)
# ---------------------------------------------------------------------------

def sort_findings(findings: Iterable[Finding]) -> List[Finding]:
    return sorted(findings, key=lambda f: (f.path, f.line, f.rule, f.message))


def render_report(title: str, res: BaselineResult) -> str:
    lines = [f"== {title}: {len(res.unsuppressed)} finding(s), "
             f"{len(res.suppressed)} baselined, {len(res.stale)} stale =="]
    for f in sort_findings(res.unsuppressed):
        lines.append("  " + f.render())
    for e in res.stale:
        lines.append(f"  stale-suppression  {e}  "
                     "(baseline entry matches no finding — remove it)")
    return "\n".join(lines)


def render_findings(title: str, findings: Sequence[Finding]) -> str:
    lines = [f"== {title}: {len(findings)} finding(s) =="]
    for f in sort_findings(findings):
        lines.append("  " + f.render())
    return "\n".join(lines)
