"""Abstract-interpretation contracts for the serving stack.

Three contract families, all reported as :class:`~repro.analysis.common.
Finding`s (a finding here is a real bug, so unlike jitlint there is no
baseline — the expected report is empty):

**Sharding contracts** (static, device-free). The whole
``distributed/sharding.py`` rule table is evaluated across the config
matrix — every assigned architecture x a set of mesh geometries
(:data:`GEOMETRIES`, via :class:`~repro.distributed.sharding.AxisMesh`
stand-ins, so a 1-device CPU host checks 16-chip layouts) x param/serve
state. Checked per leaf:

* *divisibility*: a dim sharded over mesh axes of total size ``s`` has
  ``dim % s == 0`` (``_spec_for`` guarantees this; the check catches any
  path that bypasses it).
* *head integrity* (the PR 5 bug class): a sharded dim whose logical name
  is a head axis (``heads``/``kv_heads``/``ssm_heads``) must also divide
  by the head COUNT — head-structured dims are flattened ``count*head_dim``
  in the param shapes, so per-dim divisibility alone happily splits
  mid-head (kv_heads=2, head_dim=16 on a 4-way model axis), which
  miscompiles downstream. ``make_rules`` degrades these; re-introducing the
  split (e.g. via overrides) must produce a finding.
* *axis reuse*: no mesh axis appears twice in one PartitionSpec.
* *golden pins*: a handful of known leaves (wq/wo/wg, embed, head) are
  pinned to their exact expected specs on a reference geometry, so a
  silently-dropped rule-table entry (everything degrades to replication —
  "valid" but wrong) still fails.
* *serve-state placement*: page arenas' page axis replicated, the page
  free-list replicated, block-table rows and slot vectors over the data
  axes exactly when ``n_slots`` divides them.

**Trace contracts** (runtime, unmeshed, reduced configs). The engine's
no-retrace / single-sync guarantee, pinned per serving cell in
:data:`TRACE_CELLS` x :data:`EXPECTED_TRACES`: one prefill trace, one
decode trace, one ``block_until_ready`` per generation, zero retraces on
the second wave. tests/test_serve.py consumes these pins — this module is
the single source of truth for the expected counts.

**bf16 upcast contract** (static, lowered StableHLO). Lower the decode
step of a bf16-parameterized model and scan the StableHLO for
``convert`` ops taking a bf16 tensor of a *param-leaf shape* (ndim >= 2,
i.e. a weight, not an activation) to f32 — an unintended upcast doubles
decode weight traffic, the very thing 2:4 serving halves.
"""
from __future__ import annotations

import re
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.common import Finding
from repro.configs import ASSIGNED_ARCHS, get_config
from repro.distributed import sharding as SHARD
from repro.distributed.sharding import AxisMesh

# ---------------------------------------------------------------------------
# mesh geometries: evaluated with AxisMesh stand-ins (no devices needed)
# ---------------------------------------------------------------------------

GEOMETRIES: Dict[str, AxisMesh] = {
    "tp2": AxisMesh(model=2),
    "tp4": AxisMesh(model=4),
    "dp2tp2": AxisMesh(data=2, model=2),
    "dp4": AxisMesh(data=4),
    "pod2dp2tp4": AxisMesh(pod=2, data=2, model=4),
}

# logical head axes -> the semantic unit count on the config. A sharded dim
# carrying one of these must divide by the COUNT, not just the flattened
# count*head_dim product ("inner" is excluded: its extra segments are
# elementwise-safe at any boundary; its head hazard is gated on ssm_nheads
# by make_rules and surfaces through "ssm_heads" leaves here).
HEAD_COUNTS = {
    "heads": lambda cfg: cfg.num_heads,
    "kv_heads": lambda cfg: cfg.num_kv_heads,
    "ssm_heads": lambda cfg: cfg.ssm_nheads,
}


def _mesh_size(mesh, entry) -> int:
    axes = entry if isinstance(entry, tuple) else (entry,)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size


def _leaf_items(tree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(SHARD._path_str(path), leaf) for path, leaf in flat]


def _zip_leaves(ref, *others) -> List[Tuple[str, Tuple[Any, ...]]]:
    """Align companion trees (logical tuples, PartitionSpecs) to ``ref``'s
    leaf positions — flatten_up_to returns sub-structures (a logical-axis
    tuple, a registered-leaf PartitionSpec) whole at each ref leaf."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(ref)
    cols = [treedef.flatten_up_to(t) for t in others]
    return [(SHARD._path_str(p), (leaf,) + tuple(c[i] for c in cols))
            for i, (p, leaf) in enumerate(flat)]


def _spec_axes(entry) -> Tuple[str, ...]:
    if entry is None:
        return ()
    return entry if isinstance(entry, tuple) else (entry,)


def _check_leaf_spec(findings: List[Finding], where: str, leaf_path: str,
                     shape, logical, spec, mesh, cfg) -> None:
    used: List[str] = []
    for d, (dim, lg, entry) in enumerate(zip(shape, tuple(logical) + (None,)
                                             * len(shape), tuple(spec)
                                             + (None,) * len(shape))):
        axes = _spec_axes(entry)
        if not axes:
            continue
        size = 1
        for a in axes:
            if a not in mesh.axis_names:
                findings.append(Finding(
                    "shard-axis", where, 0, leaf_path,
                    f"dim {d} -> {entry!r}",
                    f"spec names mesh axis {a!r} not in {mesh.axis_names}"))
                continue
            size *= mesh.shape[a]
        for a in axes:
            if a in used:
                findings.append(Finding(
                    "shard-axis-reuse", where, 0, leaf_path,
                    f"dim {d} -> {entry!r}",
                    f"mesh axis {a!r} used twice in one PartitionSpec"))
            used.append(a)
        if dim % size != 0:
            findings.append(Finding(
                "shard-divisibility", where, 0, leaf_path,
                f"dim {d}: {dim} over {entry!r}",
                f"dim {dim} not divisible by mesh extent {size}"))
        if lg in HEAD_COUNTS:
            count = HEAD_COUNTS[lg](cfg) or 0
            if count % size != 0:
                findings.append(Finding(
                    "mid-head-split", where, 0, leaf_path,
                    f"dim {d} ({lg}={count}) split {size}-way",
                    f"{lg} dim sharded {size}-way but the head count "
                    f"{count} is not divisible — this splits mid-head "
                    "(PR 5 bug class; make_rules must degrade it)"))


# ---------------------------------------------------------------------------
# param sharding contracts
# ---------------------------------------------------------------------------

def _param_shapes(cfg):
    from repro.models.model import Model
    model = Model(cfg)
    return model, jax.eval_shape(model.init, jax.random.PRNGKey(0))


def check_param_contracts(arch: str, geometry: str, kind: str = "decode",
                          overrides: Optional[Dict] = None,
                          cfg=None) -> List[Finding]:
    """Evaluate the param rule table for one (arch, mesh geometry) cell."""
    mesh = GEOMETRIES[geometry]
    cfg = cfg if cfg is not None else get_config(arch).reduced()
    _, shapes = _param_shapes(cfg)
    where = f"contracts/params/{arch}@{geometry}/{kind}"
    specs = SHARD.param_pspecs(mesh, cfg, shapes, kind, overrides)
    logical = SHARD.logical_spec_tree(shapes)
    findings: List[Finding] = []
    for leaf_path, (leaf, lg, spec) in _zip_leaves(shapes, logical, specs):
        _check_leaf_spec(findings, where, leaf_path, leaf.shape, lg, spec,
                         mesh, cfg)
    return findings


# reference geometry golden pins: qwen3-8b reduced on dp2tp2 (divisible
# everywhere), kind="decode". If the rule table silently drops an entry,
# everything still *validates* (replication is always legal) — these pins
# catch the silent degradation.
_GOLDEN_PINS = {
    # leaf-path regex -> expected PartitionSpec entries (stacked block
    # leaves carry the leading replicated "layers" dim)
    r"blocks/attn/wq/w$": (None, None, "model"),
    r"blocks/attn/wo/w$": (None, "model", None),
    r"blocks/mlp/wg/w$": (None, None, "model"),
    r"blocks/mlp/wd/w$": (None, "model", None),
    r"^embed$": ("model", None),
    r"^head$": (None, "model"),
}


def check_golden_pins(arch: str = "qwen3-8b",
                      geometry: str = "dp2tp2") -> List[Finding]:
    mesh = GEOMETRIES[geometry]
    cfg = get_config(arch).reduced()
    _, shapes = _param_shapes(cfg)
    where = f"contracts/golden/{arch}@{geometry}"
    specs = SHARD.param_pspecs(mesh, cfg, shapes, "decode")
    findings: List[Finding] = []
    seen = set()
    for leaf_path, (spec,) in _zip_leaves(specs):
        for pat, want in _GOLDEN_PINS.items():
            if re.search(pat, leaf_path):
                seen.add(pat)
                got = tuple(spec) + (None,) * (len(want) - len(tuple(spec)))
                if tuple(got) != want:
                    findings.append(Finding(
                        "golden-pin", where, 0, leaf_path,
                        f"{got!r}", f"expected spec {want!r} — a TP leaf "
                        "silently degraded to the wrong placement"))
    for pat in _GOLDEN_PINS:
        if pat not in seen:
            findings.append(Finding(
                "golden-pin", where, 0, pat, "",
                "pinned leaf not found in the param tree (path rules or "
                "model layout changed — update the pin)"))
    return findings


# ---------------------------------------------------------------------------
# serve-state placement contracts
# ---------------------------------------------------------------------------

def check_serve_contracts(arch: str, geometry: str, n_slots: int = 8,
                          paged: bool = True) -> List[Finding]:
    from repro.serve import paging

    mesh = GEOMETRIES[geometry]
    cfg = get_config(arch).reduced()
    from repro.models.model import Model
    model = Model(cfg)
    spec = model.cache_spec
    where = f"contracts/serve/{arch}@{geometry}/" \
            f"{'paged' if paged else 'pool'}"
    findings: List[Finding] = []
    if not spec.groups:
        return findings  # encoder-only: no decode state to place
    paged = paged and spec.has_kv
    if paged:
        cache = jax.eval_shape(lambda: spec.init_paged(n_slots * 4, 16,
                                                       n_slots))
        pstate = jax.eval_shape(
            lambda: paging.init_pages(n_slots * 4, n_slots, 4))
    else:
        cache = jax.eval_shape(lambda: spec.init_dense(n_slots, 32))
        pstate = None
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("ignore", RuntimeWarning)
        sh = SHARD.serve_state_pspecs(mesh, cfg, spec, cache, pstate,
                                      n_slots, paged)
    logical = spec.cache_logical(paged)
    for leaf_path, (leaf, lg, ps) in _zip_leaves(cache, logical,
                                                 sh["cache"]):
        _check_leaf_spec(findings, where, leaf_path, leaf.shape, lg, ps,
                         mesh, cfg)
        # the page axis must stay replicated: any slot's block table may
        # reference any page
        for d, name in enumerate(lg):
            if name == "pages" and tuple(ps)[d:d + 1] not in ((None,), ()):
                findings.append(Finding(
                    "serve-placement", where, 0, leaf_path,
                    f"pages dim -> {tuple(ps)[d]!r}",
                    "page arena's page axis must be replicated"))
    dp = SHARD.mesh_dp_axes(mesh)
    dsize = 1
    for a in dp:
        dsize *= mesh.shape[a]
    slots_divisible = dsize > 1 and n_slots % dsize == 0
    slot_axes = _spec_axes(tuple(sh["slots"])[0] if tuple(sh["slots"])
                           else None)
    if slots_divisible and not slot_axes:
        findings.append(Finding(
            "serve-placement", where, 0, "slots", f"{sh['slots']!r}",
            f"n_slots={n_slots} divides the data axes {dp} (size {dsize}) "
            "but the slot vector is not sharded over them"))
    if not slots_divisible and slot_axes:
        findings.append(Finding(
            "serve-placement", where, 0, "slots", f"{sh['slots']!r}",
            f"slot vector sharded but n_slots={n_slots} does not divide "
            f"the data axes {dp}"))
    if sh["pstate"] is not None:
        if tuple(sh["pstate"].ref) != ():
            findings.append(Finding(
                "serve-placement", where, 0, "pstate.ref",
                f"{sh['pstate'].ref!r}",
                "the page free-list must be fully replicated"))
        bt = tuple(sh["pstate"].block_tables)
        bt_row = _spec_axes(bt[0] if bt else None)
        if slots_divisible and not bt_row:
            findings.append(Finding(
                "serve-placement", where, 0, "pstate.block_tables",
                f"{bt!r}", "block-table rows must shard with their slots"))
    if tuple(sh["repl"]) != ():
        findings.append(Finding(
            "serve-placement", where, 0, "repl", f"{sh['repl']!r}",
            "wave inputs / PRNG key sharding must be fully replicated"))
    return findings


# ---------------------------------------------------------------------------
# static sweep driver
# ---------------------------------------------------------------------------

def run_static(archs: Optional[Sequence[str]] = None,
               geometries: Optional[Sequence[str]] = None) -> List[Finding]:
    archs = list(archs) if archs is not None else list(ASSIGNED_ARCHS)
    geometries = list(geometries) if geometries is not None \
        else list(GEOMETRIES)
    findings: List[Finding] = []
    for arch in archs:
        cfg = get_config(arch)
        for geo in geometries:
            findings.extend(check_param_contracts(arch, geo))
            if not cfg.is_encoder_only:
                findings.extend(check_serve_contracts(arch, geo))
    findings.extend(check_golden_pins())
    return findings


# ---------------------------------------------------------------------------
# runtime trace contracts (unmeshed, reduced configs) — the single source
# of truth for the engine's no-retrace / single-sync pins
# ---------------------------------------------------------------------------

# cell -> (arch, engine knobs, prune-first). "auto" on unpruned params is
# pinned as an exact no-op (trace counts identical to "off").
TRACE_CELLS: Dict[str, Dict[str, Any]] = {
    "dense-paged": dict(arch="qwen3-8b", prune=False,
                        engine=dict(paged=True, compressed24="off")),
    "dense-pool": dict(arch="qwen3-8b", prune=False,
                       engine=dict(paged=False, compressed24="off")),
    "compressed24": dict(arch="qwen3-8b", prune=True,
                         engine=dict(paged=True, compressed24="on")),
    "masked24": dict(arch="qwen3-8b", prune=True,
                     engine=dict(paged=True, compressed24="masked")),
}

# one prefill trace, ONE decode program for the whole generation, exactly
# one device sync per chunk (the workload runs one chunk), zero retraces
# on a second identical wave
EXPECTED_TRACES: Dict[str, Dict[str, int]] = {
    name: {"prefill": 1, "decode": 1, "syncs": 1, "retraces": 0}
    for name in TRACE_CELLS
}

# chunked-prefill cells: the unified step program replaces the prefill
# bucket zoo entirely — ZERO prefill traces, ONE decode trace, and the
# count stays flat across prompt lengths / fill loads (ragged and idle
# chunk lanes run the same traced shape; the schedule is data, not shape).
CHUNKED_TRACE_CELLS: Dict[str, Dict[str, Any]] = {
    "chunked-paged": dict(arch="qwen3-8b",
                          engine=dict(paged=True, compressed24="off")),
    "chunked-pool": dict(arch="qwen3-8b",
                         engine=dict(paged=False, compressed24="off")),
}

EXPECTED_CHUNKED_TRACES: Dict[str, Dict[str, int]] = {
    name: {"prefill": 0, "decode": 1, "retraces": 0}
    for name in CHUNKED_TRACE_CELLS
}


def magnitude_prune24(cfg, params):
    """Exact magnitude 2:4 pruning of every prunable projection (top-2 |w|
    per group of 4 along the input axis, index tie-break) — the cheap way
    to make ``sparsity_check24`` pass for the compressed-serving trace
    cells without running the full Wanda++ pipeline."""
    from repro.models.blocks import _tget, _tset, prunable_table

    def prune_leaf(w):
        if w.ndim < 2 or w.shape[-2] % 4:
            return w
        shape = w.shape
        g = np.abs(np.asarray(w)).reshape(
            shape[:-2] + (shape[-2] // 4, 4, shape[-1]))
        s_i = g[..., :, None, :]
        s_j = g[..., None, :, :]
        idx = np.arange(4)[:, None, None]
        jdx = np.arange(4)[None, :, None]
        rank = ((s_j > s_i) | ((s_j == s_i) & (jdx < idx))).sum(axis=-2)
        keep = (rank < 2).reshape(shape)
        return (np.asarray(w) * keep).astype(w.dtype)

    blocks = params["blocks"]
    for _, path in prunable_table(cfg).items():
        if path[-1] != "w":
            continue
        w = _tget(blocks, path)
        if w is None:
            continue
        blocks = _tset(blocks, path, jnp.asarray(prune_leaf(w)))
    out = dict(params)
    out["blocks"] = blocks
    return out


def run_trace_cell(name: str) -> Tuple[Dict[str, int], List[Finding]]:
    """Run one serving cell's workload; return (measured, findings)."""
    from repro.models.model import Model
    from repro.serve import Engine, EngineConfig

    cell = TRACE_CELLS[name]
    cfg = get_config(cell["arch"]).reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if cell["prune"]:
        params = magnitude_prune24(cfg, params)
    B, P, G = 2, 8, 6
    prompts = np.asarray(jax.random.randint(
        jax.random.PRNGKey(1), (B, P), 0, cfg.vocab_size), np.int32)
    eng = Engine(model, params,
                 EngineConfig(n_slots=B, max_len=P + G, chunk=G - 1,
                              prefill_buckets=(P,), **cell["engine"]))
    blocks = {"n": 0}
    real = jax.block_until_ready

    def counting(x):
        blocks["n"] += 1
        return real(x)

    jax.block_until_ready = counting
    try:
        eng.generate(prompts, G)
        first = dict(eng.trace_counts)
        syncs = blocks["n"]
        eng.generate(prompts, G)
    finally:
        jax.block_until_ready = real
    measured = {"prefill": first["prefill"], "decode": first["decode"],
                "syncs": syncs,
                "retraces": eng.trace_counts["decode"] - first["decode"]}
    where = f"contracts/trace/{name}"
    findings = []
    for k, want in EXPECTED_TRACES[name].items():
        if measured[k] != want:
            findings.append(Finding(
                "trace-pin", where, 0, k,
                f"{k}={measured[k]}",
                f"expected {k}={want}, measured {measured[k]} (the engine "
                "retraced or added a device sync on the hot path)"))
    if cell["prune"] and name == "compressed24" and eng.compressed24 == 0:
        findings.append(Finding(
            "trace-pin", where, 0, "compressed24", "0",
            "compressed24 cell served zero packed projections"))
    return measured, findings


def run_chunked_trace_cell(name: str) -> Tuple[Dict[str, int], List[Finding]]:
    """Drive the chunked-prefill scheduler twice with DIFFERENT prompt
    lengths and fill loads; pin zero prefill traces, one decode trace, and
    zero retraces across the change (the unified step program's whole
    point: varying chunk counts never change the traced shape)."""
    from repro.models.model import Model
    from repro.serve import Engine, EngineConfig, Request
    from repro.serve.scheduler import Scheduler

    cell = CHUNKED_TRACE_CELLS[name]
    cfg = get_config(cell["arch"]).reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params,
                 EngineConfig(n_slots=2, max_len=24, chunk=4, chunk_size=4,
                              prefill_buckets=(8,), **cell["engine"]))
    sched = Scheduler(eng)
    where = f"contracts/trace/{name}"
    findings: List[Finding] = []
    if not eng.chunked_prefill:
        findings.append(Finding(
            "trace-pin", where, 0, "chunked_prefill", "False",
            "cell's engine did not auto-enable chunked prefill"))
        return {}, findings

    def stream(lens, seed):
        rng = np.random.default_rng(seed)
        return [Request(i, rng.integers(0, cfg.vocab_size, n)
                        .astype(np.int32), 4)
                for i, n in enumerate(lens)]

    sched.run(stream([3, 11, 7], 0))
    first = dict(eng.trace_counts)
    sched.run(stream([13, 2, 5, 9, 16], 1))  # different lengths + load
    measured = {"prefill": first["prefill"], "decode": first["decode"],
                "retraces": eng.trace_counts["decode"] - first["decode"]}
    for k, want in EXPECTED_CHUNKED_TRACES[name].items():
        if measured[k] != want:
            findings.append(Finding(
                "trace-pin", where, 0, k, f"{k}={measured[k]}",
                f"expected {k}={want}, measured {measured[k]} (the unified "
                "chunked step program retraced, or a prefill program ran "
                "on the chunked path)"))
    return measured, findings


def check_trace_contracts(
        cells: Optional[Iterable[str]] = None,
        chunked_cells: Optional[Iterable[str]] = None) -> List[Finding]:
    findings: List[Finding] = []
    for name in (cells if cells is not None else TRACE_CELLS):
        findings.extend(run_trace_cell(name)[1])
    for name in (chunked_cells if chunked_cells is not None
                 else CHUNKED_TRACE_CELLS):
        findings.extend(run_chunked_trace_cell(name)[1])
    return findings


# ---------------------------------------------------------------------------
# bf16 upcast contract (lowered StableHLO)
# ---------------------------------------------------------------------------

_CONVERT_RE = re.compile(
    r"stablehlo\.convert\s+%[\w.#]+\s*:\s*\(tensor<([0-9x]+)xbf16>\)"
    r"\s*->\s*tensor<\1xf32>")

# weight shapes with a reviewed f32 upcast in the decode graph (none today)
UPCAST_ALLOWLIST: set = set()


def check_bf16_upcasts(arch: str = "qwen3-8b") -> List[Finding]:
    """Lower a bf16-param decode step; flag f32 converts of weight-shaped
    bf16 tensors (ndim >= 2 param leaves). 1-D leaves (norm scales, biases)
    are exempt: their f32 numerics are intentional and O(d) not O(d^2)."""
    from repro.models.model import Model

    cfg = get_config(arch).reduced()
    model = Model(cfg, param_dtype=jnp.bfloat16)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    weight_shapes = set()
    for leaf_path, (leaf,) in _zip_leaves(shapes):
        if len(leaf.shape) >= 2 and leaf.dtype == jnp.bfloat16:
            weight_shapes.add("x".join(str(d) for d in leaf.shape))
    B = 2
    cache = jax.eval_shape(lambda: model.init_cache(B, 16))
    inputs = {"token": jax.ShapeDtypeStruct((B,), jnp.int32),
              "pos": jax.ShapeDtypeStruct((), jnp.int32)}
    hlo = jax.jit(model.decode_step).lower(shapes, inputs, cache).as_text()
    where = f"contracts/bf16/{arch}"
    findings: List[Finding] = []
    flagged = set()
    for m in _CONVERT_RE.finditer(hlo):
        shape = m.group(1)
        if shape in weight_shapes and shape not in UPCAST_ALLOWLIST \
                and shape not in flagged:
            flagged.add(shape)
            findings.append(Finding(
                "bf16-upcast", where, 0, f"tensor<{shape}>",
                m.group(0)[:80],
                f"bf16 param leaf of shape {shape} upcast to f32 in the "
                "lowered decode step — doubles decode weight traffic"))
    return findings


def run_all(trace: bool = True) -> List[Finding]:
    findings = run_static()
    findings.extend(check_bf16_upcasts())
    if trace:
        findings.extend(check_trace_contracts())
    return findings
