"""AST jit-safety lint over ``src/repro``.

Repo-specific rules, each a mechanically-detectable bug class this codebase
has actually shipped (or nearly shipped):

``host-sync``
    Host synchronization constructs — ``.item()``, ``int()``/``float()``/
    ``bool()`` on non-static values, ``np.asarray``/``np.array``,
    ``jax.device_get``, ``block_until_ready`` — inside *jitted-region*
    code (functions passed to ``jax.jit``/``self._jit``/``lax.scan``/
    ``pl.pallas_call``/grad transforms, their nested functions, and
    decorated jits). Inside a trace these either fail or silently force a
    device round-trip per call. The rule also covers the whole body of the
    declared hot-path modules (``HOT_PATH_MODULES``): the serve engine's
    host-side driver ops sit on the per-chunk critical path, so every sync
    there is a reviewed decision — intentional ones (the once-per-chunk
    harvest, the host free-page mirror) live in the baseline or carry an
    inline ``# lint: allow(host-sync)``.

``pallas-interpret``
    ``pl.pallas_call`` sites whose ``interpret`` handling deviates from the
    repo contract: the enclosing wrapper must take ``interpret=None`` and
    resolve it via ``ops._interpret_default``, and the call site must pass
    that resolved local — never a hard-coded constant. (PR 6 bug class: a
    hard ``interpret=True`` default would run the Python interpreter on
    real TPUs.)

``pallas-params``
    ``pl.pallas_call`` sites missing ``compiler_params`` with explicit
    ``dimension_semantics`` and ``vmem_limit_bytes`` — without them Mosaic
    guesses the grid semantics and the VMEM budget verifier has no
    declared limit to check against.

``jit-shardings``
    ``jax.jit`` calls in mesh-aware modules (any module importing
    ``jax.sharding`` or ``repro.distributed``) without explicit
    ``in_shardings``/``out_shardings`` — unsharded programs silently
    migrate sharded state through one device (PR 5 bug class).

``f32-cast``
    Bare f32 casts/dtypes (``.astype(jnp.float32)``, ``dtype=jnp.float32``)
    in the bf16 model-compute modules (``BF16_COMPUTE_MODULES``). An
    unintended upcast doubles weight/activation traffic on the decode hot
    path — exactly what 2:4 serving exists to halve. Intentional f32
    numerics (softmax stats, norms, SSD state) are baselined;
    ``preferred_element_type=jnp.float32`` (MXU accumulation) is always
    allowed. Pallas kernel modules are exempt: f32 VMEM accumulators are
    their documented contract.

Suppression: inline ``# lint: allow(rule[, rule])`` on the offending line,
or a baseline entry (see common.py).
"""
from __future__ import annotations

import ast
import os
import re
from typing import Dict, Iterable, List, Optional, Sequence, Set

from repro.analysis.common import Finding, rel_path

RULES = ("host-sync", "pallas-interpret", "pallas-params", "jit-shardings",
         "f32-cast")

# module-wide host-sync scanning (repo-relative, posix)
HOT_PATH_MODULES = {
    "repro/serve/engine.py",
}

# f32-cast rule scope: the bf16 model-compute path
BF16_COMPUTE_MODULES = {
    "repro/models/layers.py",
    "repro/models/blocks.py",
    "repro/models/model.py",
    "repro/models/mamba2.py",
    "repro/models/moe.py",
    "repro/models/flash.py",
}

_ALLOW_RE = re.compile(r"#\s*lint:\s*allow\(([^)]*)\)")
# opt a module into the path-scoped rule sets regardless of its location:
#   # lint: module(hot-path, bf16-compute, mesh-aware)
# (used by test fixtures; real modules are classified by relpath/imports)
_MODULE_RE = re.compile(r"#\s*lint:\s*module\(([^)]*)\)")

# call heads whose first function-valued argument becomes device code
_JIT_ENTRY_ATTRS = {"jit", "_jit", "pallas_call", "scan", "checkpoint",
                    "remat", "grad", "value_and_grad", "vmap", "custom_vjp"}

_SYNC_WRAPPERS = {"int", "float", "bool"}
_NP_SYNC_ATTRS = {"asarray", "array"}


def src_root() -> str:
    """The ``src/`` directory this package lives under."""
    return os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))


def _inline_allows(source: str) -> Dict[int, Set[str]]:
    out: Dict[int, Set[str]] = {}
    for i, line in enumerate(source.splitlines(), 1):
        m = _ALLOW_RE.search(line)
        if m:
            out[i] = {r.strip() for r in m.group(1).split(",") if r.strip()}
    return out


def _is_static_arg(node: ast.AST) -> bool:
    """True when ``int()``/``float()``/``bool()`` over this expression is
    host-static (shape math, lengths, constants) rather than a device sync."""
    if isinstance(node, ast.Constant):
        return True
    for sub in ast.walk(node):
        if isinstance(sub, ast.Attribute) and sub.attr in ("shape", "ndim",
                                                           "size", "itemsize"):
            return True
        if isinstance(sub, ast.Call) and isinstance(sub.func, ast.Name) \
                and sub.func.id in ("len", "range"):
            return True
    return False


def _dotted(node: ast.AST) -> str:
    """'jax.numpy.float32' style dotted name for Attribute/Name chains."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return ".".join(reversed(parts))


def _is_f32_dtype(node: ast.AST) -> bool:
    d = _dotted(node)
    return d.endswith("float32") or (isinstance(node, ast.Constant)
                                     and node.value == "float32")


class _ModuleLint:
    def __init__(self, path: str, relpath: str, tree: ast.Module,
                 source: str):
        self.relpath = relpath
        self.tree = tree
        self.lines = source.splitlines()
        self.allows = _inline_allows(source)
        tags = set()
        for m in _MODULE_RE.finditer(source):
            tags |= {t.strip() for t in m.group(1).split(",")}
        self.hot_path = relpath in HOT_PATH_MODULES or "hot-path" in tags
        self.bf16 = relpath in BF16_COMPUTE_MODULES or "bf16-compute" in tags
        self.mesh_aware = self._detect_mesh_aware(tree) or "mesh-aware" in tags
        self.findings: List[Finding] = []
        # qualname bookkeeping + jitted-region marking
        self._parents: Dict[ast.AST, ast.AST] = {}
        for parent in ast.walk(tree):
            for child in ast.iter_child_nodes(parent):
                self._parents[child] = parent
        self._defs_by_name: Dict[str, List[ast.AST]] = {}
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._defs_by_name.setdefault(node.name, []).append(node)
        self.jitted: Set[ast.AST] = set()
        self._mark_jitted()

    # -- classification --------------------------------------------------
    @staticmethod
    def _detect_mesh_aware(tree: ast.Module) -> bool:
        for node in ast.walk(tree):
            if isinstance(node, ast.ImportFrom) and node.module and (
                    node.module.startswith("jax.sharding")
                    or node.module.startswith("repro.distributed")):
                return True
            if isinstance(node, ast.Import):
                for a in node.names:
                    if a.name.startswith("jax.sharding") \
                            or a.name.startswith("repro.distributed"):
                        return True
        return False

    # -- jitted-region marking -------------------------------------------
    def _func_targets(self, node: ast.AST) -> List[ast.AST]:
        """Function nodes a jit-entry argument resolves to: a direct
        lambda/def name, a ``self.method`` reference, or the target of a
        ``functools.partial`` wrapper."""
        if isinstance(node, ast.Lambda):
            return [node]
        name = None
        if isinstance(node, ast.Name):
            name = node.id
        elif isinstance(node, ast.Attribute):
            name = node.attr  # self._decode_impl -> "_decode_impl"
        elif isinstance(node, ast.Call):
            head = _dotted(node.func)
            if head.endswith("partial") and node.args:
                return self._func_targets(node.args[0])
        if name is not None:
            return list(self._defs_by_name.get(name, []))
        return []

    def _mark_jitted(self) -> None:
        # 1) call-site targets: jax.jit(fn), self._jit(fn), lax.scan(fn),
        #    pl.pallas_call(kernel), grad/vmap/checkpoint transforms
        for node in ast.walk(self.tree):
            if not isinstance(node, ast.Call):
                continue
            head = _dotted(node.func).rsplit(".", 1)[-1]
            if head in _JIT_ENTRY_ATTRS and node.args:
                for fn in self._func_targets(node.args[0]):
                    self.jitted.add(fn)
        # 2) decorated jits
        for node in ast.walk(self.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for dec in node.decorator_list:
                    d = ast.unparse(dec)
                    if "jit" in d.split("(")[0].rsplit(".", 1)[-1] \
                            or "jax.jit" in d:
                        self.jitted.add(node)
        # 3) transitive closure: nested defs of a jitted function trace too
        changed = True
        while changed:
            changed = False
            for node in ast.walk(self.tree):
                if not isinstance(node, (ast.FunctionDef,
                                         ast.AsyncFunctionDef, ast.Lambda)):
                    continue
                if node in self.jitted:
                    continue
                if self._enclosing_function(node) in self.jitted:
                    self.jitted.add(node)
                    changed = True

    def _enclosing_function(self, node: ast.AST) -> Optional[ast.AST]:
        cur = self._parents.get(node)
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef,
                                ast.Lambda)):
                return cur
            cur = self._parents.get(cur)
        return None

    def _qualname(self, node: ast.AST) -> str:
        parts: List[str] = []
        cur: Optional[ast.AST] = node
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
                parts.append(cur.name)
            elif isinstance(cur, ast.Lambda):
                parts.append("<lambda>")
            elif isinstance(cur, ast.ClassDef):
                parts.append(cur.name)
            cur = self._parents.get(cur)
        return ".".join(reversed(parts)) or "<module>"

    def _in_jitted_region(self, node: ast.AST) -> bool:
        fn = self._enclosing_function(node)
        return fn is not None and fn in self.jitted

    # -- emission ---------------------------------------------------------
    def _emit(self, rule: str, node: ast.AST, message: str) -> None:
        line = getattr(node, "lineno", 1)
        if rule in self.allows.get(line, ()):
            return
        snippet = self.lines[line - 1].strip() if line <= len(self.lines) \
            else ""
        scope = self._qualname(self._enclosing_function(node) or node)
        self.findings.append(Finding(rule, self.relpath, line, scope,
                                     snippet, message))

    # -- rules ------------------------------------------------------------
    def run(self) -> List[Finding]:
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Call):
                self._check_host_sync(node)
                self._check_pallas_call(node)
                self._check_jit_shardings(node)
                if self.bf16:
                    self._check_f32(node)
        return self.findings

    def _check_host_sync(self, node: ast.Call) -> None:
        in_scope = self._in_jitted_region(node) or self.hot_path
        if not in_scope:
            return
        where = "in jitted region" if self._in_jitted_region(node) \
            else "on the serve hot path"
        head = _dotted(node.func)
        tail = head.rsplit(".", 1)[-1]
        if tail == "item" and isinstance(node.func, ast.Attribute):
            self._emit("host-sync", node, f".item() {where} forces a "
                       "device round-trip")
        elif head in ("jax.device_get", "jax.block_until_ready") \
                or tail == "block_until_ready":
            self._emit("host-sync", node,
                       f"{tail}() {where} blocks on the device")
        elif isinstance(node.func, ast.Name) \
                and node.func.id in _SYNC_WRAPPERS and node.args \
                and not _is_static_arg(node.args[0]):
            self._emit("host-sync", node,
                       f"{node.func.id}() on a (possibly device) value "
                       f"{where} is a blocking transfer")
        elif isinstance(node.func, ast.Attribute) \
                and tail in _NP_SYNC_ATTRS \
                and _dotted(node.func.value) in ("np", "numpy"):
            self._emit("host-sync", node,
                       f"np.{tail}() {where} materializes on host")

    def _check_pallas_call(self, node: ast.Call) -> None:
        if _dotted(node.func).rsplit(".", 1)[-1] != "pallas_call":
            return
        kw = {k.arg: k.value for k in node.keywords if k.arg}
        # interpret contract
        interp = kw.get("interpret")
        if interp is None:
            self._emit("pallas-interpret", node,
                       "pallas_call without interpret= (must pass the "
                       "resolved interpret local)")
        elif isinstance(interp, ast.Constant):
            self._emit("pallas-interpret", node,
                       f"pallas_call with hard-coded interpret="
                       f"{interp.value!r} (PR 6 bug class: must resolve "
                       "via ops._interpret_default)")
        else:
            fn = self._enclosing_function(node)
            ok_default = False
            ok_resolve = False
            if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                args = fn.args
                names = [a.arg for a in args.args + args.kwonlyargs]
                defaults = dict(zip(
                    [a.arg for a in args.args][len(args.args)
                                               - len(args.defaults):],
                    args.defaults))
                defaults.update({a.arg: d for a, d in
                                 zip(args.kwonlyargs, args.kw_defaults)
                                 if d is not None})
                if "interpret" in names:
                    d = defaults.get("interpret")
                    if isinstance(d, ast.Constant) and d.value is None:
                        ok_default = True
                    elif isinstance(d, ast.Constant):
                        self._emit(
                            "pallas-interpret", fn,
                            f"interpret defaults to {d.value!r} — a hard "
                            "default runs the wrong engine on TPU/CPU; "
                            "use None + ops._interpret_default")
                        ok_default = True  # already reported, don't double
                for sub in ast.walk(fn):
                    if isinstance(sub, (ast.Name, ast.Attribute)) \
                            and _dotted(sub).endswith("_interpret_default"):
                        ok_resolve = True
            if not (ok_default and ok_resolve):
                self._emit("pallas-interpret", node,
                           "pallas_call wrapper must take interpret=None "
                           "and resolve it via ops._interpret_default")
        # compiler params contract
        cp = kw.get("compiler_params")
        if cp is None:
            self._emit("pallas-params", node,
                       "pallas_call without compiler_params "
                       "(dimension_semantics + vmem_limit_bytes)")
            return
        if isinstance(cp, ast.Name):
            # shared params built once in the wrapper: resolve the local
            fn = self._enclosing_function(node)
            for sub in ast.walk(fn if fn is not None else self.tree):
                if isinstance(sub, ast.Assign) and any(
                        isinstance(t, ast.Name) and t.id == cp.id
                        for t in sub.targets):
                    cp = sub.value
                    break
        cp_src = ast.unparse(cp)
        if "dimension_semantics" not in cp_src:
            self._emit("pallas-params", node,
                       "compiler_params missing dimension_semantics")
        if "vmem_limit_bytes" not in cp_src:
            self._emit("pallas-params", node,
                       "compiler_params missing vmem_limit_bytes")

    def _check_jit_shardings(self, node: ast.Call) -> None:
        if not self.mesh_aware:
            return
        head = _dotted(node.func)
        if head not in ("jax.jit", "jit") and not head.endswith("._jit"):
            return
        kws = {k.arg for k in node.keywords if k.arg}
        if head.endswith("._jit"):
            return  # engine's own wrapper: it injects the shardings
        if not ({"in_shardings", "out_shardings"} & kws):
            self._emit("jit-shardings", node,
                       "jax.jit in a mesh-aware module without explicit "
                       "in_shardings/out_shardings (state may silently "
                       "migrate through one device)")

    def _check_f32(self, node: ast.Call) -> None:
        head = _dotted(node.func)
        tail = head.rsplit(".", 1)[-1]
        if tail == "astype" and node.args and _is_f32_dtype(node.args[0]):
            self._emit("f32-cast", node,
                       "astype(float32) in a bf16 compute path")
            return
        for k in node.keywords:
            if k.arg == "dtype" and _is_f32_dtype(k.value):
                self._emit("f32-cast", node,
                           "dtype=float32 in a bf16 compute path")
                return
        # positional dtype args to jnp constructors (jnp.zeros(s, jnp.float32))
        if head.startswith(("jnp.", "jax.numpy.")):
            for a in node.args:
                if isinstance(a, (ast.Attribute, ast.Name)) \
                        and _is_f32_dtype(a):
                    self._emit("f32-cast", node,
                               "f32 dtype literal in a bf16 compute path")
                    return


def lint_file(path: str, relpath: Optional[str] = None) -> List[Finding]:
    with open(path) as f:
        source = f.read()
    rel = relpath if relpath is not None else rel_path(path, src_root())
    tree = ast.parse(source, filename=path)
    return _ModuleLint(path, rel, tree, source).run()


def lint_tree(root: Optional[str] = None,
              paths: Optional[Sequence[str]] = None) -> List[Finding]:
    """Lint every module under ``src/repro`` (or explicit ``paths``)."""
    base = src_root()
    if paths is None:
        pkg = os.path.join(base, "repro") if root is None else root
        paths = []
        for dirpath, _, names in os.walk(pkg):
            for n in sorted(names):
                if n.endswith(".py"):
                    paths.append(os.path.join(dirpath, n))
    out: List[Finding] = []
    for p in sorted(paths):
        out.extend(lint_file(p))
    return out
