"""Static VMEM budget verification for the four Pallas kernels.

Instantiates each kernel module's ``vmem_plan`` hook (kernels/budget.py)
over every assigned architecture's REAL deployment dimensions — the
projection shapes the pruner/serving path actually runs the kernels on,
derived from the same ``prunable_table`` walk the 2:4 machinery uses — and
checks the implied working set against the declared ``vmem_limit_bytes``
plus each kernel's block-divisibility constraints.

Two lanes:

* :func:`run_default` (the ``make analyze`` lane): block shapes are first
  *resolved* per dimension — the largest feasible divisor not above the
  kernel's default block (matching what a caller tuning that shape would
  pick) — so the lane verifies that every real shape HAS a feasible
  tiling, and fails if none exists or the resolved plan still blows the
  declared limit.
* :func:`sweep` (``launch/dryrun.py --check-vmem``): takes block shapes
  as-given and reports every infeasible (shape x block) cell, so a sweep
  grid can be vetted before burning TPU time on configurations Mosaic
  would reject.

All pure arithmetic — no tracing, no devices; safe in the CPU CI lane.
"""
from __future__ import annotations

from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import jax

from repro.analysis.common import Finding
from repro.configs import ASSIGNED_ARCHS, get_config
# the repro.kernels package namespace re-exports the jitted wrappers under
# the same names as their modules, and `import pkg.mod as x` binds through
# that shadowed attribute — resolve the MODULES via importlib instead
import importlib

masked_matmul = importlib.import_module("repro.kernels.masked_matmul")
nm_mask = importlib.import_module("repro.kernels.nm_mask")
paged_attention = importlib.import_module("repro.kernels.paged_attention")
sparse_matmul24 = importlib.import_module("repro.kernels.sparse_matmul24")
from repro.kernels.budget import KernelVmemPlan

# decode wave width used for the matmul M dim in the default lane (the
# serve engine's per-chunk batch; prefill M is covered by the sweep lane)
DEFAULT_DECODE_M = 8
DEFAULT_PAGE_SIZE = 16
DEFAULT_MAX_BLOCKS = 8
# chunk-lane query rows (EngineConfig.chunk_size default): the chunked
# prefill engine issues one (1, sq) query block alongside the decode wave
DEFAULT_CHUNK_SQ = 16


def resolve_block(dim: int, default: int, multiple: int = 1) -> Optional[int]:
    """Largest b <= default with dim % b == 0 and b % multiple == 0 — the
    block a caller tuning this shape would pick. None when no such b."""
    for b in range(min(default, dim), 0, -1):
        if dim % b == 0 and b % multiple == 0:
            return b
    return None


def projection_shapes(cfg) -> List[Tuple[str, Tuple[int, int]]]:
    """Distinct (tap, (K, N)) 2-D projection shapes of one arch — the
    matrices the nm_mask / masked_matmul / sparse_matmul24 kernels run on.
    Derived from the param tree via the same ``prunable_table`` walk the
    2:4 serving transform uses, so the two can't disagree about coverage."""
    from repro.models.blocks import _tget, prunable_table
    from repro.models.model import Model

    model = Model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    out: List[Tuple[str, Tuple[int, int]]] = []
    seen = set()

    def walk(tree, table):
        if tree is None:
            return
        for tap, path in table.items():
            if path[-1] != "w":
                continue  # expert stacks: no serve kernel
            w = _tget(tree, path)
            if w is None or len(w.shape) < 2:
                continue
            kn = (int(w.shape[-2]), int(w.shape[-1]))
            if kn not in seen:
                seen.add(kn)
                out.append((tap, kn))

    walk(shapes.get("blocks"), prunable_table(cfg))
    if cfg.family == "hybrid" and "shared_attn" in shapes:
        from repro.models.blocks import PRUNABLE
        walk(shapes["shared_attn"], PRUNABLE["hybrid_shared"])
    return out


def kernel_plans(arch: str, cfg=None) -> List[KernelVmemPlan]:
    """Default-lane plans for one arch: every kernel x every real shape it
    serves, with per-dimension block resolution."""
    cfg = cfg if cfg is not None else get_config(arch)
    plans: List[KernelVmemPlan] = []
    projs = projection_shapes(cfg)
    M = DEFAULT_DECODE_M
    for tap, (K, N) in projs:
        # nm_mask scores (d_out, d_in) = (N, K) weight-major layout
        bo = resolve_block(N, 256)
        bi = resolve_block(K, 512, multiple=4)
        p = nm_mask.vmem_plan(N, K, block_out=bo or 256, block_in=bi or 512)
        p.config["tap"] = tap
        if bo is None or bi is None:
            p.violations.append(
                f"no feasible (block_out, block_in) tiling for ({N}, {K})")
        plans.append(p)
        bn = resolve_block(N, 128)
        bk = resolve_block(K, 512)
        p = masked_matmul.vmem_plan(M, K, N, block_n=bn or 128,
                                    block_k=bk or 512)
        p.config["tap"] = tap
        if bn is None or bk is None:
            p.violations.append(
                f"no feasible (block_n, block_k) tiling for K={K} N={N}")
        plans.append(p)
        if K % 8 == 0:  # 2:4-compactable shapes only
            bk8 = resolve_block(K, 512, multiple=8)
            p = sparse_matmul24.vmem_plan(M, K, N, block_n=bn or 128,
                                          block_k=bk8 or 512)
            p.config["tap"] = tap
            if bn is None or bk8 is None:
                p.violations.append(
                    f"no feasible 2:4 tiling for K={K} N={N}")
            plans.append(p)
    if cfg.num_kv_heads > 0 and not cfg.is_encoder_only:
        hd = cfg.resolved_head_dim
        KV = cfg.num_kv_heads
        G = max(cfg.num_heads // max(KV, 1), 1)
        plans.append(paged_attention.vmem_plan(
            DEFAULT_DECODE_M, KV, G, hd, page_size=DEFAULT_PAGE_SIZE,
            max_blocks=DEFAULT_MAX_BLOCKS))
        # chunk-lane mode of the same kernel: the chunked-prefill engine
        # runs one batch-1 query block of chunk_size rows per decode step
        plans.append(paged_attention.vmem_plan(
            1, KV, G, hd, sq=DEFAULT_CHUNK_SQ, page_size=DEFAULT_PAGE_SIZE,
            max_blocks=DEFAULT_MAX_BLOCKS))
    return plans


def plan_findings(arch: str, plans: Iterable[KernelVmemPlan]) -> List[Finding]:
    out: List[Finding] = []
    for p in plans:
        if p.feasible:
            continue
        cfgs = " ".join(f"{k}={v}" for k, v in p.config.items())
        for why in p.why_infeasible():
            out.append(Finding(
                "vmem-budget", f"vmem/{arch}", 0,
                f"{p.kernel}({cfgs})",
                f"total={p.total_bytes / 2**20:.1f}MiB "
                f"limit={p.limit_bytes / 2**20:.0f}MiB", why))
    return out


def run_default(archs: Optional[Sequence[str]] = None) -> List[Finding]:
    findings: List[Finding] = []
    for arch in (archs if archs is not None else ASSIGNED_ARCHS):
        findings.extend(plan_findings(arch, kernel_plans(arch)))
    return findings


# ---------------------------------------------------------------------------
# sweep lane: vet explicit (shape x block) grids (launch/dryrun.py)
# ---------------------------------------------------------------------------

def sweep(arch: str, block_ms: Sequence[int] = (8, 128),
          block_ns: Sequence[int] = (128, 256),
          block_ks: Sequence[int] = (256, 512),
          cfg=None) -> Tuple[List[KernelVmemPlan], List[Finding]]:
    """Blocks as-given (no resolution): every infeasible cell is reported,
    so a launch sweep can drop configurations Mosaic would reject."""
    cfg = cfg if cfg is not None else get_config(arch)
    plans: List[KernelVmemPlan] = []
    for tap, (K, N) in projection_shapes(cfg):
        for bm in block_ms:
            for bn in block_ns:
                for bk in block_ks:
                    p = masked_matmul.vmem_plan(bm, K, N, block_m=bm,
                                                block_n=bn, block_k=bk)
                    p.config["tap"] = tap
                    plans.append(p)
                    if K % 8 == 0:
                        p = sparse_matmul24.vmem_plan(bm, K, N, block_m=bm,
                                                      block_n=bn, block_k=bk)
                        p.config["tap"] = tap
                        plans.append(p)
    return plans, plan_findings(arch, plans)
