"""Checkpoint manager: periodic async saves, auto-resume, retention,
preemption handling — the fault-tolerance substrate for launch/train.py.

Failure model (1000+ nodes): any step may be the last. Guarantees:
  * atomic publish (store.py) — a partial write is never visible
  * auto-resume picks the newest *valid* checkpoint (corrupt dirs skipped)
  * the data stream is a pure function of step (data/calibration.py), so
    restart replays the exact token order — bitwise-reproducible training
  * elastic restore — shardings are regenerated for the new mesh on load
  * async writer thread — the training loop never blocks on disk
"""
from __future__ import annotations

import json
import os
import re
import threading
from typing import Any, Optional

import jax

from repro.checkpoint import store


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_write: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_write = async_write
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- discovery ---------------------------------------------------------
    def steps(self):
        out = []
        for d in os.listdir(self.directory):
            m = re.fullmatch(r"step_(\d+)", d)
            if m and os.path.exists(os.path.join(self.directory, d, "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, state: Any, extra: Optional[dict] = None,
             block: bool = False):
        """state: any pytree (params + opt state + rng...)."""
        extra = dict(extra or {})
        extra["step"] = step
        # materialize on host *before* handing to the writer thread so the
        # training loop can donate/overwrite device buffers immediately
        host_state = jax.tree_util.tree_map(jax.device_get, state)
        path = os.path.join(self.directory, f"step_{step}")

        def _write():
            store.save_pytree(path, host_state, extra=extra)
            self._gc()

        self.wait()
        if self.async_write and not block:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.steps()
        for s in steps[: -self.keep] if self.keep else []:
            import shutil
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"),
                          ignore_errors=True)

    # -- restore --------------------------------------------------------------
    def restore(self, like: Any, step: Optional[int] = None,
                shardings: Any = None):
        """Returns (state, extra) or (None, None) when nothing to resume.
        Tries newest-first and skips checkpoints that fail to load."""
        steps = self.steps() if step is None else [step]
        for s in reversed(steps):
            path = os.path.join(self.directory, f"step_{s}")
            try:
                state = store.load_pytree(path, like, shardings=shardings)
                extra = store.load_extra(path)
                return state, extra
            except Exception as e:  # corrupt/partial — try older
                print(f"[ckpt] skipping step_{s}: {e}")
        return None, None
