"""Sharded, atomic pytree storage.

Layout:  <dir>/<name>/leaf_<i>.npy + manifest.json (treedef, shapes, dtypes,
logical sharding metadata). Writes go to a temp dir and are renamed into
place — a crash mid-write never corrupts the latest checkpoint.

Elastic restore: leaves are stored *unsharded by logical name*, so loading
onto a different mesh is just `jax.device_put(leaf, new_sharding)` — the
logical-axis metadata (distributed/sharding.py) regenerates shardings for
whatever mesh the restarted job has. At real scale each leaf would be a set
of per-shard files keyed by logical index; the manifest format already
carries what's needed (see `shard_info`).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [v for _, v in flat]
    return names, leaves, treedef


def save_pytree(path: str, tree: Any, extra: Optional[dict] = None,
                shard_info: Optional[dict] = None) -> None:
    names, leaves, _ = _flatten_with_names(tree)
    tmp = tempfile.mkdtemp(dir=os.path.dirname(os.path.abspath(path)) or ".")
    try:
        manifest = {"leaves": [], "extra": extra or {},
                    "shard_info": shard_info or {}}
        for i, (name, leaf) in enumerate(zip(names, leaves)):
            arr = np.asarray(jax.device_get(leaf))
            fn = f"leaf_{i}.npy"
            # bf16 has no numpy dtype: store bit-pattern as uint16 + tag
            if str(leaf.dtype) == "bfloat16":
                np.save(os.path.join(tmp, fn), arr.view(np.uint16))
                manifest["leaves"].append({"name": name, "file": fn,
                                           "dtype": "bfloat16",
                                           "shape": list(arr.shape)})
            else:
                np.save(os.path.join(tmp, fn), arr)
                manifest["leaves"].append({"name": name, "file": fn,
                                           "dtype": str(arr.dtype),
                                           "shape": list(arr.shape)})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(path):
            shutil.rmtree(path)
        os.replace(tmp, path)  # atomic publish
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise


def load_pytree(path: str, like: Any, shardings: Any = None) -> Any:
    """Restore into the structure of `like`. If `shardings` (a matching
    pytree of jax.sharding.Sharding) is given, leaves are placed sharded —
    this is the elastic-restore path (mesh may differ from save time)."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    names, like_leaves, treedef = _flatten_with_names(like)
    by_name = {e["name"]: e for e in manifest["leaves"]}
    out = []
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(names))
    import jax.numpy as jnp
    for name, like_leaf, shard in zip(names, like_leaves, shard_leaves):
        e = by_name[name]
        arr = np.load(os.path.join(path, e["file"]))
        if e["dtype"] == "bfloat16":
            arr = arr.view(jnp.bfloat16)
        if shard is not None:
            out.append(jax.device_put(arr, shard))
        else:
            out.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


def load_extra(path: str) -> dict:
    with open(os.path.join(path, "manifest.json")) as f:
        return json.load(f)["extra"]
