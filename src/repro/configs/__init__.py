"""Architecture config registry: ``get_config("<arch-id>")``."""
from __future__ import annotations

from repro.configs.base import (
    ModelConfig,
    PruneConfig,
    ShapeConfig,
    SHAPES,
    TrainConfig,
    shape_applicable,
)

from repro.configs import (  # noqa: F401
    qwen3_8b,
    stablelm_3b,
    qwen1_5_110b,
    llama3_405b,
    qwen3_moe_235b,
    deepseek_moe_16b,
    mamba2_1_3b,
    zamba2_7b,
    hubert_xlarge,
    qwen2_vl_2b,
    llama1_7b,
)

_REGISTRY = {
    m.CONFIG.name: m.CONFIG
    for m in (
        qwen3_8b,
        stablelm_3b,
        qwen1_5_110b,
        llama3_405b,
        qwen3_moe_235b,
        deepseek_moe_16b,
        mamba2_1_3b,
        zamba2_7b,
        hubert_xlarge,
        qwen2_vl_2b,
        llama1_7b,
    )
}

# The 10 assignment architectures (llama1-7b is the paper's own, extra).
ASSIGNED_ARCHS = [
    "qwen3-8b",
    "stablelm-3b",
    "qwen1.5-110b",
    "llama3-405b",
    "qwen3-moe-235b-a22b",
    "deepseek-moe-16b",
    "mamba2-1.3b",
    "zamba2-7b",
    "hubert-xlarge",
    "qwen2-vl-2b",
]


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs():
    return list(_REGISTRY)


__all__ = [
    "ModelConfig",
    "PruneConfig",
    "ShapeConfig",
    "SHAPES",
    "TrainConfig",
    "shape_applicable",
    "get_config",
    "list_archs",
    "ASSIGNED_ARCHS",
]
