"""Model / run configuration dataclasses.

Every assigned architecture is expressed as a ``ModelConfig``. Reduced ("smoke")
variants are derived with :meth:`ModelConfig.reduced` so smoke tests exercise the
same code paths as the full configs without the memory footprint.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: Optional[int] = None  # defaults to d_model // num_heads
    # --- attention options -------------------------------------------------
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    mrope_sections: Optional[Tuple[int, int, int]] = None  # M-RoPE (t, h, w)
    causal: bool = True  # False => encoder-only (no decode step)
    # --- MoE ----------------------------------------------------------------
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    moe_capacity_factor: float = 1.25
    # dispatch group = this many tokens (0 = one full sequence per group).
    # With sequence-sharded activations, a group that equals the local seq
    # shard keeps the sort/scatter shard-local: expert all-to-all traffic
    # then scales with tokens/chip instead of tokens/dp-shard.
    moe_group_tokens: int = 0
    # --- SSM (Mamba2 / SSD) --------------------------------------------------
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_ngroups: int = 1
    ssm_chunk: int = 256  # SSD chunk length
    # --- hybrid (Zamba2) ------------------------------------------------------
    hybrid_attn_every: int = 0  # shared attention block every k layers; 0 = never
    # --- misc ------------------------------------------------------------------
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    act: str = "silu"  # silu (SwiGLU) | gelu (plain MLP, encoder-style)
    frontend: Optional[str] = None  # None | "audio" | "vision" (stub embeddings)
    vision_patches: int = 256  # VLM stub: number of prefix patch embeddings

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def has_subquadratic_path(self) -> bool:
        """True if long-context decode is sub-quadratic (SSM / hybrid)."""
        return self.family in ("ssm", "hybrid")

    @property
    def is_encoder_only(self) -> bool:
        return not self.causal

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND roofline MODEL_FLOPS)."""
        d, f, v, L = self.d_model, self.d_ff, self.vocab_size, self.num_layers
        hd = self.resolved_head_dim
        qd = self.num_heads * hd
        kvd = self.num_kv_heads * hd
        n = v * d  # embeddings
        if not self.tie_embeddings:
            n += d * v  # lm head
        attn = d * qd + 2 * d * kvd + qd * d
        mlp = 3 * d * f if self.act == "silu" else 2 * d * f
        if self.family == "moe":
            routed = self.num_experts * 3 * d * f
            shared = self.num_shared_experts * 3 * d * f
            router = d * self.num_experts
            per_layer = attn + routed + shared + router
        elif self.family == "ssm":
            per_layer = self._mamba_block_params()
        elif self.family == "hybrid":
            per_layer = self._mamba_block_params()
            # one shared attention+MLP block amortized over all layers
            n += attn + mlp
        else:
            per_layer = attn + mlp
        n += L * per_layer
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top-k + shared experts)."""
        if self.family != "moe":
            return self.param_count()
        d, f, L = self.d_model, self.d_ff, self.num_layers
        hd = self.resolved_head_dim
        qd = self.num_heads * hd
        kvd = self.num_kv_heads * hd
        attn = d * qd + 2 * d * kvd + qd * d
        active_moe = (self.top_k + self.num_shared_experts) * 3 * d * f
        router = d * self.num_experts
        n = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return n + L * (attn + active_moe + router)

    def _mamba_block_params(self) -> int:
        d = self.d_model
        di = self.d_inner
        ds = self.ssm_state
        ng = self.ssm_ngroups
        nh = self.ssm_nheads
        d_in_proj = 2 * di + 2 * ng * ds + nh
        conv_dim = di + 2 * ng * ds
        return d * d_in_proj + self.ssm_conv * conv_dim + conv_dim + 3 * nh + di + di * d

    # ------------------------------------------------------------------
    def reduced(self, **overrides) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests."""
        small = dict(
            name=self.name + "-smoke",
            num_layers=min(self.num_layers, 2 if self.hybrid_attn_every == 0 else 4),
            d_model=64,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 4) if self.num_kv_heads < self.num_heads else 4,
            d_ff=128,
            vocab_size=256,
            head_dim=16,
        )
        if self.family == "moe":
            small.update(num_experts=4, top_k=2,
                         num_shared_experts=min(self.num_shared_experts, 1))
        if self.family in ("ssm", "hybrid"):
            small.update(ssm_state=16, ssm_headdim=16, ssm_chunk=8)
        if self.family == "hybrid":
            small.update(hybrid_attn_every=2, num_layers=4)
        if self.family == "vlm":
            small.update(vision_patches=8)
        small.update(overrides)
        return dataclasses.replace(self, **small)


@dataclass(frozen=True)
class ShapeConfig:
    """One (input-shape) cell of the assignment matrix."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Assignment skip rules. Returns (runnable, reason-if-skipped)."""
    if shape.kind == "decode":
        if cfg.is_encoder_only:
            return False, "SKIP(rule): encoder-only arch has no decode step"
        if shape.name == "long_500k" and not cfg.has_subquadratic_path:
            return False, "SKIP(rule): long_500k needs sub-quadratic attention"
    return True, ""


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    accum_steps: int = 1  # gradient accumulation microbatches
    accum_dtype: str = "float32"  # "bfloat16" halves the accumulation buffer
    optimizer: str = "adamw"  # "adafactor": factored 2nd moment, ~0 state HBM
    optimizer_state_dtype: str = "float32"  # "bfloat16" halves optimizer HBM
    remat: bool = True
    remat_groups: int = 0  # >0: two-level scan remat (sqrt-ish activation HBM)
    warmup_steps: int = 100
    total_steps: int = 1000


@dataclass(frozen=True)
class PruneConfig:
    """Wanda++ hyperparameters — defaults are the paper's."""

    # any name registered in core/scores.py (magnitude|wanda|wanda++rgs|
    # wanda++ro|wanda++|gblm|stade|connect) or "sparsegpt" (driven by
    # core/sparsegpt.py's OBS solver instead of the score registry)
    method: str = "wanda++"
    sparsity: float = 0.5
    pattern: str = "2:4"  # "unstructured" | "N:M" | "row"
    alpha: float = 100.0  # RGS scaling factor (paper Eq. 4)
    n_calib: int = 128  # N calibration samples
    calib_len: int = 128  # tokens per sample (Wanda++(M) setting)
    ro_samples: int = 32  # M samples per RO round
    ro_iters: int = 5  # K rounds
    ro_lr: float = 3e-7  # RMSprop learning rate
    ro_steps_per_iter: int = 32  # one update per RO sample
    seed: int = 0

    def pattern_nm(self):
        if ":" in self.pattern:
            n, m = self.pattern.split(":")
            return int(n), int(m)
        return None
