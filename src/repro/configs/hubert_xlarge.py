"""hubert-xlarge [audio] — arXiv:2106.07447. Encoder-only; modality frontend is a
STUB (input_specs provides precomputed frame embeddings)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    d_ff=5120,
    vocab_size=504,  # masked-prediction codebook
    head_dim=80,
    causal=False,
    act="gelu",
    frontend="audio",
)
