"""llama1-7b [dense] — the paper's own primary evaluation architecture
(Touvron et al. 2023). Used by the benchmark suite mirroring Tables 1-8."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama1-7b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    d_ff=11008,
    vocab_size=32000,
    head_dim=128,
    rope_theta=10_000.0,
)
