"""mamba2-1.3b [ssm] — arXiv:2405.21060 (SSD). Attention-free."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_ngroups=1,
    ssm_chunk=256,
    tie_embeddings=True,
)
