"""qwen2-vl-2b [vlm] — arXiv:2409.12191. M-RoPE; vision frontend is a STUB
(input_specs provides precomputed patch embeddings as a sequence prefix)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    head_dim=128,
    qkv_bias=True,
    mrope_sections=(16, 24, 24),  # (t, h, w) over head_dim/2
    rope_theta=1_000_000.0,
    frontend="vision",
    vision_patches=256,
)
