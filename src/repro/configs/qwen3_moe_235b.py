"""qwen3-moe-235b-a22b [moe] — 128 experts top-8, GQA kv=4, qk_norm."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    d_ff=1536,  # per-expert intermediate size
    vocab_size=151936,
    head_dim=128,
    qk_norm=True,
    num_experts=128,
    top_k=8,
    num_shared_experts=0,
    rope_theta=1_000_000.0,
)
