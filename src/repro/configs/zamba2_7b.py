"""zamba2-7b [hybrid] — arXiv:2411.15242. Mamba2 backbone + shared attention
block applied periodically (simplified: every 6th layer, single shared block)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    d_ff=14336,  # shared block MLP
    vocab_size=32000,
    head_dim=112,
    ssm_state=64,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_ngroups=1,
    ssm_chunk=256,
    hybrid_attn_every=6,
)
