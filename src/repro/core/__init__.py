"""Wanda++ core: regional-gradient pruning (the paper's contribution)."""
from repro.core.masks import apply_mask, make_mask, nm_mask, row_mask, unstructured_mask  # noqa: F401
from repro.core.pruner import model_sparsity_report, prune_block, prune_model  # noqa: F401
from repro.core.ro import ro_fit, ro_round  # noqa: F401
from repro.core.scores import gblm_score, magnitude_score, rgs_score, wanda_score  # noqa: F401
