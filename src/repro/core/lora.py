"""LoRA adapters for sparsity-aware fine-tuning (paper Sec 5.6).

The paper attaches LoRA to the q and v projections of every block (following
Wanda's setup) and fine-tunes the pruned model; Wanda++ stays below Wanda
after fine-tuning, demonstrating RO is orthogonal to LoRA.

Adapters live inside the linear param dicts ("lora_a"/"lora_b") so the
standard forward picks them up with zero plumbing; the base (sparse) weights
stay frozen via the trainable mask.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

LORA_SCALE = 2.0  # alpha/rank with alpha = 2*rank (standard)
DEFAULT_TARGETS = (("attn", "wq"), ("attn", "wv"))  # the paper's q,v modules


def add_lora(params, key, rank: int = 8, targets=DEFAULT_TARGETS):
    """Insert stacked (L, d_in, r) / (L, r, d_out) adapters into each target."""
    blocks = params["blocks"]
    L = jax.tree_util.tree_leaves(blocks)[0].shape[0]
    new_blocks = blocks
    for t in targets:
        sub = blocks
        for p in t:
            sub = sub[p]
        w = sub["w"]  # (L, d_in, d_out)
        key, k1 = jax.random.split(key)
        a = (jax.random.normal(k1, (L, w.shape[1], rank), jnp.float32)
             / math.sqrt(w.shape[1])).astype(w.dtype)
        b = jnp.zeros((L, rank, w.shape[2]), w.dtype)
        new_sub = dict(sub)
        new_sub["lora_a"], new_sub["lora_b"] = a, b
        new_blocks = _set_path(new_blocks, t, new_sub)
    out = dict(params)
    out["blocks"] = new_blocks
    return out


def merge_lora(params, targets=DEFAULT_TARGETS):
    """Fold adapters into the base weights (breaks exact sparsity — the paper
    keeps adapters separate at inference; merging is provided for export)."""
    blocks = params["blocks"]
    for t in targets:
        sub = blocks
        for p in t:
            sub = sub[p]
        if "lora_a" not in sub:
            continue
        w = sub["w"] + LORA_SCALE * jnp.einsum(
            "lir,lro->lio", sub["lora_a"], sub["lora_b"]).astype(sub["w"].dtype)
        new_sub = {k: v for k, v in sub.items() if not k.startswith("lora_")}
        new_sub["w"] = w
        blocks = _set_path(blocks, t, new_sub)
    out = dict(params)
    out["blocks"] = blocks
    return out


def lora_trainable(params):
    """Boolean pytree: True only on lora leaves (freeze everything else)."""
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree_util.tree_structure(params)
    vals = [any("lora_" in str(k) for k in path) for path, _ in flat]
    return jax.tree_util.tree_unflatten(treedef, vals)


def _set_path(tree, path, val):
    if len(path) == 1:
        return {**tree, path[0]: val}
    return {**tree, path[0]: _set_path(tree[path[0]], path[1:], val)}
