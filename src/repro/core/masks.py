"""Sparsity mask generation from pruning scores.

Patterns (all used in the paper):
- unstructured: global-within-layer threshold at a target sparsity ratio
- N:M semi-structured: within every group of M consecutive weights along the
  *input* dim, keep the N highest-scoring (2:4, 4:8)
- row-structured ("SP", paper §6): drop whole output rows by mean row score

Masks are boolean, True = keep. Exactness invariants are property-tested.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def unstructured_mask(score: jnp.ndarray, sparsity: float) -> jnp.ndarray:
    """Keep the top (1-sparsity) fraction per *output row* (Wanda's per-output
    comparison group, which it shows beats whole-layer for LLMs)."""
    d_in = score.shape[-1]
    k = max(int(round(d_in * (1.0 - sparsity))), 0)
    if k == 0:
        return jnp.zeros_like(score, dtype=bool)
    # rank within each row; keep rank < k with index tie-break
    order = jnp.argsort(-score, axis=-1, stable=True)
    ranks = jnp.argsort(order, axis=-1, stable=True)
    return ranks < k


def nm_mask(score: jnp.ndarray, n: int, m: int) -> jnp.ndarray:
    """Top-n-of-m groups along the last (input) axis. score: (..., d_in)."""
    *lead, d_in = score.shape
    assert d_in % m == 0, f"d_in={d_in} not divisible by m={m}"
    g = score.reshape(*lead, d_in // m, m)
    # exact rank via pairwise comparison with index tie-break (no sort):
    # rank_i = #{j : s_j > s_i} + #{j < i : s_j == s_i}
    s_i = g[..., :, None]
    s_j = g[..., None, :]
    idx = jnp.arange(m)
    gt = s_j > s_i
    eq_lower = (s_j == s_i) & (idx[None, :] < idx[:, None])
    rank = jnp.sum(gt | eq_lower, axis=-1)
    return (rank < n).reshape(*lead, d_in)


def row_mask(score: jnp.ndarray, sparsity: float) -> jnp.ndarray:
    """Structured row pruning: row score = mean over the row (paper §6)."""
    d_out, d_in = score.shape[-2], score.shape[-1]
    row_score = jnp.mean(score, axis=-1)  # (..., d_out)
    k = max(int(round(d_out * (1.0 - sparsity))), 1)
    order = jnp.argsort(-row_score, axis=-1, stable=True)
    ranks = jnp.argsort(order, axis=-1, stable=True)
    keep_row = ranks < k
    return jnp.broadcast_to(keep_row[..., None], score.shape)


def make_mask(score: jnp.ndarray, pattern: str, sparsity: float) -> jnp.ndarray:
    """pattern: "unstructured" | "N:M" (e.g. "2:4") | "row"."""
    if pattern == "unstructured":
        return unstructured_mask(score, sparsity)
    if pattern == "row":
        return row_mask(score, sparsity)
    n, m = pattern.split(":")
    return nm_mask(score, int(n), int(m))


def apply_mask(w: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    return jnp.where(mask, w, jnp.zeros((), w.dtype))


def sparsity_of(mask: jnp.ndarray) -> float:
    return float(1.0 - jnp.mean(mask.astype(jnp.float32)))
