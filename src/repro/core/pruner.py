"""Wanda++ block-sequential pruning driver (paper Alg. 1).

Walks decoder blocks in order; per block:
  1. regional gradient RMS G via one backward per calibration sample (Eq. 3)
  2. save the dense block outputs (RO targets)
  3. K iterations of [RGS prune -> RO round]   (steps 3-9)
  4. recompute G, final RGS prune              (steps 10-11)
  5. propagate calibration activations through the pruned block

Memory is O(one block) by construction — the paper's scalability claim. Under
a mesh, the same jitted per-block functions run as SPMD programs (see
launch/prune.py): calibration samples shard over `data`, block weights over
`model`, and the only cross-device reduction is the grad/tap psum.

Methods: magnitude | wanda | sparsegpt | gblm | wanda++rgs | wanda++ro | wanda++
(`wanda++ro` = Wanda score + RO; `wanda++rgs` = RGS score, no RO.)
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, PruneConfig
from repro.core import masks as M
from repro.core import ro as RO
from repro.core import scores as SC
from repro.core.regional import (block_io_stats, full_model_grad_rms,
                                 regional_grad_rms)
from repro.models import blocks as B
from repro.models.layers import default_positions
from repro.models.model import Model

# ---------------------------------------------------------------------------
# pytree path utilities
# ---------------------------------------------------------------------------

def tree_get(t, path):
    for p in path:
        if not isinstance(t, dict) or p not in t:
            return None
        t = t[p]
    return t


def tree_set(t, path, val):
    if len(path) == 1:
        return {**t, path[0]: val}
    return {**t, path[0]: tree_set(t[path[0]], path[1:], val)}


# ---------------------------------------------------------------------------
# block function factory
# ---------------------------------------------------------------------------

def make_block_fn(cfg: ModelConfig) -> Callable:
    """fn(bp, x, lin=None, elin=None) -> block output (residual included)."""
    if cfg.family in ("ssm", "hybrid"):
        def fn(bp, x, lin=None, elin=None):
            return B.ssm_block(bp, x, cfg, _positions(cfg, x), lin=lin)[0]
        return fn
    apply = B.APPLY[cfg.family]

    def fn(bp, x, lin=None, elin=None):
        return apply(bp, x, cfg, _positions(cfg, x), lin=lin, elin=elin)[0]
    return fn


def make_shared_block_fn(cfg: ModelConfig) -> Callable:
    """Zamba2's shared attention block as a standalone region."""
    def fn(bp, x, lin=None, elin=None):
        return B.transformer_block(bp, x, cfg, _positions(cfg, x), lin=lin)[0]
    return fn


def _positions(cfg: ModelConfig, x):
    Bsz, S = x.shape[0], x.shape[1]
    pos = default_positions(Bsz, S)
    if cfg.mrope_sections is not None:
        return jnp.broadcast_to(pos[None], (3, Bsz, S))
    return pos


# ---------------------------------------------------------------------------
# scoring + destructive mask application
# ---------------------------------------------------------------------------

def apply_prune(bp, xnorm: Optional[Dict], G, pcfg: PruneConfig,
                prunable: Dict[str, tuple], with_mask: bool = False):
    """Score every prunable weight and zero the pruned entries (destructive).
    RO's masked RMSprop steps keep them zero mid-round and ``ro_fit``
    re-applies the prune after the final round, so exact sparsity survives.

    ``with_mask=True`` additionally returns the 0/1 keep-mask tree (same
    structure as ``bp``, all-ones at non-prunable leaves) — the contract
    ``ro.ro_fit`` expects from its ``prune_fn``."""
    method = pcfg.method
    keep = jax.tree_util.tree_map(
        lambda p: jnp.ones(p.shape, jnp.bool_), bp) if with_mask else None
    for name, path in prunable.items():
        w = tree_get(bp, path)
        if w is None:
            continue
        w_oi = SC.to_oi(w)
        if method == "magnitude":
            s = SC.magnitude_score(w_oi)
        elif method in ("wanda", "wanda++ro"):
            s = SC.wanda_score(w_oi, xnorm[name])
        elif method in ("wanda++", "wanda++rgs", "gblm"):
            g_oi = SC.to_oi(tree_get(G, path))
            s = SC.rgs_score(w_oi, xnorm[name], g_oi, pcfg.alpha)
        else:
            raise ValueError(f"unknown method {method}")
        mask = M.make_mask(s, pcfg.pattern, pcfg.sparsity)
        bp = tree_set(bp, path, SC.from_oi(jnp.where(mask, w_oi, 0)))
        if with_mask:
            keep = tree_set(keep, path, SC.from_oi(mask))
    return (bp, keep) if with_mask else bp


# ---------------------------------------------------------------------------
# per-block Alg. 1
# ---------------------------------------------------------------------------

def prune_block(block_fn, bp, xs, pcfg: PruneConfig, prunable, key,
                grad_chunk: int = 8, G_override=None):
    """Returns (pruned bp, report dict)."""
    method = pcfg.method
    needs_grad = method in ("wanda++", "wanda++rgs", "gblm")
    needs_ro = method in ("wanda++", "wanda++ro")

    t0 = time.perf_counter()
    stats_j = jax.jit(lambda b, x: block_io_stats(block_fn, b, x))
    grad_j = jax.jit(lambda b, x: regional_grad_rms(block_fn, b, x, grad_chunk))
    prune_j = jax.jit(lambda b, xn, g: apply_prune(b, xn, g, pcfg, prunable))

    G = None
    if needs_grad:
        G = G_override if G_override is not None else grad_j(bp, xs)
    dense_out, xnorm = stats_j(bp, xs)

    report: Dict[str, Any] = {"method": method}
    if not needs_ro:
        bp = prune_j(bp, xnorm, G)
        report["seconds"] = time.perf_counter() - t0
        return bp, report

    # K x [prune -> RO] (steps 3-9)
    prune_mask_j = jax.jit(
        lambda b, xn, g: apply_prune(b, xn, g, pcfg, prunable, with_mask=True))

    def prune_fn(bp_):
        _, xn = stats_j(bp_, xs)  # fresh layer inputs; G reused (paper Sec 4.1)
        return prune_mask_j(bp_, xn, G)  # (bp, keep-mask) for masked RO steps

    bp, ro_losses = RO.ro_fit(block_fn, bp, xs, dense_out, pcfg, key, prune_fn)

    # steps 10-11: recompute gradient, final prune with fresh statistics
    if needs_grad:
        G = grad_j(bp, xs)
    _, xnorm = stats_j(bp, xs)
    bp = prune_j(bp, xnorm, G)
    report["ro_losses"] = [float(l) for l in ro_losses]
    report["seconds"] = time.perf_counter() - t0
    return bp, report


# ---------------------------------------------------------------------------
# model-level driver
# ---------------------------------------------------------------------------

def embed_calibration(model: Model, params, calib) -> jnp.ndarray:
    """calib: tokens (N, S) int32, or frames (N, S, D) for audio."""
    if model.cfg.family == "audio":
        return calib.astype(model.param_dtype)
    return jnp.take(params["embed"], calib, axis=0)


def prune_model(model: Model, params, calib, pcfg: PruneConfig,
                progress: Callable = None):
    """Prune every block of `model`. Returns (params, report list).

    calib: (N, S) token ids (or (N, S, D) frames). Embeddings, LM head and
    final norms are excluded from pruning, as in the paper.
    """
    cfg = model.cfg
    prunable = B.prunable_table(cfg)
    block_fn = make_block_fn(cfg)
    key = jax.random.PRNGKey(pcfg.seed)

    xs = embed_calibration(model, params, calib)
    blocks = params["blocks"]
    prop_j = jax.jit(lambda b, x: block_fn(b, x))

    # full-model gradient for the GBLM baseline (computed once, per-sample RMS)
    gblm_G = None
    if pcfg.method == "gblm":
        gblm_G = _gblm_grads(model, params, calib)

    reports = []
    new_blocks = blocks

    shared_fn = None
    if cfg.family == "hybrid":
        params, shared_rep = _prune_hybrid_shared(model, params, xs, pcfg, key)
        reports.append(shared_rep)
        shared_fn = jax.jit(
            lambda b, x: make_shared_block_fn(cfg)(b, x))

    for l in range(cfg.num_layers):
        if cfg.family == "hybrid" and l % cfg.hybrid_attn_every == 0:
            xs = shared_fn(params["shared_attn"], xs)
        bp = jax.tree_util.tree_map(lambda a: a[l], blocks)
        key, sub = jax.random.split(key)
        if pcfg.method == "sparsegpt":
            from repro.core.sparsegpt import sparsegpt_prune_block
            bp, rep = sparsegpt_prune_block(block_fn, bp, xs, pcfg, prunable)
        else:
            G_l = (jax.tree_util.tree_map(lambda a: a[l], gblm_G)
                   if gblm_G is not None else None)
            bp, rep = prune_block(block_fn, bp, xs, pcfg, prunable, sub,
                                  G_override=G_l)
        rep["layer"] = l
        xs = prop_j(bp, xs)
        new_blocks = jax.tree_util.tree_map(
            lambda a, b: a.at[l].set(b), new_blocks, bp)
        reports.append(rep)
        if progress:
            progress(l, rep)

    out = dict(params)
    out["blocks"] = new_blocks
    return out, reports


def _gblm_grads(model: Model, params, calib):
    """Full-model per-sample CE gradient RMS over the block weights (GBLM)."""
    def loss_fn(p, batch):
        return model.loss(p, batch)[0]

    batches = {"tokens": calib[:, :-1][:, None, :], "labels": calib[:, 1:][:, None, :]}
    G = full_model_grad_rms(loss_fn, params, batches, chunk=2)
    return G["blocks"]


def _prune_hybrid_shared(model: Model, params, xs, pcfg: PruneConfig, key):
    """Zamba2: the shared attention block is pruned ONCE with statistics
    aggregated over all of its application sites (weight sharing makes the
    paper's per-site sequential recipe ill-posed; see DESIGN.md)."""
    cfg = model.cfg
    shared_fn = make_shared_block_fn(cfg)
    block_fn = make_block_fn(cfg)
    prop_shared = jax.jit(lambda b, x: shared_fn(b, x))
    prop_mamba = jax.jit(lambda b, x: block_fn(b, x))

    # collect inputs at every application site with dense weights
    site_inputs = []
    x = xs
    for l in range(cfg.num_layers):
        if l % cfg.hybrid_attn_every == 0:
            site_inputs.append(x)
            x = prop_shared(params["shared_attn"], x)
        bp = jax.tree_util.tree_map(lambda a: a[l], params["blocks"])
        x = prop_mamba(bp, x)
    xs_sites = jnp.concatenate(site_inputs, axis=0)  # sites as extra samples

    prunable = B.PRUNABLE["hybrid_shared"]
    if pcfg.method == "sparsegpt":
        from repro.core.sparsegpt import sparsegpt_prune_block
        shared_bp, rep = sparsegpt_prune_block(shared_fn, params["shared_attn"],
                                               xs_sites, pcfg, prunable)
    else:
        shared_bp, rep = prune_block(shared_fn, params["shared_attn"], xs_sites,
                                     pcfg, prunable, key)
    rep["layer"] = "shared_attn"
    out = dict(params)
    out["shared_attn"] = shared_bp
    return out, rep


# ---------------------------------------------------------------------------
# sparsity verification
# ---------------------------------------------------------------------------

def model_sparsity_report(model: Model, params) -> Dict[str, float]:
    """Achieved zero-fraction per prunable weight (averaged over layers)."""
    prunable = B.prunable_table(model.cfg)
    rep = {}
    for name, path in prunable.items():
        w = tree_get(params["blocks"], path)
        if w is None:
            continue
        rep[name] = float(jnp.mean((w == 0).astype(jnp.float32)))
    if model.cfg.family == "hybrid":
        for name, path in B.PRUNABLE["hybrid_shared"].items():
            w = tree_get(params["shared_attn"], path)
            if w is not None:
                rep["shared." + name] = float(jnp.mean((w == 0).astype(jnp.float32)))
    return rep
