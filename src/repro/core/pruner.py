"""Wanda++ block-sequential pruning driver (paper Alg. 1).

Walks decoder blocks in order; per block:
  1. regional gradient RMS G via one backward per calibration sample (Eq. 3)
  2. save the dense block outputs (RO targets)
  3. K iterations of [RGS prune -> RO round]   (steps 3-9)
  4. recompute G, final RGS prune              (steps 10-11)
  5. propagate calibration activations through the pruned block

Memory is O(one block) by construction — the paper's scalability claim. Under
a mesh, the same jitted per-block functions run as SPMD programs (see
launch/prune.py): calibration samples shard over `data`, block weights over
`model`, and the only cross-device reduction is the grad/tap psum.

``PruneConfig.method`` resolves through the score registry in
``core/scores.py`` (magnitude | wanda | wanda++ro | wanda++rgs | wanda++ |
gblm | stade | connect); sparsegpt stays a separate driver (weight-update
solver, not a score). Each registry entry declares the stats it consumes, so
the same ``apply_prune`` serves offline calibration (``block_io_stats_full``)
and live-traffic snapshots (``Engine.calibration_snapshot`` →
``reprune_from_stats``).
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, PruneConfig
from repro.core import masks as M
from repro.core import ro as RO
from repro.core import scores as SC
from repro.core.regional import (block_io_stats, block_io_stats_full,
                                 full_model_grad_rms, regional_grad_rms)
from repro.models import blocks as B
from repro.models.layers import default_positions
from repro.models.model import Model

# ---------------------------------------------------------------------------
# pytree path utilities
# ---------------------------------------------------------------------------

def tree_get(t, path):
    for p in path:
        if not isinstance(t, dict) or p not in t:
            return None
        t = t[p]
    return t


def tree_set(t, path, val):
    if len(path) == 1:
        return {**t, path[0]: val}
    return {**t, path[0]: tree_set(t[path[0]], path[1:], val)}


# ---------------------------------------------------------------------------
# block function factory
# ---------------------------------------------------------------------------

def make_block_fn(cfg: ModelConfig) -> Callable:
    """fn(bp, x, lin=None, elin=None) -> block output (residual included)."""
    if cfg.family in ("ssm", "hybrid"):
        def fn(bp, x, lin=None, elin=None):
            return B.ssm_block(bp, x, cfg, _positions(cfg, x), lin=lin)[0]
        return fn
    apply = B.APPLY[cfg.family]

    def fn(bp, x, lin=None, elin=None):
        return apply(bp, x, cfg, _positions(cfg, x), lin=lin, elin=elin)[0]
    return fn


def make_shared_block_fn(cfg: ModelConfig) -> Callable:
    """Zamba2's shared attention block as a standalone region."""
    def fn(bp, x, lin=None, elin=None):
        return B.transformer_block(bp, x, cfg, _positions(cfg, x), lin=lin)[0]
    return fn


def _positions(cfg: ModelConfig, x):
    Bsz, S = x.shape[0], x.shape[1]
    pos = default_positions(Bsz, S)
    if cfg.mrope_sections is not None:
        return jnp.broadcast_to(pos[None], (3, Bsz, S))
    return pos


# ---------------------------------------------------------------------------
# scoring + destructive mask application
# ---------------------------------------------------------------------------

# connect-style co-activation partner: a gate/up projection's output channel
# i is the down projection's input channel, so the partner's abssum closes
# the rank-1 connectivity factor
_CO_PARTNER = {"wg": "wd", "wu": "wd", "w1": "w2"}


def _stat_entry(stats, name):
    """One linear's raw stats: a full dict ({"sumsq", ...}) or, legacy, a
    bare xnorm array. Normalized to a dict (copy; callers may extend it)."""
    raw = None if stats is None else stats.get(name)
    if raw is None:
        return {}
    if isinstance(raw, dict):
        st = dict(raw)
        if "xnorm" not in st and "sumsq" in st:
            st["xnorm"] = jnp.sqrt(st["sumsq"])
        return st
    return {"xnorm": raw}


def _co_abssum(stats, name):
    base, _, leaf = name.rpartition(".")
    partner = _CO_PARTNER.get(leaf)
    if partner is None:
        return None
    pname = f"{base}.{partner}" if base else partner
    raw = None if stats is None else stats.get(pname)
    if isinstance(raw, dict):
        return raw.get("abssum")
    return None


def apply_prune(bp, stats: Optional[Dict], G, pcfg: PruneConfig,
                prunable: Dict[str, tuple], with_mask: bool = False):
    """Score every prunable weight and zero the pruned entries (destructive).
    RO's masked RMSprop steps keep them zero mid-round and ``ro_fit``
    re-applies the prune after the final round, so exact sparsity survives.

    ``stats`` maps linear name -> per-channel stats: either the full dict of
    ``block_io_stats_full`` / ``Engine.calibration_snapshot()["stats"]``
    (from which xnorm is derived), or — legacy — a bare xnorm array. The
    method resolves through the ``core/scores.py`` registry; a score whose
    declared ``needs`` aren't present in ``stats`` raises.

    ``with_mask=True`` additionally returns the 0/1 keep-mask tree (same
    structure as ``bp``, all-ones at non-prunable leaves) — the contract
    ``ro.ro_fit`` expects from its ``prune_fn``."""
    entry = SC.get_score(pcfg.method)
    keep = jax.tree_util.tree_map(
        lambda p: jnp.ones(p.shape, jnp.bool_), bp) if with_mask else None
    for name, path in prunable.items():
        w = tree_get(bp, path)
        if w is None:
            continue
        w_oi = SC.to_oi(w)
        st = _stat_entry(stats, name)
        st["alpha"] = pcfg.alpha
        if entry.grad is not None:
            g = tree_get(G, path)
            if g is None:
                raise ValueError(
                    f"score {entry.name!r} blends a {entry.grad} gradient "
                    f"but none was provided for {name!r}")
            st["grad"] = SC.to_oi(g)
        if "abssum" in entry.needs:
            co = _co_abssum(stats, name)
            if co is not None:
                st["co_abssum"] = co
        missing = [k for k in entry.needs if k not in st]
        if missing:
            raise ValueError(
                f"score {entry.name!r} needs stats {missing} for {name!r}; "
                f"available: {sorted(set(st) - {'alpha'})} — collect full "
                "stats (block_io_stats_full or Engine.calib_taps)")
        s = entry.fn(w_oi, st)
        mask = M.make_mask(s, pcfg.pattern, pcfg.sparsity)
        bp = tree_set(bp, path, SC.from_oi(jnp.where(mask, w_oi, 0)))
        if with_mask:
            keep = tree_set(keep, path, SC.from_oi(mask))
    return (bp, keep) if with_mask else bp


# ---------------------------------------------------------------------------
# per-block Alg. 1
# ---------------------------------------------------------------------------

def prune_block(block_fn, bp, xs, pcfg: PruneConfig, prunable, key,
                grad_chunk: int = 8, G_override=None):
    """Returns (pruned bp, report dict). ``report["seconds"]`` is pure
    compute: the block's jitted programs are AOT-compiled ahead of the timer
    (their XLA time lands in ``report["compile_seconds"]``) and the result is
    ``block_until_ready`` before the clock is read. (The RO rounds' own scan
    programs still compile lazily inside the timed region on the first
    block; later blocks hit the jit cache.)"""
    method = pcfg.method
    entry = SC.get_score(method)
    needs_grad = entry.grad is not None
    needs_ro = entry.ro

    stats_j = jax.jit(lambda b, x: block_io_stats_full(block_fn, b, x))
    grad_j = jax.jit(lambda b, x: regional_grad_rms(block_fn, b, x, grad_chunk))
    prune_j = jax.jit(lambda b, st, g: apply_prune(b, st, g, pcfg, prunable))
    prune_mask_j = jax.jit(
        lambda b, st, g: apply_prune(b, st, g, pcfg, prunable, with_mask=True))

    # -- compile phase (excluded from report["seconds"]) --------------------
    tc0 = time.perf_counter()
    stats_abs = jax.eval_shape(stats_j, bp, xs)[1]
    G_abs = None
    if needs_grad:
        G_abs = (jax.tree_util.tree_map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), G_override)
            if G_override is not None else jax.eval_shape(grad_j, bp, xs))
    stats_j.lower(bp, xs).compile()
    if needs_grad and G_override is None:
        grad_j.lower(bp, xs).compile()
    prune_j.lower(bp, stats_abs, G_abs).compile()
    if needs_ro:
        prune_mask_j.lower(bp, stats_abs, G_abs).compile()
    compile_s = time.perf_counter() - tc0

    # -- compute phase ------------------------------------------------------
    t0 = time.perf_counter()
    G = None
    if needs_grad:
        G = G_override if G_override is not None else grad_j(bp, xs)
    dense_out, stats = stats_j(bp, xs)

    report: Dict[str, Any] = {"method": method, "compile_seconds": compile_s}
    if not needs_ro:
        bp = prune_j(bp, stats, G)
        jax.block_until_ready(bp)
        report["seconds"] = time.perf_counter() - t0
        return bp, report

    # K x [prune -> RO] (steps 3-9)
    def prune_fn(bp_):
        _, st = stats_j(bp_, xs)  # fresh layer inputs; G reused (paper Sec 4.1)
        return prune_mask_j(bp_, st, G)  # (bp, keep-mask) for masked RO steps

    bp, ro_losses = RO.ro_fit(block_fn, bp, xs, dense_out, pcfg, key, prune_fn)

    # steps 10-11: recompute gradient, final prune with fresh statistics
    if needs_grad:
        G = grad_j(bp, xs)
    _, stats = stats_j(bp, xs)
    bp = prune_j(bp, stats, G)
    jax.block_until_ready(bp)
    report["ro_losses"] = [float(l) for l in ro_losses]
    report["seconds"] = time.perf_counter() - t0
    return bp, report


# ---------------------------------------------------------------------------
# model-level driver
# ---------------------------------------------------------------------------

def embed_calibration(model: Model, params, calib) -> jnp.ndarray:
    """calib: tokens (N, S) int32, or frames (N, S, D) for audio."""
    if model.cfg.family == "audio":
        return calib.astype(model.param_dtype)
    return jnp.take(params["embed"], calib, axis=0)


def prune_model(model: Model, params, calib, pcfg: PruneConfig,
                progress: Callable = None):
    """Prune every block of `model`. Returns (params, report list).

    calib: (N, S) token ids (or (N, S, D) frames). Embeddings, LM head and
    final norms are excluded from pruning, as in the paper.
    """
    cfg = model.cfg
    prunable = B.prunable_table(cfg)
    block_fn = make_block_fn(cfg)
    key = jax.random.PRNGKey(pcfg.seed)

    xs = embed_calibration(model, params, calib)
    blocks = params["blocks"]
    prop_j = jax.jit(lambda b, x: block_fn(b, x))

    # full-model gradient for the GBLM baseline (computed once, per-sample RMS)
    gblm_G = None
    if pcfg.method != "sparsegpt" and SC.get_score(pcfg.method).grad == "full":
        gblm_G = _gblm_grads(model, params, calib)

    reports = []
    new_blocks = blocks

    shared_fn = None
    if cfg.family == "hybrid":
        params, shared_rep = _prune_hybrid_shared(model, params, xs, pcfg, key)
        reports.append(shared_rep)
        shared_fn = jax.jit(
            lambda b, x: make_shared_block_fn(cfg)(b, x))

    for l in range(cfg.num_layers):
        if cfg.family == "hybrid" and l % cfg.hybrid_attn_every == 0:
            xs = shared_fn(params["shared_attn"], xs)
        bp = jax.tree_util.tree_map(lambda a: a[l], blocks)
        key, sub = jax.random.split(key)
        if pcfg.method == "sparsegpt":
            from repro.core.sparsegpt import sparsegpt_prune_block
            bp, rep = sparsegpt_prune_block(block_fn, bp, xs, pcfg, prunable)
        else:
            G_l = (jax.tree_util.tree_map(lambda a: a[l], gblm_G)
                   if gblm_G is not None else None)
            bp, rep = prune_block(block_fn, bp, xs, pcfg, prunable, sub,
                                  G_override=G_l)
        rep["layer"] = l
        xs = prop_j(bp, xs)
        new_blocks = jax.tree_util.tree_map(
            lambda a, b: a.at[l].set(b), new_blocks, bp)
        reports.append(rep)
        if progress:
            progress(l, rep)

    out = dict(params)
    out["blocks"] = new_blocks
    return out, reports


def reprune_from_stats(model: Model, params, stats, pcfg: PruneConfig,
                       calib=None, progress: Callable = None):
    """Online re-prune: re-score and re-prune every block against collected
    per-linear traffic stats. Returns new params (dense weights, zeroed where
    pruned) — callers re-pack compressed storage themselves (see
    ``Engine.repack``).

    ``stats``: the ``"stats"`` pytree of ``Engine.calibration_snapshot()`` —
    name -> {"sumsq", "abssum", "sum", "count"} arrays stacked over layers
    (leading dim ``num_layers``). This is a pure re-score + re-prune pass:
    ``entry.ro`` is ignored (a serving engine cannot afford block-sequential
    RO rounds mid-traffic). Gradient-blend scores replay ``calib`` tokens
    (any (N, S) window of recent traffic — ragged N is fine) for the
    regional gradients while the channel stats stay live; xnorm-family
    scores need no forward at all.
    """
    cfg = model.cfg
    if cfg.family == "hybrid":
        raise ValueError("online re-prune does not cover the hybrid shared "
                         "block (its stats aggregate over application sites)")
    entry = SC.get_score(pcfg.method)
    prunable = B.prunable_table(cfg)
    block_fn = make_block_fn(cfg)
    prop_j = jax.jit(lambda b, x: block_fn(b, x))
    grad_j = jax.jit(lambda b, x: regional_grad_rms(block_fn, b, x))
    prune_j = jax.jit(lambda b, st, g: apply_prune(b, st, g, pcfg, prunable))

    xs = None
    if entry.grad is not None:
        if calib is None:
            raise ValueError(
                f"score {pcfg.method!r} blends a gradient; pass calib (recent "
                "traffic tokens) to replay the regional backward")
        xs = embed_calibration(model, params, calib)

    blocks = params["blocks"]
    new_blocks = blocks
    for l in range(cfg.num_layers):
        bp = jax.tree_util.tree_map(lambda a: a[l], blocks)
        st_l = {name: {k: jnp.asarray(v)[l] for k, v in d.items()}
                for name, d in stats.items()}
        G = grad_j(bp, xs) if xs is not None else None
        bp = prune_j(bp, st_l, G)
        if xs is not None:
            xs = prop_j(bp, xs)
        new_blocks = jax.tree_util.tree_map(
            lambda a, b: a.at[l].set(b), new_blocks, bp)
        if progress:
            progress(l, {"method": pcfg.method, "layer": l})

    out = dict(params)
    out["blocks"] = new_blocks
    return out


def _gblm_grads(model: Model, params, calib):
    """Full-model per-sample CE gradient RMS over the block weights (GBLM)."""
    def loss_fn(p, batch):
        return model.loss(p, batch)[0]

    batches = {"tokens": calib[:, :-1][:, None, :], "labels": calib[:, 1:][:, None, :]}
    G = full_model_grad_rms(loss_fn, params, batches, chunk=2)
    return G["blocks"]


def _prune_hybrid_shared(model: Model, params, xs, pcfg: PruneConfig, key):
    """Zamba2: the shared attention block is pruned ONCE with statistics
    aggregated over all of its application sites (weight sharing makes the
    paper's per-site sequential recipe ill-posed; see DESIGN.md)."""
    cfg = model.cfg
    shared_fn = make_shared_block_fn(cfg)
    block_fn = make_block_fn(cfg)
    prop_shared = jax.jit(lambda b, x: shared_fn(b, x))
    prop_mamba = jax.jit(lambda b, x: block_fn(b, x))

    # collect inputs at every application site with dense weights
    site_inputs = []
    x = xs
    for l in range(cfg.num_layers):
        if l % cfg.hybrid_attn_every == 0:
            site_inputs.append(x)
            x = prop_shared(params["shared_attn"], x)
        bp = jax.tree_util.tree_map(lambda a: a[l], params["blocks"])
        x = prop_mamba(bp, x)
    xs_sites = jnp.concatenate(site_inputs, axis=0)  # sites as extra samples

    prunable = B.PRUNABLE["hybrid_shared"]
    if pcfg.method == "sparsegpt":
        from repro.core.sparsegpt import sparsegpt_prune_block
        shared_bp, rep = sparsegpt_prune_block(shared_fn, params["shared_attn"],
                                               xs_sites, pcfg, prunable)
    else:
        shared_bp, rep = prune_block(shared_fn, params["shared_attn"], xs_sites,
                                     pcfg, prunable, key)
    rep["layer"] = "shared_attn"
    out = dict(params)
    out["shared_attn"] = shared_bp
    return out, rep


# ---------------------------------------------------------------------------
# sparsity verification
# ---------------------------------------------------------------------------

def model_sparsity_report(model: Model, params) -> Dict[str, float]:
    """Achieved zero-fraction per prunable weight (averaged over layers).
    All means land on host in ONE ``jax.device_get`` (one blocking transfer
    for the whole report, not one per weight)."""
    prunable = B.prunable_table(model.cfg)
    means = {}
    for name, path in prunable.items():
        w = tree_get(params["blocks"], path)
        if w is None:
            continue
        means[name] = jnp.mean((w == 0).astype(jnp.float32))
    if model.cfg.family == "hybrid":
        for name, path in B.PRUNABLE["hybrid_shared"].items():
            w = tree_get(params["shared_attn"], path)
            if w is not None:
                means["shared." + name] = jnp.mean((w == 0).astype(jnp.float32))
    host = jax.device_get(means)
    return {k: float(v) for k, v in host.items()}
