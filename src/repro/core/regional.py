"""Regional gradients and per-linear input statistics for one decoder block.

The paper's RGS loss (Sec 4.1):  L_RGS^l(X_n) = || f^l(X_n) ||_2 , one backward
per calibration sample, gradients aggregated as RMS over samples (Eq. 3).

Everything here is pure and jit-able; per-sample gradients are accumulated
with a ``lax.scan`` over sample chunks so peak memory stays O(block), which is
the paper's headline efficiency property.
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers


def _resolve_chunk(n: int, chunk: int) -> int:
    """Largest c <= chunk with n % c == 0 (mirrors analysis/vmem.py
    resolve_block, kept local to avoid a core->analysis import). Live-traffic
    calibration windows produce ragged N — prime N degrades to c=1 rather
    than crashing, and the RMS denominator stays the exact sample count."""
    for c in range(min(chunk, n), 0, -1):
        if n % c == 0:
            return c
    return 1


def make_tapped_lin(taps: Dict[str, Dict[str, jnp.ndarray]]):
    """A ``lin`` backend that records per-input-channel running stats
    ({"sumsq", "abssum", "sum", "count"} per linear — layers.input_stats)."""
    return layers.stats_lin(lambda name, p, x: layers.linear(p, x), taps)


def make_tapped_elin(taps: Dict[str, Dict[str, jnp.ndarray]]):
    """Expert einsum backend recording expert-conditional input stats.

    xin: (B, E, C, In) -> taps[name]: stats dict with (E, In) sums and (E,)
    counts. Only routed (slot-filled) tokens contribute: ``occ`` is the
    routing occupancy (B, E, C) the MoE dispatch passes alongside the expert
    buffers, and it masks the sums — so garbage (or merely zero-filled)
    values in unrouted slots can neither contaminate the per-expert ||X||
    stats nor inflate the token counts behind mean/std scores.
    """

    def elin(name, w, xin, eq, occ=None):
        x32 = xin.astype(jnp.float32)
        if occ is None:
            occf = jnp.ones(xin.shape[:-1], jnp.float32)
        else:
            occf = occ.astype(jnp.float32)
        xw = x32 * occf[..., None]
        st = {"sumsq": jnp.sum(x32 * xw, axis=(0, 2)),     # (E, In)
              "abssum": jnp.sum(jnp.abs(xw), axis=(0, 2)),
              "sum": jnp.sum(xw, axis=(0, 2)),
              "count": jnp.sum(occf, axis=(0, 2))}         # (E,)
        taps[name] = layers.acc_stats(taps.get(name), st)
        return jnp.einsum(eq, xin, w)

    return elin


def block_io_stats_full(block_fn: Callable, bp, xs: jnp.ndarray
                        ) -> Tuple[jnp.ndarray, Dict[str, Dict[str, jnp.ndarray]]]:
    """One instrumented forward over the whole calibration set.

    block_fn(bp, x, lin=, elin=) -> out.  xs: (N, S, D) calibration inputs.
    Returns (dense_out (N,S,D), stats dict name -> {"sumsq", "abssum",
    "sum", "count"}) — the same per-linear layout Engine.calibration_snapshot
    exports, so every registered score consumes either source unchanged.
    """
    taps: Dict[str, Dict[str, jnp.ndarray]] = {}
    out = block_fn(bp, xs, lin=make_tapped_lin(taps), elin=make_tapped_elin(taps))
    return out, taps


def block_io_stats(block_fn: Callable, bp, xs: jnp.ndarray
                   ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Back-compat wrapper: (dense_out, xnorm dict name->(.., in) L2 norms)."""
    out, stats = block_io_stats_full(block_fn, bp, xs)
    xnorm = {k: jnp.sqrt(v["sumsq"]) for k, v in stats.items()}
    return out, xnorm


def regional_grad_rms(block_fn: Callable, bp, xs: jnp.ndarray, chunk: int = 8):
    """RMS of per-sample regional gradients (Eq. 3). xs: (N, S, D).

    Returns a pytree matching ``bp`` (float32 leaves).
    """
    N = xs.shape[0]
    chunk = _resolve_chunk(N, chunk)

    def rgs_loss(bp_, x1):
        out = block_fn(bp_, x1[None])
        out = out.astype(jnp.float32)
        return jnp.sqrt(jnp.sum(out * out))

    gfn = jax.grad(rgs_loss)

    def body(acc, xc):  # xc: (chunk, S, D)
        gs = jax.vmap(lambda x1: gfn(bp, x1))(xc)
        acc = jax.tree_util.tree_map(
            lambda a, g: a + jnp.sum(g.astype(jnp.float32) ** 2, axis=0), acc, gs)
        return acc, 0

    acc0 = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), bp)
    xs_c = xs.reshape(N // chunk, chunk, *xs.shape[1:])
    acc, _ = jax.lax.scan(body, acc0, xs_c)
    return jax.tree_util.tree_map(lambda a: jnp.sqrt(a / N), acc)


def full_model_grad_rms(loss_fn: Callable, params, batches, chunk: int = 2):
    """GBLM-style full-model gradient RMS (the expensive baseline the paper
    contrasts against). loss_fn(params, batch)->scalar; batches: pytree with
    leading dim N (per-sample batches)."""
    N = jax.tree_util.tree_leaves(batches)[0].shape[0]
    chunk = _resolve_chunk(N, chunk)

    gfn = jax.grad(loss_fn)

    def body(acc, bc):
        gs = jax.vmap(lambda b: gfn(params, b))(bc)
        acc = jax.tree_util.tree_map(
            lambda a, g: a + jnp.sum(g.astype(jnp.float32) ** 2, axis=0), acc, gs)
        return acc, 0

    acc0 = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    bc = jax.tree_util.tree_map(
        lambda b: b.reshape(N // chunk, chunk, *b.shape[1:]), batches)
    acc, _ = jax.lax.scan(body, acc0, bc)
    return jax.tree_util.tree_map(lambda a: jnp.sqrt(a / N), acc)
