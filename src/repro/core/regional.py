"""Regional gradients and per-linear input statistics for one decoder block.

The paper's RGS loss (Sec 4.1):  L_RGS^l(X_n) = || f^l(X_n) ||_2 , one backward
per calibration sample, gradients aggregated as RMS over samples (Eq. 3).

Everything here is pure and jit-able; per-sample gradients are accumulated
with a ``lax.scan`` over sample chunks so peak memory stays O(block), which is
the paper's headline efficiency property.
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers


def make_tapped_lin(taps: Dict[str, jnp.ndarray]):
    """A ``lin`` backend that records per-input-channel sum-of-squares."""

    def lin(name, p, xin):
        flat = xin.reshape(-1, xin.shape[-1]).astype(jnp.float32)
        ss = jnp.sum(flat * flat, axis=0)
        taps[name] = taps.get(name, 0.0) + ss
        return layers.linear(p, xin)

    return lin


def make_tapped_elin(taps: Dict[str, jnp.ndarray]):
    """Expert einsum backend recording expert-conditional input sumsq.

    xin: (B, E, C, In) -> taps[name]: (E, In). Only routed (slot-filled)
    tokens contribute, which generalizes Wanda's ||X_j|| per expert.
    """

    def elin(name, w, xin, eq):
        x32 = xin.astype(jnp.float32)
        ss = jnp.sum(x32 * x32, axis=(0, 2))  # (E, In)
        taps[name] = taps.get(name, 0.0) + ss
        return jnp.einsum(eq, xin, w)

    return elin


def block_io_stats(block_fn: Callable, bp, xs: jnp.ndarray
                   ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """One instrumented forward over the whole calibration set.

    block_fn(bp, x, lin=, elin=) -> out.  xs: (N, S, D) calibration inputs.
    Returns (dense_out (N,S,D), xnorm dict name->(.., in) L2 norms).
    """
    taps: Dict[str, jnp.ndarray] = {}
    out = block_fn(bp, xs, lin=make_tapped_lin(taps), elin=make_tapped_elin(taps))
    xnorm = {k: jnp.sqrt(v) for k, v in taps.items()}
    return out, xnorm


def regional_grad_rms(block_fn: Callable, bp, xs: jnp.ndarray, chunk: int = 8):
    """RMS of per-sample regional gradients (Eq. 3). xs: (N, S, D).

    Returns a pytree matching ``bp`` (float32 leaves).
    """
    N = xs.shape[0]
    chunk = min(chunk, N)
    assert N % chunk == 0, f"N={N} not divisible by grad chunk={chunk}"

    def rgs_loss(bp_, x1):
        out = block_fn(bp_, x1[None])
        out = out.astype(jnp.float32)
        return jnp.sqrt(jnp.sum(out * out))

    gfn = jax.grad(rgs_loss)

    def body(acc, xc):  # xc: (chunk, S, D)
        gs = jax.vmap(lambda x1: gfn(bp, x1))(xc)
        acc = jax.tree_util.tree_map(
            lambda a, g: a + jnp.sum(g.astype(jnp.float32) ** 2, axis=0), acc, gs)
        return acc, 0

    acc0 = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), bp)
    xs_c = xs.reshape(N // chunk, chunk, *xs.shape[1:])
    acc, _ = jax.lax.scan(body, acc0, xs_c)
    return jax.tree_util.tree_map(lambda a: jnp.sqrt(a / N), acc)


def full_model_grad_rms(loss_fn: Callable, params, batches, chunk: int = 2):
    """GBLM-style full-model gradient RMS (the expensive baseline the paper
    contrasts against). loss_fn(params, batch)->scalar; batches: pytree with
    leading dim N (per-sample batches)."""
    N = jax.tree_util.tree_leaves(batches)[0].shape[0]
    chunk = min(chunk, N)
    assert N % chunk == 0

    gfn = jax.grad(loss_fn)

    def body(acc, bc):
        gs = jax.vmap(lambda b: gfn(params, b))(bc)
        acc = jax.tree_util.tree_map(
            lambda a, g: a + jnp.sum(g.astype(jnp.float32) ** 2, axis=0), acc, gs)
        return acc, 0

    acc0 = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    bc = jax.tree_util.tree_map(
        lambda b: b.reshape(N // chunk, chunk, *b.shape[1:]), batches)
    acc, _ = jax.lax.scan(body, acc0, bc)
    return jax.tree_util.tree_map(lambda a: jnp.sqrt(a / N), acc)
