"""Regional Optimization (paper Sec 4.2).

Minimizes  L_ro = ( f_dense(x) - f_pruned(x) )^2  over the weights of one
decoder block, with per-sample RMSprop updates at lr=3e-7 (paper defaults).

The paper performs one forward+backward+update per RO sample (M=32 samples
per round, K=5 rounds). We run that loop as a ``lax.scan`` so a whole RO round
is a single compiled program.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import PruneConfig


def rmsprop_init(params):
    return jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def rmsprop_update(params, grads, state, lr, decay=0.99, eps=1e-8):
    new_state = jax.tree_util.tree_map(
        lambda v, g: decay * v + (1 - decay) * jnp.square(g.astype(jnp.float32)),
        state, grads)
    new_params = jax.tree_util.tree_map(
        lambda p, g, v: (p.astype(jnp.float32)
                         - lr * g.astype(jnp.float32) / (jnp.sqrt(v) + eps)
                         ).astype(p.dtype),
        params, grads, new_state)
    return new_params, new_state


def select_ro_inputs(key, xs: jnp.ndarray, dense_out: jnp.ndarray, m: int):
    """Randomly pick M of the N calibration inputs without replacement."""
    n = xs.shape[0]
    idx = jax.random.permutation(key, n)[:m]
    return xs[idx], dense_out[idx]


def ro_round(block_fn: Callable, bp, opt_state, xs_ro: jnp.ndarray,
             dense_ro: jnp.ndarray, lr: float):
    """One RO round: per-sample MSE step against the dense block output.

    xs_ro: (M, S, D) inputs; dense_ro: (M, S, D) frozen dense outputs.
    Returns (bp, opt_state, mean_loss_before_updates).
    """

    def ro_loss(bp_, x1, y1):
        out = block_fn(bp_, x1[None])[0]
        d = out.astype(jnp.float32) - y1.astype(jnp.float32)
        return jnp.mean(d * d)

    vg = jax.value_and_grad(ro_loss)

    def body(carry, xy):
        bp_, st = carry
        x1, y1 = xy
        loss, g = vg(bp_, x1, y1)
        bp_, st = rmsprop_update(bp_, g, st, lr)
        return (bp_, st), loss

    (bp, opt_state), losses = jax.lax.scan(body, (bp, opt_state), (xs_ro, dense_ro))
    return bp, opt_state, losses


def ro_fit(block_fn: Callable, bp, xs: jnp.ndarray, dense_out: jnp.ndarray,
           pcfg: PruneConfig, key, prune_fn: Callable = None):
    """Full K-round RO loop for one block, with optional per-round re-pruning
    (Alg. 1 steps 3-9: prune -> RO -> prune -> RO ...).

    prune_fn(bp) -> bp applies the current RGS mask destructively.
    Returns (bp, per-round mean losses).
    """
    opt_state = rmsprop_init(bp)
    round_losses = []
    for k in range(pcfg.ro_iters):
        if prune_fn is not None:
            bp = prune_fn(bp)
        key, sub = jax.random.split(key)
        xs_ro, dense_ro = select_ro_inputs(sub, xs, dense_out, pcfg.ro_samples)
        bp, opt_state, losses = ro_round(block_fn, bp, opt_state, xs_ro,
                                         dense_ro, pcfg.ro_lr)
        round_losses.append(losses.mean())
    return bp, jnp.stack(round_losses)
