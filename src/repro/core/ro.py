"""Regional Optimization (paper Sec 4.2).

Minimizes  L_ro = ( f_dense(x) - f_pruned(x) )^2  over the weights of one
decoder block, with per-sample RMSprop updates at lr=3e-7 (paper defaults).

The paper performs one forward+backward+update per RO sample (M=32 samples
per round, K=5 rounds). We run that loop as a ``lax.scan`` so a whole RO round
is a single compiled program.

Sparsity discipline: RMSprop steps are masked so pruned entries can never
regrow mid-round, the second-moment state is zeroed wherever a re-prune
lands (a later resurrection starts from fresh variance, not pre-prune
gradients), and ``ro_fit`` re-applies the prune after the *final* round —
so its output satisfies the mask pattern exactly for every ``ro_iters``
(``kernels.ops.sparsity_check24`` passes and the serving engine's
``compressed24=auto`` packing engages instead of silently falling back
to dense).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import PruneConfig


def rmsprop_init(params):
    return jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def mask_grads(grads, mask):
    """Zero gradients at pruned (mask == 0) positions."""
    return jax.tree_util.tree_map(
        lambda g, m: g * m.astype(g.dtype), grads, mask)


def zero_masked_state(state, mask):
    """Drop second-moment accumulators at pruned (mask == 0) positions, so a
    weight that is re-pruned between rounds carries no stale f32 variance
    into a later resurrection."""
    return jax.tree_util.tree_map(
        lambda v, m: v * m.astype(v.dtype), state, mask)


def rmsprop_update(params, grads, state, lr, decay=0.99, eps=1e-8, mask=None):
    """Per-sample RMSprop step. ``mask`` (same tree as params, 1 = keep,
    0 = pruned) zeroes the gradient at pruned entries before BOTH the
    second-moment accumulation and the parameter step: a pruned weight
    neither moves nor accumulates variance, so RO cannot regrow it."""
    if mask is not None:
        grads = mask_grads(grads, mask)
    new_state = jax.tree_util.tree_map(
        lambda v, g: decay * v + (1 - decay) * jnp.square(g.astype(jnp.float32)),
        state, grads)
    new_params = jax.tree_util.tree_map(
        lambda p, g, v: (p.astype(jnp.float32)
                         - lr * g.astype(jnp.float32) / (jnp.sqrt(v) + eps)
                         ).astype(p.dtype),
        params, grads, new_state)
    return new_params, new_state


def select_ro_inputs(key, xs: jnp.ndarray, dense_out: jnp.ndarray, m: int):
    """Randomly pick M of the N calibration inputs without replacement."""
    n = xs.shape[0]
    idx = jax.random.permutation(key, n)[:m]
    return xs[idx], dense_out[idx]


def ro_round(block_fn: Callable, bp, opt_state, xs_ro: jnp.ndarray,
             dense_ro: jnp.ndarray, lr: float, mask=None):
    """One RO round: per-sample MSE step against the dense block output.

    xs_ro: (M, S, D) inputs; dense_ro: (M, S, D) frozen dense outputs;
    mask: optional 0/1 keep-mask tree threaded into every RMSprop step.
    Returns (bp, opt_state, losses, mean_loss): ``losses`` is the (M,)
    per-sample loss array, each entry evaluated *before* that sample's
    update; ``mean_loss`` is its scalar mean.
    """

    def ro_loss(bp_, x1, y1):
        out = block_fn(bp_, x1[None])[0]
        d = out.astype(jnp.float32) - y1.astype(jnp.float32)
        return jnp.mean(d * d)

    vg = jax.value_and_grad(ro_loss)

    def body(carry, xy):
        bp_, st = carry
        x1, y1 = xy
        loss, g = vg(bp_, x1, y1)
        bp_, st = rmsprop_update(bp_, g, st, lr, mask=mask)
        return (bp_, st), loss

    (bp, opt_state), losses = jax.lax.scan(body, (bp, opt_state), (xs_ro, dense_ro))
    return bp, opt_state, losses, losses.mean()


def _call_prune_fn(prune_fn: Callable, bp):
    """prune_fn(bp) -> (bp, keep_mask) under the current contract; a legacy
    prune_fn returning a bare block is accepted (no keep-mask, so update
    masking / state zeroing are skipped for it)."""
    out = prune_fn(bp)
    if isinstance(out, tuple):
        return out
    return out, None


def ro_fit(block_fn: Callable, bp, xs: jnp.ndarray, dense_out: jnp.ndarray,
           pcfg: PruneConfig, key, prune_fn: Callable = None):
    """Full K-round RO loop for one block, with per-round re-pruning AND a
    final re-prune (Alg. 1 steps 3-9: prune -> RO -> prune -> RO -> prune),
    so the returned block satisfies the mask pattern exactly for every
    ``ro_iters`` value — including 1.

    prune_fn(bp) -> (bp, keep_mask) applies the current RGS mask
    destructively and returns the 0/1 keep-mask tree (ones at non-prunable
    leaves). The mask gates every RMSprop step of the following round, and
    the optimizer's second-moment state is zeroed at pruned positions on
    each re-prune.

    Returns (bp, round_losses): ``round_losses[k]`` is round k's mean
    per-sample pre-update loss (the scalar ``ro_round`` now returns).
    """
    opt_state = rmsprop_init(bp)
    round_losses = []
    mask = None
    for k in range(pcfg.ro_iters):
        if prune_fn is not None:
            bp, mask = _call_prune_fn(prune_fn, bp)
            if mask is not None:
                opt_state = zero_masked_state(opt_state, mask)
        key, sub = jax.random.split(key)
        xs_ro, dense_ro = select_ro_inputs(sub, xs, dense_out, pcfg.ro_samples)
        bp, opt_state, _, mean_loss = ro_round(block_fn, bp, opt_state, xs_ro,
                                               dense_ro, pcfg.ro_lr, mask=mask)
        round_losses.append(mean_loss)
    if prune_fn is not None:
        # the fix: without this, the final round's updates (dense under the
        # legacy contract) would land after the last mask application and
        # the returned block would violate the sparsity pattern.
        bp, _ = _call_prune_fn(prune_fn, bp)
    return bp, jnp.stack(round_losses)
