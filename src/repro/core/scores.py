"""Pruning scores. Canonical weight layout here is ``w_oi`` = (..., out, in);
the pruner transposes native (in, out) weights (and (E, in, out) expert
stacks) into this layout before scoring.

  magnitude:  |W|                                   (Han et al.)
  wanda:      |W| * ||X_j||_2                        (Eq. 1)
  rgs/gblm:   (alpha * G + ||X_j||_2) * |W|          (Eq. 4 / Eq. 2)
  stade:      |W| * std(X_j)                         (arXiv 2503.22451)
  connect:    |W| * sqrt(sum|X_j| * sum|X_out,i|)    (CoNNect-style)

G is the RMS over per-sample gradients (Eq. 3); for RGS the gradient is the
*regional* one (block-local L2 loss), for GBLM it is the full-model CE grad.

Every score is registered in ``SCORES`` as a ``(w_oi, stats) -> score``
function plus a declared stats requirement; ``PruneConfig.method`` resolves
through this one table (pruner, benchmarks, launch CLI). ``stats`` is a
per-linear dict; which keys a score reads is declared in ``needs``:

  xnorm      (..., in)   L2 norm of each input channel over calib tokens
  sumsq      (..., in)   running sum of x_j^2          (xnorm = sqrt(sumsq))
  abssum     (..., in)   running sum of |x_j|
  sum        (..., in)   running sum of x_j
  count      () / (E,)   weighted token count behind the sums
  grad       (.., out, in)  gradient RMS in w_oi layout (entry.grad != None)
  alpha      scalar      RGS blend weight (from PruneConfig)
  co_abssum  (..., out)  partner linear's abssum (connect co-activation);
                         optional — the score degrades to sqrt(abssum) alone

``entry.grad`` names which gradient feeds ``stats["grad"]`` ("regional" |
"full"); ``entry.ro`` marks methods followed by regional-optimization rounds.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax.numpy as jnp


def magnitude_score(w_oi: jnp.ndarray) -> jnp.ndarray:
    return jnp.abs(w_oi).astype(jnp.float32)


def wanda_score(w_oi: jnp.ndarray, xnorm: jnp.ndarray) -> jnp.ndarray:
    """xnorm: (..., in) L2 norm of each input channel over calibration tokens."""
    return jnp.abs(w_oi).astype(jnp.float32) * xnorm[..., None, :].astype(jnp.float32)


def rgs_score(w_oi: jnp.ndarray, xnorm: jnp.ndarray, g_oi: jnp.ndarray,
              alpha: float) -> jnp.ndarray:
    """Regional Gradient Score (paper Eq. 4). g_oi: gradient RMS, (.., out, in)."""
    return (alpha * g_oi.astype(jnp.float32)
            + xnorm[..., None, :].astype(jnp.float32)) * jnp.abs(w_oi).astype(jnp.float32)


# GBLM uses the same blend with a full-model gradient (Eq. 2)
gblm_score = rgs_score


def stade_score(w_oi: jnp.ndarray, sumsq: jnp.ndarray, xsum: jnp.ndarray,
                count: jnp.ndarray) -> jnp.ndarray:
    """STADE's std-based metric: |W_ij| * std(X_j), std over calib tokens.

    For zero-mean channels this equals Wanda's metric up to a global 1/sqrt(n)
    scale (rank-invariant); channels carrying a large DC offset are demoted.
    """
    n = jnp.maximum(jnp.asarray(count, jnp.float32), 1.0)
    if n.ndim:  # per-expert counts (E,) against (E, in) sums
        n = n[..., None]
    mean = xsum.astype(jnp.float32) / n
    var = sumsq.astype(jnp.float32) / n - mean * mean
    std = jnp.sqrt(jnp.maximum(var, 0.0))
    return jnp.abs(w_oi).astype(jnp.float32) * std[..., None, :]


def connect_score(w_oi: jnp.ndarray, abssum: jnp.ndarray,
                  co_abssum: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """CoNNect-style co-activation score S_ij = |W_ij| * sqrt(A_j * B_i):
    A_j = sum|X_j| over the linear's own inputs, B_i = the partner linear's
    abssum over *its* inputs — for a gate/up projection that partner is the
    block's down projection, whose input j == this linear's output channel i,
    closing the rank-1 connectivity factorization. Without a partner the
    score degrades to |W| * sqrt(A_j)."""
    a = abssum.astype(jnp.float32)[..., None, :]          # (..., 1, in)
    if co_abssum is None:
        co = jnp.sqrt(a)
    else:
        b = co_abssum.astype(jnp.float32)[..., :, None]   # (..., out, 1)
        co = jnp.sqrt(a * b)
    return jnp.abs(w_oi).astype(jnp.float32) * co


def to_oi(w: jnp.ndarray) -> jnp.ndarray:
    """Native (in, out) / (E, in, out) -> canonical (out, in) / (E, out, in)."""
    return jnp.swapaxes(w, -1, -2)


def from_oi(w_oi: jnp.ndarray) -> jnp.ndarray:
    return jnp.swapaxes(w_oi, -1, -2)


# ---------------------------------------------------------------------------
# the registry


@dataclasses.dataclass(frozen=True)
class ScoreEntry:
    name: str
    fn: Callable  # (w_oi, stats: dict) -> (..., out, in) float32 score
    needs: Tuple[str, ...] = ()  # stat keys the fn reads (beyond alpha)
    grad: Optional[str] = None   # None | "regional" | "full"
    ro: bool = False             # RO rounds follow the prune


SCORES: Dict[str, ScoreEntry] = {}


def _register(name: str, needs: Tuple[str, ...] = (),
              grad: Optional[str] = None, ro: bool = False):
    def deco(fn):
        SCORES[name] = ScoreEntry(name, fn, needs, grad, ro)
        return fn
    return deco


def get_score(name: str) -> ScoreEntry:
    try:
        return SCORES[name]
    except KeyError:
        raise ValueError(
            f"unknown pruning score {name!r}; registered: {available()} "
            "(sparsegpt is driven separately by core/sparsegpt.py)") from None


def available() -> Tuple[str, ...]:
    return tuple(sorted(SCORES))


@_register("magnitude")
def _magnitude(w_oi, stats):
    return magnitude_score(w_oi)


@_register("wanda", needs=("xnorm",))
def _wanda(w_oi, stats):
    return wanda_score(w_oi, stats["xnorm"])


@_register("wanda++ro", needs=("xnorm",), ro=True)
def _wanda_ro(w_oi, stats):
    return wanda_score(w_oi, stats["xnorm"])


@_register("wanda++rgs", needs=("xnorm", "grad"), grad="regional")
def _wanda_rgs(w_oi, stats):
    return rgs_score(w_oi, stats["xnorm"], stats["grad"], stats["alpha"])


@_register("wanda++", needs=("xnorm", "grad"), grad="regional", ro=True)
def _wanda_pp(w_oi, stats):
    return rgs_score(w_oi, stats["xnorm"], stats["grad"], stats["alpha"])


@_register("gblm", needs=("xnorm", "grad"), grad="full")
def _gblm(w_oi, stats):
    return gblm_score(w_oi, stats["xnorm"], stats["grad"], stats["alpha"])


@_register("stade", needs=("sumsq", "sum", "count"))
def _stade(w_oi, stats):
    return stade_score(w_oi, stats["sumsq"], stats["sum"], stats["count"])


@_register("connect", needs=("abssum",))
def _connect(w_oi, stats):
    return connect_score(w_oi, stats["abssum"], stats.get("co_abssum"))
