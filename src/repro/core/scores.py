"""Pruning scores. Canonical weight layout here is ``w_oi`` = (..., out, in);
the pruner transposes native (in, out) weights (and (E, in, out) expert
stacks) into this layout before scoring.

  magnitude:  |W|                                   (Han et al.)
  wanda:      |W| * ||X_j||_2                        (Eq. 1)
  rgs/gblm:   (alpha * G + ||X_j||_2) * |W|          (Eq. 4 / Eq. 2)

G is the RMS over per-sample gradients (Eq. 3); for RGS the gradient is the
*regional* one (block-local L2 loss), for GBLM it is the full-model CE grad.
"""
from __future__ import annotations

import jax.numpy as jnp


def magnitude_score(w_oi: jnp.ndarray) -> jnp.ndarray:
    return jnp.abs(w_oi).astype(jnp.float32)


def wanda_score(w_oi: jnp.ndarray, xnorm: jnp.ndarray) -> jnp.ndarray:
    """xnorm: (..., in) L2 norm of each input channel over calibration tokens."""
    return jnp.abs(w_oi).astype(jnp.float32) * xnorm[..., None, :].astype(jnp.float32)


def rgs_score(w_oi: jnp.ndarray, xnorm: jnp.ndarray, g_oi: jnp.ndarray,
              alpha: float) -> jnp.ndarray:
    """Regional Gradient Score (paper Eq. 4). g_oi: gradient RMS, (.., out, in)."""
    return (alpha * g_oi.astype(jnp.float32)
            + xnorm[..., None, :].astype(jnp.float32)) * jnp.abs(w_oi).astype(jnp.float32)


# GBLM uses the same blend with a full-model gradient (Eq. 2)
gblm_score = rgs_score


def to_oi(w: jnp.ndarray) -> jnp.ndarray:
    """Native (in, out) / (E, in, out) -> canonical (out, in) / (E, out, in)."""
    return jnp.swapaxes(w, -1, -2)


def from_oi(w_oi: jnp.ndarray) -> jnp.ndarray:
    return jnp.swapaxes(w_oi, -1, -2)
