"""SparseGPT baseline (Frantar & Alistarh 2023) — Hessian/OBS column solver.

Layer-wise: H = X^T X + damp*I from calibration inputs; columns are pruned
in order with the OBS weight update distributing each pruned weight's error
onto not-yet-processed columns via the Cholesky factor of H^{-1}.

The paper uses SparseGPT as its strongest weight-update baseline (Table 1);
we implement the N:M and unstructured variants. Expert-stacked (3-D) weights
are handled by vmapping the solver over the leading expert axis.
"""
from __future__ import annotations

import time
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import PruneConfig
from repro.core import scores as SC
from repro.core.pruner import tree_get, tree_set
from repro.models import layers


def make_gram_lin(grams: Dict[str, jnp.ndarray]):
    def lin(name, p, xin):
        flat = xin.reshape(-1, xin.shape[-1]).astype(jnp.float32)
        grams[name] = grams.get(name, 0.0) + flat.T @ flat
        return layers.linear(p, xin)
    return lin


def make_gram_elin(grams: Dict[str, jnp.ndarray]):
    def elin(name, w, xin, eq, occ=None):
        x32 = xin.astype(jnp.float32)  # (B, E, C, In)
        if occ is not None:  # mask unrouted capacity slots out of the Gram
            x32 = x32 * occ.astype(jnp.float32)[..., None]
        g = jnp.einsum("beci,becj->eij", x32, x32)
        grams[name] = grams.get(name, 0.0) + g
        return jnp.einsum(eq, xin, w)
    return elin


def block_gram_stats(block_fn, bp, xs):
    grams: Dict[str, jnp.ndarray] = {}
    out = block_fn(bp, xs, lin=make_gram_lin(grams), elin=make_gram_elin(grams))
    return out, grams


def _solve_2d(w_oi, gram, pcfg: PruneConfig, percdamp=0.01):
    """OBS solver for one (out, in) weight with Gram (in, in)."""
    d_out, d_in = w_oi.shape
    w = w_oi.astype(jnp.float32)
    damp = percdamp * jnp.mean(jnp.diag(gram)) + 1e-8
    H = gram + damp * jnp.eye(d_in, dtype=jnp.float32)
    Hinv = jnp.linalg.inv(H)
    Lc = jnp.linalg.cholesky(Hinv)  # lower; U = Lc.T is the GPTQ upper factor
    U = Lc.T
    diagU = jnp.diag(U)

    nm = pcfg.pattern_nm()
    if nm is not None:
        n, m = nm
    else:
        n, m = None, 128  # unstructured: block threshold per 128 columns
        m = min(m, d_in)
    assert d_in % m == 0, (d_in, m)
    n_groups = d_in // m
    col_idx = jnp.arange(d_in, dtype=jnp.int32)

    def group_body(g, w):
        j0 = g * m
        wg = jax.lax.dynamic_slice(w, (0, j0), (d_out, m))  # (out, m)
        dg = jax.lax.dynamic_slice(diagU, (j0,), (m,))
        score = (wg / dg[None, :]) ** 2
        if nm is not None:
            # keep top-n per row within the group
            s_i, s_j = score[..., :, None], score[..., None, :]
            ii = jnp.arange(m)
            rank = jnp.sum((s_j > s_i) | ((s_j == s_i) & (ii[None, :] < ii[:, None])), -1)
            keep = rank < n
        else:
            flat = jnp.sort(score.reshape(-1))
            thresh = flat[jnp.int32(score.size * pcfg.sparsity)]
            keep = score >= thresh

        def col_body(t, w):
            j = j0 + t
            wc = jax.lax.dynamic_slice(w, (0, j), (d_out, 1))[:, 0]
            keep_c = jax.lax.dynamic_slice(keep, (0, t), (d_out, 1))[:, 0]
            d = diagU[j]
            err = jnp.where(keep_c, 0.0, wc) / d
            # distribute error onto future columns (row j of U, cols > j)
            urow = U[j] * (col_idx > j)
            w = w - err[:, None] * urow[None, :]
            w = jax.lax.dynamic_update_slice(
                w, jnp.where(keep_c, wc, 0.0)[:, None], (0, j))
            return w

        w = jax.lax.fori_loop(0, m, col_body, w)
        return w

    w = jax.lax.fori_loop(0, n_groups, group_body, w)
    return w.astype(w_oi.dtype)


def sparsegpt_prune_block(block_fn, bp, xs, pcfg: PruneConfig, prunable):
    """Prune one block with SparseGPT. Returns (bp, report)."""
    t0 = time.perf_counter()
    _, grams = jax.jit(lambda b, x: block_gram_stats(block_fn, b, x))(bp, xs)
    solve = jax.jit(lambda w, g: _solve_2d(w, g, pcfg))
    solve_e = jax.jit(jax.vmap(lambda w, g: _solve_2d(w, g, pcfg)))
    for name, path in prunable.items():
        w = tree_get(bp, path)
        if w is None:
            continue
        w_oi = SC.to_oi(w)
        gram = grams[name]
        if w_oi.ndim == 2:
            # gram tap is (in, in) built from all tokens
            new = solve(w_oi, gram)
        else:
            # expert-stacked: gram (E, in, in), weights (E, out, in)
            new = solve_e(w_oi, gram)
        bp = tree_set(bp, path, SC.from_oi(new))
    return bp, {"method": "sparsegpt", "seconds": time.perf_counter() - t0}
