from repro.data.calibration import (  # noqa: F401
    calibration_batch, eval_batch, synthetic_lm_stream, SyntheticLM,
)
