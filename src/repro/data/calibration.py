"""Synthetic C4-like token streams (offline container — no real C4).

The generator is a seeded first-order Markov chain over a Zipfian vocabulary:
unigram frequencies follow a power law (like natural text) and bigram
structure gives models something learnable, so perplexity deltas between
pruning methods are meaningful. Everything is deterministic in (seed, shape),
and the iterator supports skip-ahead for fault-tolerant restart.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class SyntheticLM:
    vocab_size: int
    seed: int = 0
    # high branching + flat-ish Zipf keep benchmark models capacity-limited
    # (like real LLMs), so pruning-method deltas are visible
    branching: int = 16
    zipf_a: float = 1.05

    def _tables(self):
        rng = np.random.default_rng(self.seed)
        V = self.vocab_size
        # Zipfian unigram distribution
        ranks = np.arange(1, V + 1, dtype=np.float64)
        uni = ranks ** (-self.zipf_a)
        uni /= uni.sum()
        # each token has `branching` successors drawn from the unigram dist
        succ = rng.choice(V, size=(V, self.branching), p=uni)
        sp = rng.dirichlet(np.ones(self.branching) * 0.5, size=V)
        return uni, succ.astype(np.int32), sp.astype(np.float32)

    def sample(self, n: int, seq_len: int, stream_seed: int = 0) -> np.ndarray:
        """Returns int32 tokens (n, seq_len). Deterministic in all args."""
        uni, succ, sp = self._tables()
        rng = np.random.default_rng((self.seed, stream_seed))
        out = np.empty((n, seq_len), np.int32)
        cur = rng.choice(self.vocab_size, size=n, p=uni)
        out[:, 0] = cur
        # vectorized Markov walk with 10% unigram restarts (noise floor)
        for t in range(1, seq_len):
            u = rng.random(n)
            choice = (rng.random(n)[:, None] < np.cumsum(sp[cur], -1)).argmax(-1)
            nxt = succ[cur, choice]
            restart = u < 0.1
            if restart.any():
                nxt[restart] = rng.choice(self.vocab_size, size=int(restart.sum()), p=uni)
            out[:, t] = nxt
            cur = nxt
        return out


def calibration_batch(vocab_size: int, n: int, seq_len: int, seed: int = 0):
    """The paper's 128-sample C4 calibration set, synthetic version."""
    return jnp.asarray(SyntheticLM(vocab_size, seed).sample(n, seq_len, stream_seed=1))


def eval_batch(vocab_size: int, n: int, seq_len: int, seed: int = 0):
    """Held-out eval stream (different stream_seed => disjoint from calib)."""
    toks = SyntheticLM(vocab_size, seed).sample(n, seq_len + 1, stream_seed=2)
    return {"tokens": jnp.asarray(toks[:, :-1]), "labels": jnp.asarray(toks[:, 1:])}


def synthetic_lm_stream(vocab_size: int, batch: int, seq_len: int,
                        seed: int = 0, start_step: int = 0) -> Iterator[dict]:
    """Infinite deterministic training stream with skip-ahead restart:
    batch at step k is a pure function of (seed, k), so resuming from a
    checkpoint at step k replays the exact same data order."""
    gen = SyntheticLM(vocab_size, seed)
    step = start_step
    while True:
        toks = gen.sample(batch, seq_len + 1, stream_seed=1000 + step)
        yield {"tokens": jnp.asarray(toks[:, :-1]),
               "labels": jnp.asarray(toks[:, 1:]),
               "step": step}
        step += 1
