from repro.distributed.sharding import (  # noqa: F401
    cache_shardings, input_shardings, make_rules, mesh_dp_axes, logical_spec_tree, param_shardings,
)
from repro.distributed.roofline import (  # noqa: F401
    collective_bytes, roofline_report, HW,
)
