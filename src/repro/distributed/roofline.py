"""Roofline analysis from compiled XLA artifacts (no hardware needed).

Three terms per (arch x shape x mesh), all in seconds:
    compute    = HLO_FLOPs   / (chips * peak_FLOPs)
    memory     = HLO_bytes   / (chips * HBM_bw)
    collective = coll_bytes  / (chips * link_bw)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``; collective
bytes are parsed out of the optimized HLO text by summing operand sizes of
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Dict, Optional

# TPU v5e per chip (assignment-specified constants)
@dataclass(frozen=True)
class _HW:
    peak_flops: float = 197e12   # bf16 FLOP/s
    hbm_bw: float = 819e9        # bytes/s
    link_bw: float = 50e9        # ICI bytes/s per link
    hbm_bytes: float = 16e9      # capacity

HW = _HW()

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?:\([^)]*\)|(?P<single>\S+))\s+"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", )

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def xla_cost(compiled) -> Dict[str, float]:
    """Normalized ``compiled.cost_analysis()``.

    Newer jaxlibs return a per-program *list* of dicts (one entry per
    executable); older ones return the dict directly. Either way this
    returns a plain dict (empty if the backend reports nothing).
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost or {})


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes of every collective op in the HLO, by op kind.

    Uses the *result* shape on the lhs of each `<shape> <op-name>(...)` line;
    for -done/-start pairs only the -start is counted.
    """
    out: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(
            r"^(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(?P<shape>\([^=]*?\)|\S+\[[^\]]*\]\S*)\s*"
            r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
            r"(?P<suffix>-start|-done)?\(", line)
        if not m or m.group("suffix") == "-done":
            continue
        op = m.group("op")
        b = _shape_bytes(m.group("shape"))
        out[op] = out.get(op, 0) + b
    return out


def roofline_report(cost: dict, coll: Dict[str, int], n_chips: int,
                    model_flops: Optional[float] = None,
                    bytes_per_chip: Optional[float] = None) -> Dict[str, float]:
    """cost: compiled.cost_analysis(); coll: collective_bytes() output.

    cost_analysis flops/bytes on an SPMD module are *per-program* (one chip's
    share); collective bytes from HLO are likewise per-participant.
    """
    flops = float(cost.get("flops", 0.0))
    if bytes_per_chip is None:
        bytes_per_chip = float(cost.get("bytes accessed", 0.0))
    coll_total = float(sum(coll.values()))
    t_compute = flops / HW.peak_flops
    t_memory = bytes_per_chip / HW.hbm_bw
    t_coll = coll_total / HW.link_bw
    terms = {"compute_s": t_compute, "memory_s": t_memory, "collective_s": t_coll}
    dom = max(terms, key=terms.get)
    rep = dict(terms)
    rep["bottleneck"] = dom
    rep["hlo_flops_per_chip"] = flops
    rep["hlo_bytes_per_chip"] = bytes_per_chip
    rep["collective_bytes_per_chip"] = coll_total
    rep["coll_breakdown"] = dict(coll)
    if model_flops is not None:
        rep["model_flops_total"] = model_flops
        # useful-fraction: model math vs compiled math across the whole mesh
        rep["useful_flop_frac"] = (model_flops / (flops * n_chips)) if flops else 0.0
        ideal = model_flops / (n_chips * HW.peak_flops)
        rep["roofline_frac"] = ideal / max(max(terms.values()), 1e-30)
    return rep


def analytic_flops(cfg, shape, accum_steps: int = 1, remat: bool = False,
                   remat_groups: int = 0) -> float:
    """Exact executed FLOPs per step, summed over the whole mesh.

    Needed because XLA's HloCostAnalysis visits ``while`` bodies once: every
    lax.scan (layers, grad-accum, flash chunks, SSD chunks) is undercounted
    by its trip count in ``compiled.cost_analysis()``. We know every matmul
    in the model, so we count them directly: matmul params (6ND train / 2ND
    fwd), the quadratic attention term, MoE capacity overhead, and the remat
    recompute factor (8/6 with full block remat).
    """
    toks = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    n_active = cfg.active_param_count()
    fwd_mult = 2.0
    train = shape.kind == "train"
    # attention quadratic term (per layer fwd): 4 * B * S^2 * H * hd ;
    # decode: S_q=1 against S_kv cache -> 4 * B * S * H * hd
    attn_fl = 0.0
    hd = cfg.resolved_head_dim
    n_attn_layers = 0
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        n_attn_layers = cfg.num_layers
    elif cfg.family == "hybrid":
        n_attn_layers = (cfg.num_layers + cfg.hybrid_attn_every - 1) // cfg.hybrid_attn_every
    if n_attn_layers:
        if shape.kind == "decode":
            attn_fl = 4.0 * shape.global_batch * shape.seq_len * cfg.num_heads * hd
        else:
            s_eff = shape.seq_len ** 2 / 2.0 if cfg.causal else shape.seq_len ** 2
            attn_fl = 4.0 * shape.global_batch * s_eff * cfg.num_heads * hd
        attn_fl *= n_attn_layers
    # SSD chunk math (intra-chunk quadratic within Q): ~ 2*B*S*Q*(H*P + N(H->G))
    ssd_fl = 0.0
    if cfg.family in ("ssm", "hybrid") and shape.kind != "decode":
        Q = min(cfg.ssm_chunk, shape.seq_len)
        H, Pd, N = cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state
        per_tok = 2 * Q * H * Pd + 2 * Q * H * N + 2 * H * Pd * N * 2
        ssd_fl = cfg.num_layers * toks * per_tok
    # MoE capacity overhead: tokens processed = k * capacity_factor vs k
    moe_over = 1.0
    if cfg.family == "moe":
        # only the expert-FFN share is inflated by the capacity factor
        expert_share = (cfg.top_k * 3 * cfg.d_model * cfg.d_ff * cfg.num_layers) / max(n_active, 1)
        moe_over = 1.0 + expert_share * (cfg.moe_capacity_factor - 1.0)

    base = fwd_mult * n_active * toks * moe_over + attn_fl + ssd_fl
    if train:
        # bwd = 2x fwd; full remat re-runs fwd once (4x); two-level scan
        # remat re-runs group fwds too (5x)
        factor = 3.0
        if remat:
            factor = 5.0 if remat_groups else 4.0
        base *= factor
    return base


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE); decode: D=batch
    new tokens. Forward-only shapes use 2*N*D."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        toks = shape.global_batch * shape.seq_len
        return 6.0 * n * toks
    if shape.kind == "prefill":
        toks = shape.global_batch * shape.seq_len
        return 2.0 * n * toks
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


# ---------------------------------------------------------------------------
# analytic traffic / collective model (scan-trip-count-aware)
# ---------------------------------------------------------------------------

def analytic_bytes(cfg, shape, *, param_bytes_per_chip: float,
                   cache_bytes_per_chip: float = 0.0, accum_steps: int = 1,
                   dp: int = 1, tp: int = 1, act_bytes: int = 2,
                   act_reads: float = 12.0) -> float:
    """Per-chip HBM traffic (bytes) per step.

    Model: weights stream from HBM once per microbatch per pass (fwd,
    recompute, bwd for train => 3x), activations move `act_reads` times per
    token per layer (writes+reads of residual/intermediates; the flash path
    keeps S^2 scores out of HBM), optimizer update touches params+grads+
    states once, decode reads the KV cache once.
    """
    toks = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    toks_chip = toks / max(dp, 1)
    train = shape.kind == "train"
    passes = 3.0 if train else 1.0
    w_traffic = param_bytes_per_chip * passes * (accum_steps if train else 1)

    d_eff = cfg.d_model / max(tp, 1) if cfg.family != "moe" else cfg.d_model
    act_traffic = toks_chip * cfg.num_layers * d_eff * act_bytes * act_reads
    if train:
        act_traffic *= 2.5  # bwd re-reads saved carries + writes grads of acts

    opt_traffic = 0.0
    if train:
        # grads(f32 r+w) + mu/nu (r+w) + params (r+w)
        opt_traffic = param_bytes_per_chip * (2 * 4 / 2 + 2 * 2 / 2 * 2 + 2)

    return w_traffic + act_traffic + opt_traffic + cache_bytes_per_chip


def analytic_collectives(cfg, shape, *, param_bytes_per_chip: float,
                         grad_bytes_per_chip: float = 0.0, accum_steps: int = 1,
                         dp: int = 1, tp: int = 1, pods: int = 1,
                         fsdp: bool = False, act_bytes: int = 2,
                         dense_tp: bool = True, seq_shard: bool = False,
                         moe_local_groups: bool = False) -> Dict[str, float]:
    """Per-chip ICI/DCN bytes per step, by source. Ring-collective cost
    per chip ~ 2*(n-1)/n * payload for all-reduce, (n-1)/n for all-gather.

    dense_tp=False: attention/MLP weights replicated over `model` (only
    experts/vocab sharded) — no Megatron activation all-reduces; instead,
    seq-sharded attention gathers k/v for the local rows.
    moe_local_groups: dispatch groups are shard-local (moe_group_tokens
    aligned with the seq shard), so a2a scales with tokens/(dp*tp).
    """
    out: Dict[str, float] = {}
    toks = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    toks_chip = toks / max(dp, 1)
    train = shape.kind == "train"
    passes = 3.0 if train else 1.0

    if train and dp > 1:
        out["grad_allreduce"] = 2.0 * grad_bytes_per_chip
    if train and fsdp:
        # per-microbatch per-pass weight gather (fwd + recompute + bwd);
        # gathered bytes per chip = shard-group total minus own share
        out["fsdp_allgather"] = param_bytes_per_chip * (dp - 1) * 3 * accum_steps

    n_l_attn = cfg.num_layers if cfg.family != "hybrid" else \
        (cfg.num_layers + cfg.hybrid_attn_every - 1) // cfg.hybrid_attn_every
    if tp > 1 and dense_tp and cfg.family in ("dense", "moe", "vlm", "audio", "hybrid"):
        # Megatron TP: ~2 activation all-reduces per layer (AG+RS under SP —
        # same bytes). Sequence-sharding changes memory, not these bytes.
        ar = 2.0 * toks_chip * cfg.d_model * act_bytes * 2 * cfg.num_layers
        out["tp_allreduce"] = ar * passes
    elif tp > 1 and not dense_tp and seq_shard and cfg.num_heads > 0:
        # replicated dense weights + seq-sharded activations: attention
        # gathers the other (tp-1)/tp of k/v for the locally-owned rows
        kvd = cfg.num_kv_heads * cfg.resolved_head_dim
        gather = 2.0 * toks_chip * kvd * act_bytes * (tp - 1) / tp * n_l_attn
        out["attn_kv_gather"] = gather * passes

    if cfg.family == "moe" and tp > 1:
        toks_moe = toks / (dp * tp) if (moe_local_groups and seq_shard) else toks_chip
        a2a = toks_moe * cfg.top_k * cfg.moe_capacity_factor * cfg.d_model \
            * act_bytes * 2 * cfg.num_layers
        out["moe_alltoall"] = a2a * passes
    return out
