"""Logical-axis sharding: every param dim gets a logical name by path rules,
and a per-(arch x shape-kind) rule table maps logical names to mesh axes.

Robustness: a logical->mesh mapping is dropped automatically when the dim is
not divisible by the mesh axis (e.g. kv_heads=8 on a 16-way model axis, or
qwen2-vl's 12 heads) — the framework never produces an invalid sharding; it
degrades to replication for that dim. This auto-degradation is also why one
rule table serves all 10 assigned architectures.

Default strategy (hillclimbed further in EXPERIMENTS.md §Perf):
  * TP over `model`: attention heads, MLP ffn, experts (EP), vocab
  * DP over `pod`+`data`: batch; FSDP (weights' embed dim over `data`) for
    >=70B configs so params+optimizer fit v5e HBM
  * decode: KV-cache length over `model` (flash-decode style context split)
"""
from __future__ import annotations

import re
import warnings
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig

# ---------------------------------------------------------------------------
# logical axis assignment by path regex (first match wins)
# ---------------------------------------------------------------------------

_PATH_RULES = [
    # embeddings / head (the table's d_model dim is never FSDP-sharded:
    # token gathers against a 2-way-sharded table force SPMD full-remat)
    (r"^embed$", ("vocab", "embed_table")),
    (r"^head$", ("embed_table", "vocab")),
    # attention (leading "layers" dim added automatically for stacked
    # blocks). The 2:4 compressed-serving leaves (w24_vals (K/2, N),
    # w24_idx (K/8, N) packed, mask24 (K, N) — models/blocks.py
    # compress_params24) carry the SAME logical axes as the dense w: the
    # row axis is still the input/embed dim (just /2 or /8 in size — an
    # indivisible shard degrades to replication via the per-dim rule), the
    # column axis is still the TP output dim.
    (r"attn/wq/(w|w24_vals|w24_idx|mask24)$", ("embed", "heads")),
    (r"attn/wk/(w|w24_vals|w24_idx|mask24)$", ("embed", "kv_heads")),
    (r"attn/wv/(w|w24_vals|w24_idx|mask24)$", ("embed", "kv_heads")),
    (r"attn/wo/(w|w24_vals|w24_idx|mask24)$", ("heads", "embed")),
    (r"attn/wq/b$", ("heads",)),
    (r"attn/w[kv]/b$", ("kv_heads",)),
    (r"attn/.*lora_a$", ("embed", None)),
    (r"attn/.*lora_b$", (None, "heads")),
    # MLP
    (r"mlp/w[gu1]/(w|w24_vals|w24_idx|mask24)$", ("embed", "ffn")),
    (r"mlp/w[d2]/(w|w24_vals|w24_idx|mask24)$", ("ffn", "embed")),
    (r"mlp/w\w/b$", (None,)),
    # MoE
    (r"moe/router/(w|w24_vals|w24_idx|mask24)$", ("embed", None)),
    (r"moe/wg$", ("experts", "embed", None)),
    (r"moe/wu$", ("experts", "embed", None)),
    (r"moe/wd$", ("experts", None, "embed")),
    (r"moe/shared/w[gu]/(w|w24_vals|w24_idx|mask24)$", ("embed", "ffn")),
    (r"moe/shared/wd/(w|w24_vals|w24_idx|mask24)$", ("ffn", "embed")),
    # Mamba2
    (r"mamba/in_proj/(w|w24_vals|w24_idx|mask24)$", ("embed", "inner")),
    (r"mamba/out_proj/(w|w24_vals|w24_idx|mask24)$", ("inner", "embed")),
    (r"mamba/conv_w$", (None, "inner")),
    (r"mamba/conv_b$", ("inner",)),
    (r"mamba/(A_log|D|dt_bias)$", ("ssm_heads",)),
    (r"mamba/norm/scale$", ("inner",)),
]


def _path_str(path) -> str:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
        else:
            out.append(str(k))
    return "/".join(out)


def logical_spec_tree(params: Any) -> Any:
    """Pytree of logical-axis tuples matching `params` (shapes or arrays)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat:
        ps = _path_str(path)
        ndim = len(leaf.shape)
        spec: Optional[Tuple] = None
        for pat, logical in _PATH_RULES:
            if re.search(pat, ps):
                spec = tuple(logical)
                break
        if spec is None:
            spec = (None,) * ndim
        # stacked blocks / shared caches carry extra leading dims
        if len(spec) < ndim:
            spec = ("layers",) * (ndim - len(spec)) + spec
        specs.append(spec[:ndim])
    return jax.tree_util.tree_unflatten(treedef, specs)


# ---------------------------------------------------------------------------
# rule tables: logical axis -> mesh axis (or tuple of axes)
#
# Every rule/spec function below needs only a mesh's GEOMETRY (axis names +
# sizes), never its devices, so each accepts either a real jax Mesh or an
# AxisMesh stand-in. The *_pspecs functions return plain PartitionSpecs —
# the static-analysis contract checker (repro.analysis.contracts) evaluates
# the whole rule table across mesh geometries on a 1-device CPU host with
# them; the *_shardings wrappers bind a real Mesh into NamedShardings for
# the runtime programs.
# ---------------------------------------------------------------------------


class AxisMesh:
    """Device-free stand-in for ``jax.sharding.Mesh`` in rule evaluation:
    carries only ``shape`` (axis name -> size) and ``axis_names``."""

    def __init__(self, **axes: int):
        self.shape = dict(axes)
        self.axis_names = tuple(axes)

    def __repr__(self):
        return "AxisMesh(%s)" % ", ".join(
            f"{k}={v}" for k, v in self.shape.items())


def mesh_dp_axes(mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def make_rules(cfg: ModelConfig, mesh, kind: str,
               overrides: Optional[Dict] = None) -> Dict[str, Any]:
    """Logical->mesh rules for (arch, shape-kind). `overrides` is the perf
    hillclimb lever (launch/dryrun.py --rules)."""
    tp = mesh.shape.get("model", 1)
    # FSDP / 2-D weight sharding whenever TP-only weights would blow HBM:
    # training threshold is lower (grads+opt states), inference higher.
    per_chip_tp = cfg.param_count() * 2 / tp
    fsdp = per_chip_tp > (3e9 if kind == "train" else 8e9)
    rules: Dict[str, Any] = {
        "layers": None,
        "vocab": "model",
        "embed": "data" if fsdp else None,
        "embed_table": None,
        "heads": "model",
        "kv_heads": "model",
        "ffn": "model",
        "experts": "model",
        "inner": "model",
        "ssm_heads": "model",
        # activations
        "batch": mesh_dp_axes(mesh),
        "seq": None,
        "kv_len": "model" if kind == "decode" else None,
    }
    # Head-structured dims appear FLATTENED in the param shapes (wq is
    # (embed, heads*hd), wk/wv (embed, kv_heads*hd), mamba's inner is
    # nheads*headdim), so _spec_for's per-dim divisibility check alone would
    # happily split mid-head whenever head_dim picks up the slack (e.g.
    # kv_heads=2 on a 4-way model axis: 2*16=32 divides by 4). A mid-head
    # split is numerically WRONG under SPMD on this jax build — the
    # (count, hd) reshape + rotary split downstream miscompiles (verified:
    # tests/test_serve_distributed.py's divisibility case) — so degrade by
    # the semantic unit, the head COUNT, here where the config is in hand.
    if tp > 1:
        if cfg.num_heads % tp:
            rules["heads"] = None
        if cfg.num_kv_heads % tp:
            rules["kv_heads"] = None
        if (getattr(cfg, "ssm_nheads", 0) or 0) % tp:
            rules["ssm_heads"] = None
            # "inner" also labels dims that are NOT pure nheads*headdim
            # (in_proj's z|x|B|C|dt concat, the conv window's x|B|C): those
            # segments are only ever consumed elementwise or by static
            # slices, which SPMD reshards correctly at any boundary (pinned
            # bit-exact by the mesh parity suite even with the boundary
            # mid-segment). The hazard is the x segment's reshape to
            # (nheads, headdim) for the SSD scan — head-aligned exactly
            # when nheads divides tp's split of d_inner, i.e. this gate.
            rules["inner"] = None
    if overrides:
        rules.update(overrides)
    return rules


def _spec_for(shape, logical, rules, mesh) -> P:
    axes = []
    used = set()
    for dim, lg in zip(shape, logical):
        mesh_ax = rules.get(lg) if lg else None
        if mesh_ax is None:
            axes.append(None)
            continue
        ax_tuple = mesh_ax if isinstance(mesh_ax, tuple) else (mesh_ax,)
        ax_tuple = tuple(a for a in ax_tuple if a in mesh.axis_names and a not in used)
        size = 1
        for a in ax_tuple:
            size *= mesh.shape[a]
        if not ax_tuple or dim % size != 0:
            axes.append(None)  # auto-degrade to replication
            continue
        used.update(ax_tuple)
        axes.append(ax_tuple if len(ax_tuple) > 1 else ax_tuple[0])
    return P(*axes)


def param_pspecs(mesh, cfg: ModelConfig, params: Any, kind: str,
                 overrides: Optional[Dict] = None) -> Any:
    """PartitionSpec pytree for the param tree. ``mesh`` may be an
    :class:`AxisMesh` — only the geometry enters the rule evaluation."""
    rules = make_rules(cfg, mesh, kind, overrides)
    logical = logical_spec_tree(params)
    return jax.tree_util.tree_map(
        lambda leaf, lg: _spec_for(leaf.shape, lg, rules, mesh),
        params, logical,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))


def param_shardings(mesh: Mesh, cfg: ModelConfig, params: Any, kind: str,
                    overrides: Optional[Dict] = None) -> Any:
    """NamedSharding pytree for the param tree (arrays or ShapeDtypeStructs)."""
    return jax.tree_util.tree_map(
        lambda ps: NamedSharding(mesh, ps),
        param_pspecs(mesh, cfg, params, kind, overrides),
        is_leaf=lambda x: isinstance(x, P))


def wave_param_shardings(mesh: Mesh, cfg: ModelConfig, wparams: tuple,
                         kind: str = "decode") -> tuple:
    """Shardings for the serving engine's weight tuple ``(target,)`` or
    ``(target, drafter)``. The self-speculation drafter is a pruned copy of
    the target, so its tree paths hit the same ``_PATH_RULES`` rows —
    including the ``w24_vals``/``w24_idx``/``mask24`` aliases when either
    model serves compressed. Each element is still sharded independently:
    a dense f32 target and a 2:4-compressed drafter get the right specs
    for their own leaf shapes."""
    return tuple(param_shardings(mesh, cfg, p, kind) for p in wparams)


# ---------------------------------------------------------------------------
# input / cache shardings per shape kind
# ---------------------------------------------------------------------------

def input_shardings(mesh: Mesh, cfg: ModelConfig, specs: Dict, kind: str,
                    overrides: Optional[Dict] = None) -> Dict:
    rules = make_rules(cfg, mesh, kind, overrides)
    dp = rules["batch"]
    seq = rules.get("seq")
    out = {}
    for name, s in specs.items():
        nd = len(s.shape)
        if name in ("tokens", "labels", "mask"):
            out[name] = NamedSharding(mesh, _spec_for(s.shape, ("batch", "seq"), rules, mesh))
        elif name in ("frames", "vision_embeds"):
            out[name] = NamedSharding(mesh, _spec_for(s.shape, ("batch", "seq", None), rules, mesh))
        elif name == "token":
            out[name] = NamedSharding(mesh, _spec_for(s.shape, ("batch",), rules, mesh))
        else:  # scalars (pos, ...)
            out[name] = NamedSharding(mesh, P())
    return out


def serve_rules(mesh, cfg: ModelConfig, n_slots: int,
                overrides: Optional[Dict] = None) -> Dict[str, Any]:
    """Logical->mesh rules for the serving engine's runtime state.

    Derived from the one :func:`make_rules` table (kind="decode") with the
    serve-specific deltas:

    * ``batch`` == the slot axis: sharded over the data axes — but only when
      they divide ``n_slots``. An indivisible pool degrades to replication
      with a *warning* instead of failing inside the jitted programs
      (mirroring :func:`_spec_for`'s per-dim divisibility rule).
    * ``kv_len`` / ``pages`` replicated: the engine addresses KV by per-slot
      cache positions and block tables — any slot must reach any position /
      page, so the context-parallel decode split of the dryrun rules does
      not apply. Heads still split over ``model``.
    * an indivisible ``kv_heads`` also warns here (``_spec_for`` would
      silently replicate that dim everywhere it appears).
    """
    rules = make_rules(cfg, mesh, "decode")
    rules["kv_len"] = None
    rules["pages"] = None
    dp = mesh_dp_axes(mesh)
    dsize = 1
    for a in dp:
        dsize *= mesh.shape[a]
    if dsize > 1 and n_slots % dsize != 0:
        warnings.warn(
            f"serve mesh: n_slots={n_slots} is not divisible by the data "
            f"axes {dp} (size {dsize}); slot state and per-slot pools "
            "degrade to replication", RuntimeWarning, stacklevel=2)
        rules["batch"] = None
    tp = mesh.shape.get("model", 1)
    if tp > 1 and cfg.num_kv_heads % tp != 0:
        warnings.warn(
            f"serve mesh: num_kv_heads={cfg.num_kv_heads} is not divisible "
            f"by the model axis ({tp}); KV head dims degrade to "
            "replication", RuntimeWarning, stacklevel=2)
    if overrides:
        rules.update(overrides)
    return rules


def serve_state_shardings(mesh: Mesh, cfg: ModelConfig, spec, cache: Any,
                          pstate: Any, n_slots: int, paged: bool,
                          rules: Optional[Dict] = None) -> Dict[str, Any]:
    """NamedShardings for the serving engine's device-resident state.

    Returns ``{"cache", "slots", "pstate", "repl", "rules"}``:

    * ``cache``: pytree matching ``cache`` — each leaf placed by its
      CacheSpec group's logical axes (``CacheSpec.cache_logical``): slots
      over ``data`` for per-slot pools, KV/SSM heads over ``model``, page
      arenas' page axis replicated (any block table may reference any page).
    * ``slots``: the (n_slots,) spec shared by every SlotState scalar and
      the sampling draws (a pytree prefix — all leaves are slot vectors).
    * ``pstate``: PageState shardings — ``ref`` replicated (the free list is
      global), ``block_tables`` rows over ``data`` with their slots.
    * ``repl``: fully-replicated sharding for wave inputs, PRNG key, and the
      host-mirrored scalars (free pages / prefix registry stay host-side and
      therefore trivially replicated).
    """
    specs = serve_state_pspecs(mesh, cfg, spec, cache, pstate, n_slots,
                               paged, rules)
    ns = lambda ps: NamedSharding(mesh, ps)
    pstate_sh = None
    if specs["pstate"] is not None:
        pstate_sh = type(pstate)(ref=ns(specs["pstate"].ref),
                                 block_tables=ns(specs["pstate"].block_tables))
    return {"cache": jax.tree_util.tree_map(ns, specs["cache"],
                                            is_leaf=lambda x: isinstance(x, P)),
            "slots": ns(specs["slots"]), "pstate": pstate_sh,
            "repl": ns(specs["repl"]), "rules": specs["rules"]}


def serve_state_pspecs(mesh, cfg: ModelConfig, spec, cache: Any,
                       pstate: Any, n_slots: int, paged: bool,
                       rules: Optional[Dict] = None) -> Dict[str, Any]:
    """PartitionSpec-level core of :func:`serve_state_shardings` — accepts
    an :class:`AxisMesh`, so the contract checker can verify the serve-state
    placement rules for any mesh geometry without devices."""
    if rules is None:
        rules = serve_rules(mesh, cfg, n_slots)
    logical = spec.cache_logical(paged)
    cache_sh = jax.tree_util.tree_map(
        lambda leaf, lg: _spec_for(leaf.shape, lg, rules, mesh),
        cache, logical)
    slot_sh = _spec_for((n_slots,), ("batch",), rules, mesh)
    pstate_sh = None
    if pstate is not None:
        pstate_sh = type(pstate)(
            ref=P(),
            block_tables=_spec_for(
                pstate.block_tables.shape, ("batch", None), rules, mesh))
    return {"cache": cache_sh, "slots": slot_sh, "pstate": pstate_sh,
            "repl": P(), "rules": rules}


def cache_shardings(mesh: Mesh, cfg: ModelConfig, cache: Any, kind: str = "decode",
                    overrides: Optional[Dict] = None) -> Any:
    """KV/state cache shardings: (L, B, S, KV, hd) -> batch over dp, S over
    model (context-parallel decode); SSM states: heads over model."""
    rules = make_rules(cfg, mesh, kind, overrides)

    def spec(leaf):
        nd = len(leaf.shape)
        if nd == 5:  # (L|apps, B, S, KV, hd) attention cache
            return _spec_for(leaf.shape, (None, "batch", "kv_len", "kv_heads", None), rules, mesh)
        if nd == 5 - 1:  # (L, B, K-1, conv_dim) conv state
            return _spec_for(leaf.shape, (None, "batch", None, "inner"), rules, mesh)
        return _spec_for(leaf.shape, (None, "batch") + (None,) * (nd - 2), rules, mesh)

    def to_ns(leaf):
        # ssm state (L, B, H, P, N): heads over model, batch over dp
        if len(leaf.shape) == 5 and leaf.dtype == jnp.float32 and cfg.family in ("ssm", "hybrid"):
            return NamedSharding(mesh, _spec_for(
                leaf.shape, (None, "batch", "ssm_heads", None, None), rules, mesh))
        return NamedSharding(mesh, spec(leaf))

    return jax.tree_util.tree_map(to_ns, cache)
