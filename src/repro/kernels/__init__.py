"""Pallas TPU kernels for the pruning/serving hot-spots (see DESIGN.md §4)."""
from repro.kernels.ops import (  # noqa: F401
    compact24, masked_matmul, nm_mask, sparse_matmul24, sparsity_check24,
)
