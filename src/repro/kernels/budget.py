"""Static VMEM budget accounting for the Pallas kernels.

Every kernel module exposes a ``vmem_plan(...)`` hook returning a
:class:`KernelVmemPlan`: the per-grid-step VMEM working set implied by its
block shapes, scratch declarations, and accumulator dtypes, plus any
block-shape divisibility constraints the kernel asserts at call time. The
plan is PURE ARITHMETIC — no tracing, no devices — so the analysis CLI
(``python -m repro.analysis``) and the dryrun sweep (``launch/dryrun.py
--check-vmem``) can reject configurations that cannot compile on real TPUs
from this CPU-only container, where the kernels only ever run through the
Pallas interpreter and would never hit Mosaic's VMEM allocator.

Accounting model (see /opt/skills/guides/pallas_guide.md):

* pallas_call's automatic pipelining DOUBLE-BUFFERS every in/out block
  (the next grid step's HBM->VMEM DMA overlaps this step's compute), so
  block bytes count twice.
* scratch_shapes persist across the grid — single-buffered.
* ``temp_bytes`` covers in-kernel materialized temporaries that Mosaic
  must also place in VMEM (e.g. sparse_matmul24's decompressed dense
  tile); the estimate is documented at each hook.

The total is checked against the kernel's declared ``vmem_limit_bytes``
(each module's ``VMEM_LIMIT_BYTES`` — the same constant passed to
``TPUCompilerParams``, so the check cannot drift from the declaration).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Tuple


def block_bytes(shape: Tuple[int, ...], itemsize: int) -> int:
    n = itemsize
    for d in shape:
        n *= d
    return n


@dataclass
class KernelVmemPlan:
    """Static VMEM working set of one kernel invocation config."""
    kernel: str
    config: Dict[str, int]  # the block/shape parameters the plan was built for
    blocks: Dict[str, int]  # in/out block name -> bytes (single copy)
    scratch: Dict[str, int]  # scratch name -> bytes
    temp_bytes: int  # in-kernel materialized temporaries (estimate)
    limit_bytes: int  # the kernel's declared vmem_limit_bytes
    violations: List[str] = field(default_factory=list)  # constraint failures

    @property
    def total_bytes(self) -> int:
        # double-buffered pipeline blocks + resident scratch + temporaries
        return (2 * sum(self.blocks.values()) + sum(self.scratch.values())
                + self.temp_bytes)

    @property
    def feasible(self) -> bool:
        return not self.violations and self.total_bytes <= self.limit_bytes

    def why_infeasible(self) -> List[str]:
        out = list(self.violations)
        if self.total_bytes > self.limit_bytes:
            out.append(
                f"VMEM {self.total_bytes / 2**20:.1f}MiB > limit "
                f"{self.limit_bytes / 2**20:.0f}MiB")
        return out


def require(plan: KernelVmemPlan, ok: bool, msg: str) -> None:
    if not ok:
        plan.violations.append(msg)
