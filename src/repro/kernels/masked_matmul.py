"""Pallas TPU kernel: masked matmul  y = x @ (W * mask).

Sparse fine-tuning forward: the N:M mask is applied at tile load so the
masked weight tensor is never materialized in HBM (the int8 mask costs 0.5x
extra weight traffic vs 1x for a materialized masked copy; on-the-fly
masking also keeps a single source of truth for W during RO, where pruned
weights may be regrown and re-pruned).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, m_ref, o_ref):
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]
    w = w_ref[...] * m_ref[...].astype(w_ref.dtype)
    o_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32
                          ).astype(o_ref.dtype)


def masked_matmul_pallas(x, w, mask, *, block_m: int = 128, block_n: int = 128,
                         block_k: int = 512,
                         interpret: Optional[bool] = None):
    """x: (M, K); w: (K, N); mask: (K, N) int8/bool. Returns (M, N) f32.
    ``interpret=None`` resolves via ops._interpret_default (True off-TPU —
    a hard-coded True would silently run the Python interpreter on TPU)."""
    if interpret is None:
        from repro.kernels.ops import _interpret_default
        interpret = _interpret_default()
    M, K = x.shape
    N = w.shape[1]
    bm, bn, bk = min(block_m, M), min(block_n, N), min(block_k, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0
    grid = (M // bm, N // bn, K // bk)
    return pl.pallas_call(
        _kernel, grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=interpret,
    )(x, w, mask.astype(jnp.int8))
