"""Pallas TPU kernel: masked matmul  y = x @ (W * mask).

Sparse fine-tuning forward: the N:M mask is applied at tile load so the
masked weight tensor is never materialized in HBM (the int8 mask costs 0.5x
extra weight traffic vs 1x for a materialized masked copy; on-the-fly
masking also keeps a single source of truth for W during RO, where pruned
weights may be regrown and re-pruned).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.budget import KernelVmemPlan, block_bytes, require

VMEM_LIMIT_BYTES = 64 * 1024 * 1024


def _kernel(x_ref, w_ref, m_ref, o_ref):
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]
    w = w_ref[...] * m_ref[...].astype(w_ref.dtype)
    o_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32
                          ).astype(o_ref.dtype)


def masked_matmul_pallas(x, w, mask, *, block_m: int = 128, block_n: int = 128,
                         block_k: int = 512,
                         interpret: Optional[bool] = None):
    """x: (M, K); w: (K, N); mask: (K, N) int8/bool. Returns (M, N) f32.
    ``interpret=None`` resolves via ops._interpret_default (True off-TPU —
    a hard-coded True would silently run the Python interpreter on TPU)."""
    if interpret is None:
        from repro.kernels.ops import _interpret_default
        interpret = _interpret_default()
    M, K = x.shape
    N = w.shape[1]
    bm, bn, bk = min(block_m, M), min(block_n, N), min(block_k, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0
    grid = (M // bm, N // bn, K // bk)
    return pl.pallas_call(
        _kernel, grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        compiler_params=pltpu.TPUCompilerParams(
            # M/N tiles are independent; the K axis revisits the output block
            dimension_semantics=("parallel", "parallel", "arbitrary"),
            vmem_limit_bytes=VMEM_LIMIT_BYTES,
        ),
        interpret=interpret,
    )(x, w, mask.astype(jnp.int8))


def vmem_plan(M: int, K: int, N: int, *, block_m: int = 128,
              block_n: int = 128, block_k: int = 512, x_itemsize: int = 4,
              w_itemsize: int = 4) -> KernelVmemPlan:
    """Static VMEM working set of one ``masked_matmul_pallas`` call (see
    kernels/budget.py). The f32 output block revisits across the K axis and
    the masked weight tile materializes once in VMEM per step."""
    bm, bn, bk = min(block_m, M), min(block_n, N), min(block_k, K)
    blocks = {"x": block_bytes((bm, bk), x_itemsize),
              "w": block_bytes((bk, bn), w_itemsize),
              "mask": block_bytes((bk, bn), 1),
              "out": block_bytes((bm, bn), 4)}
    # the w * mask product tile (w dtype) before the MXU dot
    temp = block_bytes((bk, bn), w_itemsize)
    plan = KernelVmemPlan("masked_matmul", dict(M=M, K=K, N=N, block_m=bm,
                                                block_n=bn, block_k=bk),
                          blocks, {}, temp, VMEM_LIMIT_BYTES)
    require(plan, M % bm == 0, f"M={M} % block_m={bm} != 0")
    require(plan, N % bn == 0, f"N={N} % block_n={bn} != 0")
    require(plan, K % bk == 0, f"K={K} % block_k={bk} != 0")
    return plan
