"""Pallas TPU kernel: fused Wanda/RGS score + exact top-N-of-M mask.

One VMEM pass computes  s = (alpha*G + ||X||_2) * |W|  and the N:M keep-mask
per group of M consecutive inputs — the (score, sort, mask, apply) chain of
the reference implementation collapses into a single HBM read of W (+G).

Ranking uses O(M^2) pairwise comparison with index tie-break instead of a
sort: M is 4 or 8, so the compare tensor stays tiny and fully vectorizes on
the VPU (TPUs have no fast small-sort primitive — this is the TPU-native
replacement, exact by construction).

Tiles are (block_out, block_in) with block_in % M == 0; both dims aligned to
the (8, 128) f32 VMEM layout.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.budget import KernelVmemPlan, block_bytes, require

VMEM_LIMIT_BYTES = 64 * 1024 * 1024


def _nm_rank_mask(s, n: int, m: int):
    """s: (bo, bi) scores -> bool keep mask via exact rank-within-group."""
    bo, bi = s.shape
    g = s.reshape(bo, bi // m, m)
    s_i = g[..., :, None]   # (bo, gi, m, 1)
    s_j = g[..., None, :]   # (bo, gi, 1, m)
    idx = jax.lax.broadcasted_iota(jnp.int32, (m, m), 0)  # i
    jdx = jax.lax.broadcasted_iota(jnp.int32, (m, m), 1)  # j
    gt = s_j > s_i
    eq_lower = (s_j == s_i) & (jdx < idx)
    rank = jnp.sum((gt | eq_lower).astype(jnp.int32), axis=-1)
    return (rank < n).reshape(bo, bi)


def _kernel(w_ref, xnorm_ref, g_ref, mask_ref, *, alpha: float, n: int, m: int,
            use_grad: bool):
    w = w_ref[...].astype(jnp.float32)
    xn = xnorm_ref[...].astype(jnp.float32)  # (1, bi)
    if use_grad:
        gr = g_ref[...].astype(jnp.float32)
        s = (alpha * gr + xn) * jnp.abs(w)
    else:
        s = xn * jnp.abs(w)
    mask_ref[...] = _nm_rank_mask(s, n, m).astype(jnp.int8)


def _kernel_nograd(w_ref, xnorm_ref, mask_ref, *, alpha, n, m):
    _kernel(w_ref, xnorm_ref, None, mask_ref, alpha=alpha, n=n, m=m,
            use_grad=False)


def nm_mask_pallas(w_oi, xnorm, g_oi=None, *, alpha: float = 100.0,
                   n: int = 2, m: int = 4, block_out: int = 256,
                   block_in: int = 512, interpret: Optional[bool] = None):
    """w_oi: (d_out, d_in); xnorm: (d_in,); g_oi: optional (d_out, d_in).

    Returns int8 keep-mask (d_out, d_in) with exactly n of every m kept.
    ``interpret=None`` resolves via ops._interpret_default (True off-TPU —
    a hard-coded True would silently run the Python interpreter on TPU).
    """
    if interpret is None:
        from repro.kernels.ops import _interpret_default
        interpret = _interpret_default()
    d_out, d_in = w_oi.shape
    bo = min(block_out, d_out)
    bi = min(block_in, d_in)
    assert d_out % bo == 0 and d_in % bi == 0 and bi % m == 0
    grid = (d_out // bo, d_in // bi)
    xnorm2 = xnorm.reshape(1, d_in)

    w_spec = pl.BlockSpec((bo, bi), lambda i, j: (i, j))
    x_spec = pl.BlockSpec((1, bi), lambda i, j: (0, j))
    out_spec = pl.BlockSpec((bo, bi), lambda i, j: (i, j))

    # every (i, j) tile is written exactly once — no revisiting axis
    compiler_params = pltpu.TPUCompilerParams(
        dimension_semantics=("parallel", "parallel"),
        vmem_limit_bytes=VMEM_LIMIT_BYTES,
    )
    if g_oi is not None:
        fn = functools.partial(_kernel, alpha=alpha, n=n, m=m, use_grad=True)
        return pl.pallas_call(
            fn, grid=grid,
            in_specs=[w_spec, x_spec, w_spec],
            out_specs=out_spec,
            out_shape=jax.ShapeDtypeStruct((d_out, d_in), jnp.int8),
            compiler_params=compiler_params,
            interpret=interpret,
        )(w_oi, xnorm2, g_oi)
    fn = functools.partial(_kernel_nograd, alpha=alpha, n=n, m=m)
    return pl.pallas_call(
        fn, grid=grid,
        in_specs=[w_spec, x_spec],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((d_out, d_in), jnp.int8),
        compiler_params=compiler_params,
        interpret=interpret,
    )(w_oi, xnorm2)


def vmem_plan(d_out: int, d_in: int, *, block_out: int = 256,
              block_in: int = 512, itemsize: int = 4, use_grad: bool = True,
              m: int = 4) -> KernelVmemPlan:
    """Static VMEM working set of one ``nm_mask_pallas`` call (see
    kernels/budget.py for the accounting model). ``itemsize`` is the W/G
    dtype width; the score math always runs in f32, so the pairwise
    (bo, bi/m, m, m) rank compare dominates the temporaries."""
    bo, bi = min(block_out, d_out), min(block_in, d_in)
    blocks = {"w": block_bytes((bo, bi), itemsize),
              "xnorm": block_bytes((1, bi), itemsize),
              "mask_out": block_bytes((bo, bi), 1)}
    if use_grad:
        blocks["g"] = block_bytes((bo, bi), itemsize)
    # f32 score tile + the (bo, bi/m, m, m) broadcast-compare rank tensor
    temp = block_bytes((bo, bi), 4) + block_bytes((bo, bi // m, m, m), 4)
    plan = KernelVmemPlan("nm_mask", dict(d_out=d_out, d_in=d_in,
                                          block_out=bo, block_in=bi),
                          blocks, {}, temp, VMEM_LIMIT_BYTES)
    require(plan, d_out % bo == 0, f"d_out={d_out} % block_out={bo} != 0")
    require(plan, d_in % bi == 0, f"d_in={d_in} % block_in={bi} != 0")
    require(plan, bi % m == 0, f"block_in={bi} % m={m} != 0")
    return plan
