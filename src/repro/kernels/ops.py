"""Jit'd public wrappers for the Pallas kernels + packing utilities.

``interpret`` defaults to True off-TPU (this container is CPU-only; the
kernel bodies execute in Python for correctness validation) and False on
TPU, where pallas_call lowers to Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.masked_matmul import masked_matmul_pallas
from repro.kernels.nm_mask import nm_mask_pallas
from repro.kernels.paged_attention import paged_attention_pallas
from repro.kernels.sparse_matmul24 import sparse_matmul24_pallas


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("alpha", "n", "m"))
def nm_mask(w_oi, xnorm, g_oi=None, *, alpha: float = 100.0, n: int = 2,
            m: int = 4):
    """Fused score + N:M mask (int8). See kernels/nm_mask.py."""
    return nm_mask_pallas(w_oi, xnorm, g_oi, alpha=alpha, n=n, m=m,
                          interpret=_interpret_default())


@jax.jit
def sparse_matmul24(x, vals, idx):
    """y = x @ decompress_2:4(vals, idx). See kernels/sparse_matmul24.py."""
    return sparse_matmul24_pallas(x, vals, idx,
                                  interpret=_interpret_default())


@jax.jit
def masked_matmul(x, w, mask):
    """y = x @ (w * mask) with the mask applied at tile load."""
    return masked_matmul_pallas(x, w, mask, interpret=_interpret_default())


@functools.partial(jax.jit, static_argnames=("scale", "kv_qscale"))
def paged_attention(q, k_pages, v_pages, block_table, lengths, *,
                    scale: float, kv_qscale=None):
    """Single-query decode attention straight off the paged KV arena.
    See kernels/paged_attention.py for the grid/layout contract."""
    return paged_attention_pallas(q, k_pages, v_pages, block_table, lengths,
                                  scale=scale, kv_qscale=kv_qscale,
                                  interpret=_interpret_default())


# ---------------------------------------------------------------------------
# 2:4 packing (offline, at model-export time)
# ---------------------------------------------------------------------------

def compact24(w) -> tuple:
    """Pack a 2:4-sparse (K, N) weight into (vals, idx), both (K/2, N).

    Within every group of 4 consecutive rows there must be <= 2 nonzeros
    (guaranteed by the 2:4 pruner); ties broken by position.
    """
    K, N = w.shape
    assert K % 4 == 0
    g = w.reshape(K // 4, 4, N)
    is_zero = (g == 0)
    # stable argsort: nonzero positions first, original order preserved
    order = jnp.argsort(is_zero.astype(jnp.int32), axis=1, stable=True)
    top2 = order[:, :2, :].astype(jnp.int8)  # (K/4, 2, N)
    vals = jnp.take_along_axis(g, top2.astype(jnp.int32), axis=1)  # (K/4, 2, N)
    return vals.reshape(K // 2, N), top2.reshape(K // 2, N)


def sparsity_check24(w) -> bool:
    """True iff every group of 4 along K has >= 2 zeros."""
    K, N = w.shape
    g = (w.reshape(K // 4, 4, N) == 0).sum(axis=1)
    return bool((g >= 2).all())
