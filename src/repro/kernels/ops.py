"""Jit'd public wrappers for the Pallas kernels + 2:4 packing utilities.

``interpret`` resolves to True off-TPU (this container is CPU-only; the
kernel bodies execute in Python for correctness validation) and False on
TPU, where pallas_call lowers to Mosaic. The resolution happens INSIDE each
kernel module (``interpret=None`` default) so direct callers get the same
behavior as these wrappers.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.masked_matmul import masked_matmul_pallas
from repro.kernels.nm_mask import nm_mask_pallas
from repro.kernels.paged_attention import paged_attention_pallas
from repro.kernels.sparse_matmul24 import sparse_matmul24_pallas


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("alpha", "n", "m"))
def nm_mask(w_oi, xnorm, g_oi=None, *, alpha: float = 100.0, n: int = 2,
            m: int = 4):
    """Fused score + N:M mask (int8). See kernels/nm_mask.py."""
    return nm_mask_pallas(w_oi, xnorm, g_oi, alpha=alpha, n=n, m=m)


@functools.partial(jax.jit, static_argnames=("w_qscale",))
def sparse_matmul24(x, vals, idx, bias=None, w_qscale=None):
    """y = x @ decompress_2:4(vals, idx) [+ bias], fused in one kernel.
    ``w_qscale``: int8 ``vals`` dequant scale (None == float vals).
    See kernels/sparse_matmul24.py for the packed-index storage contract."""
    return sparse_matmul24_pallas(x, vals, idx, bias=bias, w_qscale=w_qscale)


@jax.jit
def masked_matmul(x, w, mask):
    """y = x @ (w * mask) with the mask applied at tile load."""
    return masked_matmul_pallas(x, w, mask)


@functools.partial(jax.jit, static_argnames=("scale", "kv_qscale"))
def paged_attention(q, k_pages, v_pages, block_table, lengths, *,
                    scale: float, kv_qscale=None):
    """Single-query decode attention straight off the paged KV arena.
    See kernels/paged_attention.py for the grid/layout contract."""
    return paged_attention_pallas(q, k_pages, v_pages, block_table, lengths,
                                  scale=scale, kv_qscale=kv_qscale)


# ---------------------------------------------------------------------------
# 2:4 compacted storage (offline, at engine-build / model-export time)
#
# A 2:4-sparse (K, N) weight packs into
#   vals (K/2, N)  the two surviving values per group of 4 along K
#   idx  (K/8, N)  uint8, each value's offset in its group, 2 bits per
#                  entry: byte b holds entries [4b, 4b+4) of the logical
#                  (K/2, N) int index plane, entry t in bits [2t, 2t+2)
#
# so compressed bytes / dense bytes = (itemsize/2 + 1/8) / itemsize:
# 0.5625x for bf16, 0.53125x for f32 (compressed24_ratio below). The byte
# layout is chosen so the kernel's in-tile unpack is a repeat + shift
# (kernels/sparse_matmul24.py) — no gathers on the TPU vector units.
# ---------------------------------------------------------------------------

def _pack24_idx(idx2):
    """Logical 2-bit index plane (..., K/2, N) in [0,4) -> packed uint8
    (..., K/8, N)."""
    g = idx2.astype(jnp.uint8).reshape(*idx2.shape[:-2], idx2.shape[-2] // 4,
                                       4, idx2.shape[-1])
    return (g[..., 0, :] | (g[..., 1, :] << 2) | (g[..., 2, :] << 4)
            | (g[..., 3, :] << 6))


def unpack24_idx(idx):
    """Packed uint8 (..., K/8, N) -> logical index plane (..., K/2, N) int32."""
    parts = jnp.stack([(idx >> (2 * t)) & 3 for t in range(4)], axis=-2)
    return parts.reshape(*idx.shape[:-2], idx.shape[-2] * 4,
                         idx.shape[-1]).astype(jnp.int32)


def compact24(w) -> tuple:
    """Pack a 2:4-sparse (..., K, N) weight into (vals, packed idx).

    Within every group of 4 consecutive K rows there must be <= 2 nonzeros
    (guaranteed by the 2:4 pruner); groups with > 2 zeros keep their
    nonzeros plus leading zero positions, ties broken by position (stable).
    Leading dims (stacked layer axes) pack elementwise.
    """
    K = w.shape[-2]
    assert K % 8 == 0, f"2:4 packing needs K % 8 == 0, got K={K}"
    g = w.reshape(*w.shape[:-2], K // 4, 4, w.shape[-1])
    is_zero = (g == 0)
    # stable argsort: nonzero positions first, original order preserved
    order = jnp.argsort(is_zero.astype(jnp.int32), axis=-2, stable=True)
    top2 = order[..., :2, :]  # (..., K/4, 2, N)
    vals = jnp.take_along_axis(g, top2, axis=-2)
    idx2 = top2.reshape(*w.shape[:-2], K // 2, w.shape[-1])
    return vals.reshape(*w.shape[:-2], K // 2, w.shape[-1]), _pack24_idx(idx2)


def decompress24(vals, idx):
    """(vals, packed idx) -> dense (..., K, N), bit-exact inverse of
    ``compact24`` on pruner output (zeros come back as +0.0, matching
    ``jnp.where(mask, w, 0)``)."""
    K2, N = vals.shape[-2], vals.shape[-1]
    idx2 = unpack24_idx(idx)
    v = vals.reshape(*vals.shape[:-2], K2 // 2, 2, N)
    i = idx2.reshape(*vals.shape[:-2], K2 // 2, 2, N)
    off = jnp.arange(4, dtype=idx2.dtype).reshape(4, 1)  # group-local row
    dense = (jnp.where(i[..., 0:1, :] == off, v[..., 0:1, :], 0)
             + jnp.where(i[..., 1:2, :] == off, v[..., 1:2, :], 0))
    return dense.reshape(*vals.shape[:-2], K2 * 2, N).astype(vals.dtype)


def sparsity_check24(w) -> bool:
    """True iff every group of 4 along K (axis -2) has >= 2 zeros."""
    K = w.shape[-2]
    if K % 4 != 0:
        return False
    g = (w.reshape(*w.shape[:-2], K // 4, 4, w.shape[-1]) == 0).sum(axis=-2)
    return bool((g >= 2).all())


def compressed24_ratio(itemsize: int) -> float:
    """Compressed (vals + packed 2-bit idx) bytes as a fraction of dense
    bytes for a weight of the given itemsize: 0.5625 for bf16, 0.53125 for
    f32. The single source of truth for every projection/accounting site
    (launch/dryrun.py, benchmarks) — derived from the storage format above,
    so it cannot drift from what compact24 actually emits."""
    return (0.5 * itemsize + 0.125) / itemsize
