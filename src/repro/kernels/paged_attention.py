"""Pallas TPU kernel: paged attention over a KV page arena (Sq=1 decode and
Sq>1 chunked-prefill modes).

The serve engine's paged pool (serve/paging.py) stores KV in a shared
(n_pages, page_size, KV, hd) arena per layer, with per-slot block tables
mapping position-ordered blocks to pages. Since PR 2 the decode read was a
``.at[block_table].get`` gather that materialises the full
(B, max_blocks*page_size) KV view in HBM every step — exactly the traffic
the paged pool exists to avoid. This kernel computes the attention directly
against the arena, vLLM-style (Kwon et al., PagedAttention): the grid walks
each slot's block table page-by-page and folds every page into a flash-style
online-softmax carry (Dao et al.), so per-step KV reads are O(tokens
actually cached) instead of O(max_blocks * page_size).

Grid / layout contract
----------------------
  grid = (B, max_blocks); the page axis is innermost, so the m/l/acc
  scratch carries one slot's online softmax across its pages (the output
  block revisits, like the K loop of kernels/sparse_matmul24.py).

  scalar prefetch (PrefetchScalarGridSpec): block_table (B, MB) int32 and
  lengths (B,) int32 — prefetched so the k/v BlockSpec index_map can steer
  each HBM->VMEM page fetch straight off the table:

      page(b, j) = block_table[b, j]   if j*page_size < lengths[b] (clamped)
                   0                   otherwise (dead fetch, masked off)

  q:        (B, KV, G, hd)            one query token per slot, GQA-grouped
            or (B, Sq, KV, G, hd)     Sq query positions per slot (chunked
                                      prefill: the prefill-chunk lane of the
                                      unified step program)
  k/v:      (n_pages, page_size, KV, hd)  the shared arena (fp32/bf16/int8)
  block_table: (B, MB) int32          ``n_pages`` == unmapped block
  lengths:  (B,) int32                valid cache tokens per slot, i.e.
                                      cache_index + Sq with this call's KV
                                      already scattered into the arena
  out:      same shape as q           q.dtype

Sq>1 causal contract: query row i of slot b sits at absolute cache position
``lengths[b] - Sq + i`` and attends every kv position <= its own — both the
already-paged prefix AND the in-chunk positions this call just scattered.
Rows must satisfy ``lengths[b] == 0`` (zero output) or ``lengths[b] >= Sq``
(every query position real); a ragged final chunk is handled by the caller
re-overlapping the previous chunk's tail, not by partial-length rows.

Semantics match the retained gather path bit-for-bit in structure: positions
``>= lengths[b]`` are masked with -inf BEFORE the softmax, while an
*unmapped* page whose positions are still inside ``lengths[b]`` (a frozen
slot whose table was released) contributes zero K/V — the ``mode="fill"``
gather semantics — so its logits enter the softmax as zeros rather than
being skipped. int8 arenas are dequantized in-kernel (``kv_qscale``),
mirroring the symmetric KV_QSCALE quantization of models/layers.py. Rows
with ``lengths[b] == 0`` produce a zero output vector (the gather path has
no such case; decode always has length >= 1).

``interpret=None`` resolves to True off-TPU (ops._interpret_default) and
runs the same body through the Pallas interpreter for CPU correctness
testing; on TPU it lowers to Mosaic.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.budget import KernelVmemPlan, block_bytes, require

VMEM_LIMIT_BYTES = 64 * 1024 * 1024

NEG_INF = -1e30


def _kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
            acc_ref, *, page_size, n_pages, scale, kv_qscale):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[b]

    @pl.when(j * page_size < length)
    def _fold_page():
        q = q_ref[0].astype(jnp.float32)          # (KV, G, hd)
        k = k_ref[0]                              # (page_size, KV, hd)
        v = v_ref[0]
        if kv_qscale is not None:
            k = k.astype(jnp.float32) / kv_qscale
            v = v.astype(jnp.float32) / kv_qscale
        else:
            k = k.astype(jnp.float32)
            v = v.astype(jnp.float32)
        # unmapped block inside the valid length: zero KV (gather fill),
        # NOT a skip — the zero logits must still enter the softmax
        mapped = (bt_ref[b, j] < n_pages).astype(jnp.float32)
        k = k * mapped
        v = v * mapped
        s = jnp.einsum("kgh,skh->kgs", q, k,
                       preferred_element_type=jnp.float32) * scale
        pos = j * page_size + jax.lax.broadcasted_iota(
            jnp.int32, (1, 1, page_size), 2)
        s = jnp.where(pos < length, s, NEG_INF)   # beyond-length: hard mask
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        m_ref[...] = m_new
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * corr[..., None] + jnp.einsum(
            "kgs,skh->kgh", p, v, preferred_element_type=jnp.float32)

    @pl.when(j == pl.num_programs(1) - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)        # length-0 rows -> zeros
        o_ref[0] = (acc_ref[...] / l[..., None]).astype(o_ref.dtype)


def _kernel_sq(bt_ref, len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
               acc_ref, *, page_size, n_pages, sq, scale, kv_qscale):
    """Sq>1 mode: the chunk lane's causal multi-query read. Query row i of
    slot b is at absolute position lengths[b] - sq + i; each page's logits
    are masked per query row, so in-chunk positions (this call's own
    scatter) and the already-paged prefix fold through one online softmax."""
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[b]

    @pl.when(j * page_size < length)
    def _fold_page():
        q = q_ref[0].astype(jnp.float32)          # (sq, KV, G, hd)
        k = k_ref[0]                              # (page_size, KV, hd)
        v = v_ref[0]
        if kv_qscale is not None:
            k = k.astype(jnp.float32) / kv_qscale
            v = v.astype(jnp.float32) / kv_qscale
        else:
            k = k.astype(jnp.float32)
            v = v.astype(jnp.float32)
        mapped = (bt_ref[b, j] < n_pages).astype(jnp.float32)
        k = k * mapped
        v = v * mapped
        s = jnp.einsum("qkgh,skh->qkgs", q, k,
                       preferred_element_type=jnp.float32) * scale
        # causal: kv position p visible to query row i iff p <= q_start + i
        pos = j * page_size + jax.lax.broadcasted_iota(
            jnp.int32, (1, 1, 1, page_size), 3)
        qpos = (length - sq) + jax.lax.broadcasted_iota(
            jnp.int32, (sq, 1, 1, 1), 0)
        s = jnp.where(pos <= qpos, s, NEG_INF)
        m_prev = m_ref[...]                        # (sq, KV, G)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        m_ref[...] = m_new
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * corr[..., None] + jnp.einsum(
            "qkgs,skh->qkgh", p, v, preferred_element_type=jnp.float32)

    @pl.when(j == pl.num_programs(1) - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)        # length-0 rows -> zeros
        o_ref[0] = (acc_ref[...] / l[..., None]).astype(o_ref.dtype)


def paged_attention_pallas(q, k_pages, v_pages, block_table, lengths, *,
                           scale: float, kv_qscale=None,
                           interpret: Optional[bool] = None):
    """q: (B, KV, G, hd) decode or (B, Sq, KV, G, hd) chunked prefill;
    k/v_pages: (n_pages, page_size, KV, hd); block_table: (B, MB) int32;
    lengths: (B,) int32. Returns q's shape in q.dtype. ``kv_qscale``: int8
    arena dequant scale (None == float KV). Sq>1 rows need lengths[b] == 0
    or lengths[b] >= Sq (see the module docstring's causal contract).
    ``interpret=None`` resolves via ops._interpret_default (True off-TPU —
    a hard-coded True would silently run the Python interpreter on TPU).
    """
    if interpret is None:
        from repro.kernels.ops import _interpret_default
        interpret = _interpret_default()
    if q.ndim == 5:
        return _paged_attention_sq(q, k_pages, v_pages, block_table, lengths,
                                   scale=scale, kv_qscale=kv_qscale,
                                   interpret=interpret)
    B, KV, G, hd = q.shape
    n_pages, page_size = k_pages.shape[0], k_pages.shape[1]
    assert k_pages.shape == v_pages.shape == (n_pages, page_size, KV, hd)
    assert block_table.shape[0] == B and lengths.shape == (B,)

    def kv_map(b, j, bt, ln):
        # dead fetches (past the slot's length) pin to page 0; unmapped
        # blocks clamp to a real page and are zero-masked in the body
        page = jnp.where(j * page_size < ln[b],
                         jnp.minimum(bt[b, j], n_pages - 1), 0)
        return page, 0, 0, 0

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, block_table.shape[1]),
        in_specs=[
            pl.BlockSpec((1, KV, G, hd), lambda b, j, bt, ln: (b, 0, 0, 0)),
            pl.BlockSpec((1, page_size, KV, hd), kv_map),
            pl.BlockSpec((1, page_size, KV, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, KV, G, hd),
                               lambda b, j, bt, ln: (b, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((KV, G), jnp.float32),      # m: running max
            pltpu.VMEM((KV, G), jnp.float32),      # l: running denominator
            pltpu.VMEM((KV, G, hd), jnp.float32),  # acc: running numerator
        ],
    )
    kern = functools.partial(_kernel, page_size=page_size, n_pages=n_pages,
                             scale=scale, kv_qscale=kv_qscale)
    return pl.pallas_call(
        kern, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        compiler_params=pltpu.TPUCompilerParams(
            # slots are independent; the page axis revisits the m/l/acc carry
            dimension_semantics=("parallel", "arbitrary"),
            vmem_limit_bytes=VMEM_LIMIT_BYTES,
        ),
        interpret=interpret,
    )(block_table.astype(jnp.int32), lengths.astype(jnp.int32),
      q, k_pages, v_pages)


def _paged_attention_sq(q, k_pages, v_pages, block_table, lengths, *,
                        scale: float, kv_qscale,
                        interpret: Optional[bool] = None):
    """Sq>1 lowering: same grid walk as the decode mode, with the query
    block, scratch carry, and causal mask grown a leading Sq axis.
    ``interpret`` arrives resolved from the public wrapper; None resolves
    via ops._interpret_default for direct callers."""
    if interpret is None:
        from repro.kernels.ops import _interpret_default
        interpret = _interpret_default()
    B, Sq, KV, G, hd = q.shape
    n_pages, page_size = k_pages.shape[0], k_pages.shape[1]
    assert k_pages.shape == v_pages.shape == (n_pages, page_size, KV, hd)
    assert block_table.shape[0] == B and lengths.shape == (B,)

    def kv_map(b, j, bt, ln):
        page = jnp.where(j * page_size < ln[b],
                         jnp.minimum(bt[b, j], n_pages - 1), 0)
        return page, 0, 0, 0

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, block_table.shape[1]),
        in_specs=[
            pl.BlockSpec((1, Sq, KV, G, hd),
                         lambda b, j, bt, ln: (b, 0, 0, 0, 0)),
            pl.BlockSpec((1, page_size, KV, hd), kv_map),
            pl.BlockSpec((1, page_size, KV, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, Sq, KV, G, hd),
                               lambda b, j, bt, ln: (b, 0, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Sq, KV, G), jnp.float32),      # m: running max
            pltpu.VMEM((Sq, KV, G), jnp.float32),      # l: running denom
            pltpu.VMEM((Sq, KV, G, hd), jnp.float32),  # acc: numerator
        ],
    )
    kern = functools.partial(_kernel_sq, page_size=page_size, n_pages=n_pages,
                             sq=Sq, scale=scale, kv_qscale=kv_qscale)
    return pl.pallas_call(
        kern, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Sq, KV, G, hd), q.dtype),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
            vmem_limit_bytes=VMEM_LIMIT_BYTES,
        ),
        interpret=interpret,
    )(block_table.astype(jnp.int32), lengths.astype(jnp.int32),
      q, k_pages, v_pages)


def vmem_plan(B: int, KV: int, G: int, hd: int, *, sq: int = 1,
              page_size: int = 16, max_blocks: int = 8, q_itemsize: int = 2,
              kv_itemsize: int = 2) -> KernelVmemPlan:
    """Static VMEM working set of one ``paged_attention_pallas`` call (see
    kernels/budget.py). The grid walks (B, max_blocks) with one page of K
    and V resident per step plus the f32 m/l/acc online-softmax carry; the
    scalar-prefetched block table and lengths live in SMEM and are counted
    against the VMEM budget conservatively. ``sq > 1`` models the chunked-
    prefill mode: query block, carry, and logits all grow the Sq axis."""
    blocks = {"q": block_bytes((1, sq, KV, G, hd), q_itemsize),
              "k_page": block_bytes((1, page_size, KV, hd), kv_itemsize),
              "v_page": block_bytes((1, page_size, KV, hd), kv_itemsize),
              "out": block_bytes((1, sq, KV, G, hd), q_itemsize),
              "block_table": block_bytes((B, max_blocks), 4),
              "lengths": block_bytes((B,), 4)}
    scratch = {"m": block_bytes((sq, KV, G), 4),
               "l": block_bytes((sq, KV, G), 4),
               "acc": block_bytes((sq, KV, G, hd), 4)}
    # f32 copies of q/k/v for the einsums + the (sq, KV, G, page_size) logits
    temp = (block_bytes((sq, KV, G, hd), 4)
            + 2 * block_bytes((page_size, KV, hd), 4)
            + 2 * block_bytes((sq, KV, G, page_size), 4))
    plan = KernelVmemPlan("paged_attention",
                          dict(B=B, sq=sq, KV=KV, G=G, hd=hd,
                               page_size=page_size, max_blocks=max_blocks),
                          blocks, scratch, temp, VMEM_LIMIT_BYTES)
    require(plan, page_size >= 1, f"page_size={page_size} < 1")
    require(plan, sq >= 1, f"sq={sq} < 1")
    require(plan, G >= 1 and KV >= 1, f"bad GQA grouping KV={KV} G={G}")
    return plan
