"""Pure-jnp oracles for every Pallas kernel (tests assert allclose)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.masks import nm_mask as _nm_mask_ref


def nm_mask_ref(w_oi, xnorm, g_oi=None, *, alpha=100.0, n=2, m=4):
    w32 = jnp.abs(w_oi).astype(jnp.float32)
    xn = xnorm.astype(jnp.float32)[None, :]
    s = (alpha * g_oi.astype(jnp.float32) + xn) * w32 if g_oi is not None \
        else xn * w32
    return _nm_mask_ref(s, n, m).astype(jnp.int8)


def decompress24_ref(vals, idx, K):
    """vals/idx: (K/2, N) -> dense (K, N)."""
    N = vals.shape[1]
    dense = jnp.zeros((K, N), vals.dtype)
    groups = K // 4
    for t in range(2):
        v = vals[t::2, :]  # (K/4, N)
        i = idx[t::2, :].astype(jnp.int32)
        rows = jnp.arange(groups)[:, None] * 4 + i  # (K/4, N) dense row ids
        cols = jnp.broadcast_to(jnp.arange(N)[None, :], rows.shape)
        dense = dense.at[rows, cols].add(v)
    return dense


def sparse_matmul24_ref(x, vals, idx):
    dense = decompress24_ref(vals, idx, x.shape[1])
    return (x.astype(jnp.float32) @ dense.astype(jnp.float32))


def masked_matmul_ref(x, w, mask):
    return x.astype(jnp.float32) @ (w * mask.astype(w.dtype)).astype(jnp.float32)


def paged_attention_ref(q, k_pages, v_pages, block_table, lengths, *,
                        scale, kv_qscale=None):
    """The gather-path semantics of kernels/paged_attention.py, in plain jnp:
    ``mode="fill"`` gather of the position-ordered KV view, -inf mask beyond
    each row's length, full (non-online) softmax. Rows with length 0 are
    defined as zero output."""
    B, KV, G, hd = q.shape
    n_pages, ps = k_pages.shape[0], k_pages.shape[1]
    MB = block_table.shape[1]
    k_full = k_pages.at[block_table].get(mode="fill", fill_value=0)
    v_full = v_pages.at[block_table].get(mode="fill", fill_value=0)
    k_full = k_full.reshape(B, MB * ps, KV, hd).astype(jnp.float32)
    v_full = v_full.reshape(B, MB * ps, KV, hd).astype(jnp.float32)
    if kv_qscale is not None:
        k_full = k_full / kv_qscale
        v_full = v_full / kv_qscale
    s = jnp.einsum("bkgh,bskh->bkgs", q.astype(jnp.float32), k_full) * scale
    valid = jnp.arange(MB * ps)[None, :] < lengths[:, None]  # (B, S_kv)
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", w, v_full)
    out = jnp.where((lengths > 0)[:, None, None, None], out, 0.0)
    return out.astype(q.dtype)
