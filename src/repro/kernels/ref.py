"""Pure-jnp oracles for every Pallas kernel (tests assert allclose)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.masks import nm_mask as _nm_mask_ref


def nm_mask_ref(w_oi, xnorm, g_oi=None, *, alpha=100.0, n=2, m=4):
    w32 = jnp.abs(w_oi).astype(jnp.float32)
    xn = xnorm.astype(jnp.float32)[None, :]
    s = (alpha * g_oi.astype(jnp.float32) + xn) * w32 if g_oi is not None \
        else xn * w32
    return _nm_mask_ref(s, n, m).astype(jnp.int8)


def decompress24_ref(vals, idx, K):
    """vals (K/2, N) + packed 2-bit idx (K/8, N) uint8 -> dense (K, N).

    Scatter-based oracle, deliberately a different algorithm from the
    compare-select decompression in ops.decompress24 and the kernel."""
    N = vals.shape[1]
    # unpack: logical index row r sits in byte r//4 at bits [2*(r%4), ...)
    idx2 = jnp.stack([(idx >> (2 * t)) & 3 for t in range(4)],
                     axis=1).reshape(K // 2, N).astype(jnp.int32)
    dense = jnp.zeros((K, N), vals.dtype)
    groups = K // 4
    for t in range(2):
        v = vals[t::2, :]  # (K/4, N)
        i = idx2[t::2, :]
        rows = jnp.arange(groups)[:, None] * 4 + i  # (K/4, N) dense row ids
        cols = jnp.broadcast_to(jnp.arange(N)[None, :], rows.shape)
        dense = dense.at[rows, cols].add(v)
    return dense


def sparse_matmul24_ref(x, vals, idx, bias=None, w_qscale=None):
    dense = decompress24_ref(vals, idx, x.shape[1]).astype(jnp.float32)
    if w_qscale is not None:
        dense = dense / w_qscale
    y = x.astype(jnp.float32) @ dense
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y.astype(x.dtype)


def masked_matmul_ref(x, w, mask):
    return x.astype(jnp.float32) @ (w * mask.astype(w.dtype)).astype(jnp.float32)


def paged_attention_ref(q, k_pages, v_pages, block_table, lengths, *,
                        scale, kv_qscale=None):
    """The gather-path semantics of kernels/paged_attention.py, in plain jnp:
    ``mode="fill"`` gather of the position-ordered KV view, -inf causal mask,
    full (non-online) softmax. Rows with length 0 are defined as zero
    output. q may be (B, KV, G, hd) (decode: the single query sits at
    position lengths-1) or (B, Sq, KV, G, hd) (chunked prefill: query row i
    sits at position lengths - Sq + i and attends every kv position <= its
    own — the Sq>1 kernel mode's causal contract)."""
    sq1 = q.ndim == 4
    if sq1:
        q = q[:, None]
    B, Sq, KV, G, hd = q.shape
    n_pages, ps = k_pages.shape[0], k_pages.shape[1]
    MB = block_table.shape[1]
    k_full = k_pages.at[block_table].get(mode="fill", fill_value=0)
    v_full = v_pages.at[block_table].get(mode="fill", fill_value=0)
    k_full = k_full.reshape(B, MB * ps, KV, hd).astype(jnp.float32)
    v_full = v_full.reshape(B, MB * ps, KV, hd).astype(jnp.float32)
    if kv_qscale is not None:
        k_full = k_full / kv_qscale
        v_full = v_full / kv_qscale
    s = jnp.einsum("bqkgh,bskh->bqkgs", q.astype(jnp.float32), k_full) * scale
    qpos = lengths[:, None] - Sq + jnp.arange(Sq)[None, :]  # (B, Sq)
    valid = jnp.arange(MB * ps)[None, None, :] <= qpos[:, :, None]
    s = jnp.where(valid[:, :, None, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqkgs,bskh->bqkgh", w, v_full)
    out = jnp.where((lengths > 0)[:, None, None, None, None], out, 0.0)
    out = out.astype(q.dtype)
    return out[:, 0] if sq1 else out
