"""Pallas TPU kernel: 2:4 compacted-weight matmul  y = x @ decompress(W).

TPU adaptation of the paper's NVIDIA-sparse-tensor-core deployment story
(Appendix B.1): TPUs have no sparse MXU, but decode is weight-bandwidth
bound, so the win is moving HALF the weight bytes HBM->VMEM and expanding
to a dense tile on-chip for the MXU.

Storage: vals (K/2, N) keeps the 2 surviving values per group of 4 along K;
idx (K/2, N) int8 in [0,4) records each value's offset inside its group.
Decompression is two broadcast-compares against an iota (no gathers — TPU
vector units hate gathers):

    dense[k, n] = sum_t vals[g*2+t, n] * (idx[g*2+t, n] == k % 4),  g = k//4

Grid (M/bm, N/bn, K/bk) with K innermost: the output tile lives in VMEM
across the K loop (revisiting), initialized at k==0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, vals_ref, idx_ref, o_ref):
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]                       # (bm, bk)
    vals = vals_ref[...]                 # (bk/2, bn)
    idx = idx_ref[...].astype(jnp.int32)  # (bk/2, bn)
    bk = x.shape[1]
    bn = vals.shape[1]

    # expand to a dense (bk, bn) tile in VMEM with 2 broadcast-compares
    within = jax.lax.broadcasted_iota(jnp.int32, (bk, bn), 0) % 4  # k % 4
    v0 = vals[0::2, :]   # (bk/4, bn) first kept value per group
    v1 = vals[1::2, :]
    i0 = idx[0::2, :]
    i1 = idx[1::2, :]
    rep = lambda a: jnp.repeat(a, 4, axis=0)  # group -> 4 dense rows
    dense = (rep(v0) * (rep(i0) == within).astype(v0.dtype)
             + rep(v1) * (rep(i1) == within).astype(v1.dtype))
    o_ref[...] += jnp.dot(x, dense, preferred_element_type=jnp.float32
                          ).astype(o_ref.dtype)


def sparse_matmul24_pallas(x, vals, idx, *, block_m: int = 128,
                           block_n: int = 128, block_k: int = 128,
                           interpret: bool = True):
    """x: (M, K); vals/idx: (K/2, N). Returns (M, N) in f32."""
    M, K = x.shape
    N = vals.shape[1]
    assert vals.shape[0] == K // 2 and idx.shape == vals.shape
    bm, bn, bk = min(block_m, M), min(block_n, N), min(block_k, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0 and bk % 4 == 0
    grid = (M // bm, N // bn, K // bk)

    return pl.pallas_call(
        _kernel, grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk // 2, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bk // 2, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=interpret,
    )(x, vals, idx)
