"""Pallas TPU kernel: 2:4 compacted-weight matmul  y = x @ decompress(W) + b.

TPU adaptation of the paper's NVIDIA-sparse-tensor-core deployment story
(Appendix B.1): TPUs have no sparse MXU, but decode is weight-bandwidth
bound, so the win is moving 0.5625x the weight bytes HBM->VMEM and expanding
to a dense tile on-chip for the MXU.

Storage (see kernels/ops.py compact24): vals (K/2, N) keeps the 2 surviving
values per group of 4 along K; idx (K/8, N) uint8 packs each value's 2-bit
offset inside its group, four entries per byte — byte b holds logical index
rows [4b, 4b+4), entry t in bits [2t, 2t+2). Decompression is a repeat +
shift to unpack, then two broadcast-compares against an iota (no gathers —
TPU vector units hate gathers):

    dense[k, n] = sum_t vals[g*2+t, n] * (idx2[g*2+t, n] == k % 4),  g = k//4

Grid (M/bm, N/bn, K/bk) with K innermost: a float32 VMEM scratch accumulates
across the K loop (revisiting) and the epilogue — optional fused bias add,
cast back to x.dtype — runs on the last K step. ``w_qscale`` dequantizes
int8 ``vals`` in-tile (mirroring paged_attention's kv_qscale), stacking the
int8 quant saving on top of the 2:4 compaction. The jitted wrapper zero-pads
ragged M up to the block and slices the result back, so decode batch widths
need not divide ``block_m``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.budget import KernelVmemPlan, block_bytes, require

VMEM_LIMIT_BYTES = 64 * 1024 * 1024


def _body(x_ref, vals_ref, idx_ref, bias_ref, o_ref, acc_ref, *, w_qscale):
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]                        # (bm, bk)
    vals = vals_ref[...]                  # (bk/2, bn)
    if w_qscale is not None:
        vals = vals.astype(jnp.float32) / w_qscale
    packed = idx_ref[...].astype(jnp.int32)  # (bk/8, bn) uint8 bytes
    bk = x.shape[1]
    bn = vals.shape[1]

    # unpack: logical index row r sits in byte r//4 at bits [2*(r%4), ...)
    bytes_rep = jnp.repeat(packed, 4, axis=0)  # (bk/2, bn)
    shift = (jax.lax.broadcasted_iota(jnp.int32, (bk // 2, bn), 0) % 4) * 2
    idx2 = (bytes_rep >> shift) & 3

    # expand to a dense (bk, bn) tile in VMEM with 2 broadcast-compares
    within = jax.lax.broadcasted_iota(jnp.int32, (bk, bn), 0) % 4  # k % 4
    v0 = vals[0::2, :]   # (bk/4, bn) first kept value per group
    v1 = vals[1::2, :]
    i0 = idx2[0::2, :]
    i1 = idx2[1::2, :]
    rep = lambda a: jnp.repeat(a, 4, axis=0)  # group -> 4 dense rows
    dense = (jnp.where(rep(i0) == within, rep(v0), 0)
             + jnp.where(rep(i1) == within, rep(v1), 0))
    acc_ref[...] += jnp.dot(x, dense, preferred_element_type=jnp.float32)

    @pl.when(k_step == pl.num_programs(2) - 1)
    def _epilogue():
        acc = acc_ref[...]
        if bias_ref is not None:
            acc = acc + bias_ref[...].astype(jnp.float32)
        o_ref[...] = acc.astype(o_ref.dtype)


def _kernel_bias(x_ref, vals_ref, idx_ref, bias_ref, o_ref, acc_ref, *,
                 w_qscale):
    _body(x_ref, vals_ref, idx_ref, bias_ref, o_ref, acc_ref,
          w_qscale=w_qscale)


def _kernel(x_ref, vals_ref, idx_ref, o_ref, acc_ref, *, w_qscale):
    _body(x_ref, vals_ref, idx_ref, None, o_ref, acc_ref, w_qscale=w_qscale)


def sparse_matmul24_pallas(x, vals, idx, *, bias=None, w_qscale=None,
                           block_m: int = 128, block_n: int = 128,
                           block_k: int = 512,
                           interpret: Optional[bool] = None):
    """x: (M, K); vals: (K/2, N); idx: (K/8, N) uint8 packed (see module
    docstring); bias: optional (N,). Returns (M, N) in x.dtype. M may be
    ragged (padded internally); N and K must divide their blocks, K % 8 == 0.
    ``interpret=None`` resolves via ops._interpret_default (True off-TPU)."""
    if interpret is None:
        from repro.kernels.ops import _interpret_default
        interpret = _interpret_default()
    M, K = x.shape
    N = vals.shape[1]
    assert K % 8 == 0, f"K={K} must be a multiple of 8 (packed 2-bit idx)"
    assert vals.shape[0] == K // 2 and idx.shape == (K // 8, N), \
        (x.shape, vals.shape, idx.shape)
    bm, bn, bk = min(block_m, M), min(block_n, N), min(block_k, K)
    assert N % bn == 0 and K % bk == 0 and bk % 8 == 0
    pad = (-M) % bm
    if pad:  # ragged decode batch: zero-pad rows, slice the result back
        x = jnp.concatenate([x, jnp.zeros((pad, K), x.dtype)], axis=0)
    grid = ((M + pad) // bm, N // bn, K // bk)

    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
        pl.BlockSpec((bk // 2, bn), lambda i, j, k: (k, j)),
        pl.BlockSpec((bk // 8, bn), lambda i, j, k: (k, j)),
    ]
    operands = [x, vals, idx]
    kern = functools.partial(_kernel, w_qscale=w_qscale)
    if bias is not None:
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, k: (0, j)))
        operands.append(bias.reshape(1, N))
        kern = functools.partial(_kernel_bias, w_qscale=w_qscale)

    out = pl.pallas_call(
        kern, grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M + pad, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=pltpu.TPUCompilerParams(
            # M/N tiles are independent; the K axis revisits the accumulator
            dimension_semantics=("parallel", "parallel", "arbitrary"),
            vmem_limit_bytes=VMEM_LIMIT_BYTES,
        ),
        interpret=interpret,
    )(*operands)
    return out[:M] if pad else out


def vmem_plan(M: int, K: int, N: int, *, block_m: int = 128,
              block_n: int = 128, block_k: int = 512, x_itemsize: int = 2,
              vals_itemsize: int = 2, bias: bool = False,
              w_qscale: bool = False) -> KernelVmemPlan:
    """Static VMEM working set of one ``sparse_matmul24_pallas`` call (see
    kernels/budget.py). Besides the compacted input blocks and the f32
    scratch accumulator, the in-tile decompression materializes the dense
    (bk, bn) f32 expansion plus the unpacked int32 index plane."""
    bm, bn, bk = min(block_m, M), min(block_n, N), min(block_k, K)
    blocks = {"x": block_bytes((bm, bk), x_itemsize),
              "vals": block_bytes((bk // 2, bn), vals_itemsize),
              "idx": block_bytes((bk // 8, bn), 1),
              "out": block_bytes((bm, bn), x_itemsize)}
    if bias:
        blocks["bias"] = block_bytes((1, bn), x_itemsize)
    scratch = {"acc": block_bytes((bm, bn), 4)}
    # dense f32 expansion + unpacked idx2 (int32) + repeated byte plane
    temp = (block_bytes((bk, bn), 4) + 2 * block_bytes((bk // 2, bn), 4)
            + (block_bytes((bk // 2, bn), 4) if w_qscale else 0))
    plan = KernelVmemPlan("sparse_matmul24", dict(M=M, K=K, N=N, block_m=bm,
                                                  block_n=bn, block_k=bk),
                          blocks, scratch, temp, VMEM_LIMIT_BYTES)
    require(plan, K % 8 == 0, f"K={K} % 8 != 0 (packed 2-bit idx)")
    require(plan, N % bn == 0, f"N={N} % block_n={bn} != 0")
    require(plan, K % bk == 0, f"K={K} % block_k={bk} != 0")
    require(plan, bk % 8 == 0, f"block_k={bk} % 8 != 0")
    return plan
