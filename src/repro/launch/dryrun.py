import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch x shape) on the production
meshes with 512 placeholder host devices; record memory/cost/collective
analysis for the roofline tables.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun.jsonl

XLA_FLAGS is set at the very top, before any jax import, because jax locks
the device count on first initialization. Do NOT import this module from
tests (they must see 1 device).
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED_ARCHS, SHAPES, get_config, shape_applicable
from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig
from repro.distributed.roofline import (HW, analytic_bytes,
                                        analytic_collectives, analytic_flops,
                                        collective_bytes, model_flops_for,
                                        roofline_report, xla_cost)
from repro.distributed.sharding import (cache_shardings, input_shardings,
                                        param_shardings)
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (init_train_state, make_prefill_step,
                                make_serve_step, make_train_step)
from repro.models.model import Model, input_specs

from jax.sharding import NamedSharding, PartitionSpec as P


# Per-arch training memory knobs for the dry-run. Rationale (v5e = 16GB):
#   accum_steps: saved block-boundary activations scale with the per-chip
#     microbatch; dp=16 means accum=16 reaches microbatch 1/chip.
#   remat_groups: two-level scan remat -> only G boundary activations live.
#   adafactor + bf16 accum: optimizer+grad HBM for the >=100B configs.
TRAIN_OVERRIDES: Dict[str, Dict[str, Any]] = {
    "llama3-405b": dict(accum_steps=16, optimizer="adafactor",
                        accum_dtype="bfloat16", remat_groups=14),
    "qwen1.5-110b": dict(accum_steps=16, optimizer="adafactor",
                         accum_dtype="bfloat16", remat_groups=10),
    "qwen3-moe-235b-a22b": dict(accum_steps=16, optimizer="adafactor",
                                accum_dtype="bfloat16"),
    "qwen3-8b": dict(accum_steps=16, optimizer_state_dtype="bfloat16",
                     remat_groups=6),
    "stablelm-3b": dict(accum_steps=16, remat_groups=8),
    "deepseek-moe-16b": dict(accum_steps=16, optimizer_state_dtype="bfloat16"),
    "mamba2-1.3b": dict(accum_steps=16, remat_groups=8),
    "zamba2-7b": dict(accum_steps=16, optimizer_state_dtype="bfloat16"),
    "hubert-xlarge": dict(accum_steps=16, remat_groups=8),
    "qwen2-vl-2b": dict(accum_steps=16, remat_groups=7),
}


def _train_config(arch: str, overrides: Optional[dict] = None) -> TrainConfig:
    kw = dict(TRAIN_OVERRIDES.get(arch, {}))
    kw.update(overrides or {})
    return TrainConfig(remat=True, **kw)


# ---------------------------------------------------------------------------
# Optimized variants (the §Perf hillclimb configurations). Each entry may
# override sharding rules, the model config, the train config, the KV dtype,
# and pin sequence-sharded activations. Baseline records stay untouched.
# ---------------------------------------------------------------------------
OPT_CONFIGS: Dict[tuple, Dict[str, Any]] = {
    # worst-roofline pair: small fine-grained MoE. Dense weights are small ->
    # replicate over `model` (kill Megatron ARs), keep EP over `model`,
    # seq-shard activations, shard-local dispatch groups (a2a ~ toks/chip).
    ("deepseek-moe-16b", "train_4k"): dict(
        rules={"heads": None, "kv_heads": None, "ffn": None, "inner": None,
               "embed": "data", "seq": "model"},
        model=dict(moe_group_tokens=256),
        train=dict(accum_steps=2, optimizer="adafactor",
                   accum_dtype="bfloat16"),
        seq_shard=True),
    # most collective-bound pair: 405B dense. Seq-sharded carries make plain
    # per-layer remat affordable (no nested-remat 5/4 flop tax) and let accum
    # drop 16->4 (4x fewer FSDP re-gathers); TP ARs overlap against compute.
    ("llama3-405b", "train_4k"): dict(
        rules={"seq": "model"},
        train=dict(accum_steps=4, optimizer="adafactor",
                   accum_dtype="bfloat16", remat_groups=0),
        seq_shard=True),
    # paper-representative pair: 2:4-pruned decode. int8 KV cache halves
    # cache traffic; 2:4 compacted weights (vals bf16 + 2-bit idx) cut weight
    # traffic to 0.5625x (projected in `derived_24`, kernels/sparse_matmul24).
    ("qwen3-8b", "decode_32k"): dict(kv_dtype="int8"),
    # bonus: second MoE with the same dispatch-locality treatment
    ("qwen3-moe-235b-a22b", "train_4k"): dict(
        rules={"seq": "model"},
        model=dict(moe_group_tokens=256),
        train=dict(accum_steps=4),
        seq_shard=True),
    # --- broader sweep: the deepseek-B2 treatment (no dense TP + seq-shard)
    # applied to every other collective-bound cell -----------------------
    ("qwen3-8b", "train_4k"): dict(
        rules={"heads": None, "kv_heads": None, "ffn": None,
               "embed": "data", "seq": "model"},
        train=dict(accum_steps=2, optimizer="adafactor",
                   accum_dtype="bfloat16", remat_groups=0),
        seq_shard=True),
    ("stablelm-3b", "train_4k"): dict(
        rules={"heads": None, "kv_heads": None, "ffn": None,
               "embed": "data", "seq": "model"},
        train=dict(accum_steps=2, optimizer="adafactor",
                   accum_dtype="bfloat16", remat_groups=0),
        seq_shard=True),
    ("zamba2-7b", "train_4k"): dict(
        rules={"heads": None, "kv_heads": None, "ffn": None, "inner": None,
               "ssm_heads": None, "embed": "data", "seq": "model"},
        train=dict(accum_steps=2, optimizer="adafactor",
                   accum_dtype="bfloat16"),
        seq_shard=True),
    ("hubert-xlarge", "train_4k"): dict(
        rules={"heads": None, "kv_heads": None, "ffn": None, "seq": "model"},
        train=dict(accum_steps=2, optimizer="adafactor",
                   accum_dtype="bfloat16", remat_groups=0),
        seq_shard=True),
    ("qwen2-vl-2b", "train_4k"): dict(
        rules={"heads": None, "kv_heads": None, "ffn": None, "seq": "model"},
        train=dict(accum_steps=2, optimizer="adafactor",
                   accum_dtype="bfloat16", remat_groups=0),
        seq_shard=True),
    ("mamba2-1.3b", "train_4k"): dict(
        rules={"inner": None, "ssm_heads": None, "seq": "model"},
        train=dict(accum_steps=4, optimizer="adafactor",
                   accum_dtype="bfloat16", remat_groups=0),
        seq_shard=True),
    ("hubert-xlarge", "prefill_32k"): dict(
        rules={"heads": None, "kv_heads": None, "ffn": None, "seq": "model"},
        seq_shard=True),
    ("qwen2-vl-2b", "prefill_32k"): dict(
        rules={"heads": None, "kv_heads": None, "ffn": None, "seq": "model"},
        seq_shard=True),
    ("stablelm-3b", "prefill_32k"): dict(
        rules={"heads": None, "kv_heads": None, "ffn": None, "seq": "model"},
        seq_shard=True),
}


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               rules_overrides: Optional[dict] = None,
               donate: bool = True, opt: bool = False) -> Dict[str, Any]:
    """Lower + compile one (arch x shape x mesh) cell; return the record."""
    import dataclasses
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "variant": "opt" if opt else "baseline",
    }
    if not ok:
        rec["status"] = why
        return rec

    oc = OPT_CONFIGS.get((arch, shape_name), {}) if opt else {}
    if oc.get("model"):
        cfg = dataclasses.replace(cfg, **oc["model"])
    if oc.get("rules"):
        rules_overrides = {**(rules_overrides or {}), **oc["rules"]}
    kv_dtype = oc.get("kv_dtype")

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    model = Model(cfg, param_dtype=jnp.bfloat16, kv_dtype=kv_dtype)
    t0 = time.time()

    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    kind = shape.kind if shape.kind != "prefill" else "prefill"
    p_shard = param_shardings(mesh, cfg, params_shape, kind, rules_overrides)
    specs, cache_spec = input_specs(cfg, shape, kv_dtype=kv_dtype)
    in_shard = input_shardings(mesh, cfg, specs, kind, rules_overrides)

    act_pspec = None
    if oc.get("seq_shard"):
        dp_axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
        act_pspec = P(dp_axes, "model", None)

    with mesh:
        if shape.kind == "train":
            tc = _train_config(arch, oc.get("train"))
            step = make_train_step(model, tc, act_pspec=act_pspec)
            state_shape = jax.eval_shape(
                lambda p: init_train_state(model, p, tc), params_shape)
            state_shard = {
                "params": p_shard,
                "opt": _opt_shardings(mesh, params_shape, p_shard,
                                      state_shape["opt"]),
                "step": NamedSharding(mesh, P()),
            }
            jitted = jax.jit(step, in_shardings=(state_shard, in_shard),
                             donate_argnums=(0,) if donate else ())
            lowered = jitted.lower(state_shape, specs)
        elif shape.kind == "prefill":
            step = make_prefill_step(model)
            jitted = jax.jit(step, in_shardings=(p_shard, in_shard))
            lowered = jitted.lower(params_shape, specs)
        else:  # decode
            step = make_serve_step(model)
            c_shard = cache_shardings(mesh, cfg, cache_spec, "decode",
                                      rules_overrides)
            jitted = jax.jit(step, in_shardings=(p_shard, c_shard, in_shard),
                             donate_argnums=(1,) if donate else ())
            lowered = jitted.lower(params_shape, cache_spec, specs)

        compiled = lowered.compile()

    rec["lower_compile_s"] = round(time.time() - t0, 1)
    rec["status"] = "OK"

    # ---- memory analysis -------------------------------------------------
    try:
        ma = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
            "output_bytes": getattr(ma, "output_size_in_bytes", None),
            "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
            "peak_bytes": (getattr(ma, "temp_size_in_bytes", 0) or 0)
                          + (getattr(ma, "argument_size_in_bytes", 0) or 0),
        }
    except Exception as e:
        rec["memory"] = {"error": str(e)}

    # ---- cost + collectives + roofline ------------------------------------
    cost = xla_cost(compiled)
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    rec["cost"] = {k: float(v) for k, v in cost.items()
                   if k in ("flops", "bytes accessed")}
    mf = model_flops_for(cfg, shape)
    # raw HLO-derived terms (NOTE: XLA cost analysis visits scan/while bodies
    # once, so these under-count by loop trip counts — see §Roofline notes)
    rec["roofline_hlo"] = roofline_report(cost, coll, n_chips, model_flops=mf)
    rec["hlo_collective_ops"] = {k: int(v) for k, v in coll.items()}

    # scan-trip-count-aware analytic terms (validated vs unrolled HLO in
    # tests/test_roofline.py) — these drive the bottleneck call + §Perf.
    tc = _train_config(arch, oc.get("train")) if shape.kind == "train" else None
    accum = tc.accum_steps if tc else 1
    dp = n_chips // mesh.shape["model"]
    tp = mesh.shape["model"]
    pb = _sharded_bytes(params_shape, p_shard)
    cb = _sharded_bytes(cache_spec, cache_shardings(mesh, cfg, cache_spec, "decode",
                                                    rules_overrides)) \
        if shape.kind == "decode" else 0.0
    # mirror the ACTUAL rules used (make_rules + overrides), not a re-derivation
    from repro.distributed.sharding import make_rules
    rules = make_rules(cfg, mesh, kind, rules_overrides)
    fsdp = rules.get("embed") == "data" and shape.kind == "train"
    dense_tp = rules.get("ffn") == "model" or rules.get("heads") == "model"
    seq_shard = rules.get("seq") == "model" or bool(oc.get("seq_shard"))
    grad_mult = 1.0 if (tc and tc.accum_dtype == "bfloat16") else 2.0
    fl = analytic_flops(cfg, shape, accum, remat=bool(tc and tc.remat),
                        remat_groups=(tc.remat_groups if tc else 0))
    byt = analytic_bytes(cfg, shape, param_bytes_per_chip=pb,
                         cache_bytes_per_chip=cb, accum_steps=accum, dp=dp, tp=tp)
    acoll = analytic_collectives(
        cfg, shape, param_bytes_per_chip=pb,
        grad_bytes_per_chip=pb * grad_mult,
        accum_steps=accum, dp=dp, tp=tp, fsdp=fsdp, dense_tp=dense_tp,
        seq_shard=seq_shard, moe_local_groups=cfg.moe_group_tokens > 0)
    t_compute = fl / n_chips / HW.peak_flops
    t_memory = byt / HW.hbm_bw
    t_coll = sum(acoll.values()) / HW.link_bw
    terms = {"compute_s": t_compute, "memory_s": t_memory, "collective_s": t_coll}
    dom = max(terms, key=terms.get)
    ideal = mf / (n_chips * HW.peak_flops)
    rec["roofline"] = {
        **terms, "bottleneck": dom,
        "flops_total": fl, "bytes_per_chip": byt,
        "collective_bytes_per_chip": sum(acoll.values()),
        "coll_breakdown": {k: float(v) for k, v in acoll.items()},
        "model_flops_total": mf,
        "useful_flop_frac": mf / fl if fl else 0.0,
        "roofline_frac": ideal / max(max(terms.values()), 1e-30),
        "param_bytes_per_chip": pb, "cache_bytes_per_chip": cb,
    }
    if shape.kind == "decode":
        # memory-roofline view for decode: ideal = (weights+cache)/BW; plus
        # the 2:4 + int8-KV serving projection (the paper's Table 7 analogue)
        ideal_bytes = pb + cb
        rec["roofline"]["decode_mem_eff"] = ideal_bytes / max(byt, 1e-30)
        from repro.kernels.ops import compressed24_ratio
        # bf16 vals + packed 2-bit idx (kernels/sparse_matmul24): 0.5625x
        w24 = pb * compressed24_ratio(2)
        cbq = cb if kv_dtype == "int8" else cb * 0.5
        rec["roofline"]["derived_24_int8kv_ms"] = (w24 + cbq) / HW.hbm_bw * 1e3
        rec["roofline"]["tpot_ms"] = byt / HW.hbm_bw * 1e3
    return rec


def _opt_shardings(mesh, params_shape, p_shard, opt_shape):
    """Optimizer-state shardings mirroring the param shardings.

    AdamW: mu/nu are param-shaped. Adafactor: vr drops the last dim, vc the
    second-to-last — their PartitionSpecs drop the same entries.
    """
    scalar = NamedSharding(mesh, P())
    if "mu" in opt_shape:
        return {"mu": p_shard, "nu": p_shard, "step": scalar}

    def leaf(ps, ns, sub):
        nd = len(ps.shape)
        spec = tuple(ns.spec) + (None,) * (nd - len(ns.spec))
        if "vr" in sub:
            return {"vr": NamedSharding(mesh, P(*spec[:-1])),
                    "vc": NamedSharding(mesh, P(*(spec[:-2] + spec[-1:])))}
        return {"v": NamedSharding(mesh, P(*spec))}

    v = jax.tree_util.tree_map(
        leaf, params_shape, p_shard, opt_shape["v"],
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct)
        or isinstance(x, NamedSharding)
        or (isinstance(x, dict) and ("vr" in x or "v" in x)))
    return {"v": v, "step": scalar}


def _sharded_bytes(tree, shardings) -> float:
    """Per-chip bytes of a sharded pytree of ShapeDtypeStructs."""
    total = 0.0
    for leaf, sh in zip(jax.tree_util.tree_leaves(tree),
                        jax.tree_util.tree_leaves(
                            shardings, is_leaf=lambda x: isinstance(x, NamedSharding))):
        shard_shape = sh.shard_shape(leaf.shape)
        n = 1
        for d in shard_shape:
            n *= d
        total += n * leaf.dtype.itemsize
    return total


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--rules", default=None, help="JSON logical->mesh overrides")
    ap.add_argument("--opt", action="store_true",
                    help="apply the OPT_CONFIGS hillclimb variant if defined")
    ap.add_argument("--out", default=None, help="append JSONL records here")
    ap.add_argument("--check-vmem", action="store_true",
                    help="run the static Pallas VMEM budget estimator "
                    "(repro.analysis.vmem) over the sweep grid instead of "
                    "lowering — reports infeasible block shapes Mosaic "
                    "would reject, without burning TPU time")
    args = ap.parse_args()

    rules = json.loads(args.rules) if args.rules else None
    archs = ASSIGNED_ARCHS if (args.all or not args.arch) else [args.arch]
    if args.check_vmem:
        from repro.analysis import vmem as VMEM
        bad = 0
        for a in archs:
            plans, findings = VMEM.sweep(a)
            bad += len(findings)
            rec = {"arch": a, "check": "vmem", "cells": len(plans),
                   "infeasible": sorted({f.scope for f in findings})}
            print(json.dumps(rec))
            for f in findings:
                print(f"  {f.render()}")
            if args.out:
                os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
                with open(args.out, "a") as f:
                    f.write(json.dumps(rec) + "\n")
        raise SystemExit(1 if bad else 0)
    cells = []
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                cells.append((a, s, mp))

    for a, s, mp in cells:
        try:
            rec = lower_cell(a, s, mp, rules, opt=args.opt)
        except Exception as e:
            rec = {"arch": a, "shape": s,
                   "mesh": "2x16x16" if mp else "16x16",
                   "status": f"FAIL: {type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-2000:]}
        line = {k: v for k, v in rec.items() if k != "traceback"}
        print(json.dumps(line))
        if "traceback" in rec:
            print(rec["traceback"])
        if args.out:
            os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
            with open(args.out, "a") as f:
                f.write(json.dumps(rec) + "\n")


if __name__ == "__main__":
    main()
