"""Production mesh definitions.

Single pod = 16x16 = 256 chips (v5e pod slice); multi-pod adds a leading
`pod` axis (2 x 256 = 512 chips). The `pod` axis carries only data
parallelism (one gradient all-reduce per step crosses the DCN), so scaling
to 1000+ nodes means growing `pod` — the step functions are pod-count
agnostic.

These are FUNCTIONS (not module constants) so importing never touches jax
device state; the dry-run sets XLA_FLAGS before calling.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_dev_mesh(data: int = 1, model: int = 1):
    """Small mesh for multi-device CPU tests (subprocess sets device count)."""
    return jax.make_mesh((data, model), ("data", "model"))


def parse_mesh(spec: str):
    """``"4,2"`` -> a (data=4, model=2) mesh (the serve CLI's ``--mesh``
    flag). The host must expose data*model devices (on CPU: run under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``)."""
    try:
        data, model = (int(x) for x in spec.split(","))
    except ValueError:
        raise ValueError(
            f"--mesh expects 'data,model' axis sizes (e.g. 4,2), got {spec!r}")
    return make_dev_mesh(data, model)
