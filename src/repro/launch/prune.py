"""Pruning launcher: run Wanda++ (or any baseline) against an arch config.

    PYTHONPATH=src python -m repro.launch.prune --arch llama1-7b --smoke \
        --method wanda++ --pattern 2:4

At production scale the same per-block jitted functions run under the mesh:
calibration samples shard over `data`, the block's weights over `model`
(see DESIGN.md §7); memory stays O(one block) either way, which is the
paper's central efficiency claim.
"""
from __future__ import annotations

import argparse
import json
import time

import jax

from repro.configs import get_config
from repro.configs.base import PruneConfig
from repro.data import calibration_batch, eval_batch
from repro.core.pruner import model_sparsity_report, prune_model
from repro.models.model import Model


def run(arch: str, method: str, pattern: str, sparsity: float, smoke: bool,
        n_calib: int, calib_len: int, ro_iters: int, eval_ppl: bool = True):
    cfg = get_config(arch)
    if smoke:
        cfg = cfg.reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    pcfg = PruneConfig(method=method, pattern=pattern, sparsity=sparsity,
                       n_calib=n_calib, calib_len=calib_len, ro_iters=ro_iters)
    if cfg.family == "audio":
        import jax.numpy as jnp
        calib = jax.random.normal(jax.random.PRNGKey(1),
                                  (n_calib, calib_len, cfg.d_model))
    else:
        calib = calibration_batch(cfg.vocab_size, n_calib, calib_len)

    t0 = time.time()
    pruned, reports = prune_model(
        model, params, calib, pcfg,
        progress=lambda l, r: print(f"[prune] block {l}: {r.get('seconds', 0):.1f}s"))
    dt = time.time() - t0
    sparsity_rep = model_sparsity_report(model, pruned)
    print(json.dumps({"arch": cfg.name, "method": method, "pattern": pattern,
                      "seconds": round(dt, 1), "sparsity": sparsity_rep}))

    if eval_ppl and cfg.family not in ("audio",):
        import jax.numpy as jnp
        ev = eval_batch(cfg.vocab_size, 8, calib_len)
        loss_d = float(model.loss(params, ev)[0])
        loss_p = float(model.loss(pruned, ev)[0])
        print(f"[prune] eval loss dense={loss_d:.4f} pruned={loss_p:.4f} "
              f"(ppl {jnp.exp(loss_d):.2f} -> {jnp.exp(loss_p):.2f})")
    return pruned, reports


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama1-7b")
    ap.add_argument("--method", default="wanda++")
    ap.add_argument("--pattern", default="2:4")
    ap.add_argument("--sparsity", type=float, default=0.5)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--n-calib", type=int, default=16)
    ap.add_argument("--calib-len", type=int, default=64)
    ap.add_argument("--ro-iters", type=int, default=2)
    args = ap.parse_args()
    run(args.arch, args.method, args.pattern, args.sparsity, args.smoke,
        args.n_calib, args.calib_len, args.ro_iters)


if __name__ == "__main__":
    main()
