"""Offline re-prune from a saved live-traffic calibration snapshot.

    # 1. serve real traffic with taps on, exporting the statistics:
    PYTHONPATH=src python -m repro.launch.serve --arch llama1-7b --smoke \
        --requests 16 --pruned 2:4 --save-calib snap.npz
    # 2. later, re-score + re-prune the dense weights against that traffic:
    PYTHONPATH=src python -m repro.launch.reprune --arch llama1-7b --smoke \
        --snapshot snap.npz --method wanda --pattern 2:4 --out pruned_ckpt

This is the offline half of the online-recalibration story
(``--recalibrate-every`` in launch/serve.py is the in-place half): the
engine's per-channel running ``sum(x^2)`` / ``sum|x|`` / ``sum(x)`` / token
counts are exact over whatever traffic was served, so re-pruning against
them is identical to re-pruning against that traffic replayed offline —
without holding the tokens.

The snapshot ``.npz`` stores one array per ``<linear-name>/<stat>`` key
(stats stacked over layers, leading dim ``num_layers``) plus the scalar
token count; ``save_snapshot`` / ``load_snapshot`` round-trip the
``Engine.calibration_snapshot()`` pytree. Dense weights come from a
checkpoint directory (``--params``, checkpoint/store.py layout) or, by
default, the same seed-0 init launch/serve.py builds from.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.base import PruneConfig
from repro.data import calibration_batch
from repro.models.model import Model


def save_snapshot(path: str, snap: dict) -> None:
    """Write a ``Engine.calibration_snapshot()`` dict to ``path`` (.npz)."""
    flat = {f"{name}/{k}": np.asarray(v)
            for name, d in snap["stats"].items() for k, v in d.items()}
    flat["tokens"] = np.asarray(float(snap.get("tokens", 0.0)))
    np.savez(path, **flat)


def load_snapshot(path: str) -> dict:
    """Inverse of ``save_snapshot``; restores the nested stats pytree."""
    with np.load(path) as z:
        stats: dict = {}
        tokens = 0.0
        for key in z.files:
            if key == "tokens":
                tokens = float(z[key])
                continue
            name, stat = key.rsplit("/", 1)
            stats.setdefault(name, {})[stat] = z[key]
    xnorm = {name: np.sqrt(d["sumsq"]) for name, d in stats.items()
             if "sumsq" in d}
    return {"stats": stats, "xnorm": xnorm, "tokens": tokens}


def reprune(arch: str, snapshot: str, method: str = "wanda",
            pattern: str = "2:4", smoke: bool = True, params_dir: str = None,
            out_dir: str = None, calib_len: int = 32):
    """Re-score + re-prune dense weights against a saved snapshot.

    Returns the new params. ``params_dir``/``out_dir`` use the
    checkpoint/store.py pytree layout; without them the weights are the
    seed-0 init (matching launch/serve.py) and nothing is written."""
    from repro.core import scores as SC
    from repro.core.pruner import model_sparsity_report, reprune_from_stats

    cfg = get_config(arch)
    if smoke:
        cfg = cfg.reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if params_dir:
        from repro.checkpoint.store import load_pytree
        params = load_pytree(params_dir, params)
    snap = load_snapshot(snapshot)
    print(f"[reprune] snapshot {snapshot}: {int(snap['tokens'])} live "
          f"tokens, {len(snap['stats'])} tapped linears")
    pcfg = PruneConfig(method=method, pattern=pattern)
    calib = None
    if SC.get_score(method).grad is not None:
        calib = calibration_batch(cfg.vocab_size, 8, calib_len)
    new_params = reprune_from_stats(model, params, snap["stats"], pcfg,
                                    calib=calib)
    rep = model_sparsity_report(model, new_params)
    mean_sp = float(np.mean([v for v in rep.values()])) if rep else 0.0
    print(f"[reprune] {method} @ {pattern}: mean sparsity "
          f"{mean_sp:.3f} over {len(rep)} projections")
    if out_dir:
        from repro.checkpoint.store import save_pytree
        save_pytree(out_dir, new_params,
                    extra={"method": method, "pattern": pattern,
                           "snapshot_tokens": snap["tokens"]})
        print(f"[reprune] wrote re-pruned params -> {out_dir}")
    return new_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama1-7b")
    ap.add_argument("--snapshot", required=True,
                    help=".npz from launch/serve.py --save-calib")
    ap.add_argument("--method", default="wanda",
                    help="score from the core/scores.py registry")
    ap.add_argument("--pattern", default="2:4")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--params", default=None,
                    help="checkpoint dir with dense weights (default: "
                         "seed-0 init, matching launch/serve.py)")
    ap.add_argument("--out", default=None,
                    help="checkpoint dir to write the re-pruned weights")
    ap.add_argument("--calib-len", type=int, default=32,
                    help="token-window length replayed for gradient-blend "
                         "scores")
    args = ap.parse_args()
    reprune(args.arch, args.snapshot, method=args.method,
            pattern=args.pattern, smoke=args.smoke, params_dir=args.params,
            out_dir=args.out, calib_len=args.calib_len)


if __name__ == "__main__":
    main()
