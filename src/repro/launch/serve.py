"""Serving CLI — a thin shell over the continuous-batching engine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --smoke \
        --batch 4 --prompt-len 32 --gen 16 [--pruned 2:4] [--requests 16] \
        [--temperature 0.8 --top-k 40]

Two modes:
  * default: one same-shape wave through ``Engine.generate`` — prefill once,
    then a single jitted scan over the decode steps (two device syncs total).
  * ``--requests N``: N mixed-length requests through the continuous-batching
    ``Scheduler``, reporting TTFT / TPOT percentiles. Eligible engines
    (pure token-KV, non-vision) serve with chunked prefill by default —
    prompts stream through the decode steps' prefill-chunk lane
    (``--chunk-size`` tokens per step) inside ONE unified jitted program;
    ``--no-chunked-prefill`` forces the bucket-wave baseline (recurrent/
    hybrid/VLM families always use it).

Every decoder family serves — dense, MoE, SSM (``--arch mamba2-1.3b``),
hybrid (``--arch zamba2-7b``), VLM (``--arch qwen2-vl-2b``; the CLI attaches
stub vision-patch embeddings to each request, matching the repo's stub
vision frontend). ``--mesh 4,2`` runs the engine tensor/data-parallel over
a (data, model) device mesh — same tokens, sharded params + KV arena. Demonstrates the paper's deployment story: the same engine
serves dense or Wanda++-pruned (2:4 zeros) weights; with ``--pruned 2:4``
the engine auto-packs 2:4 projections into compacted (vals + 2-bit idx)
storage at build (``--compressed-24`` to control, ``--sparse-24-kernel``
to force the Pallas decode matmul off-TPU);
benchmarks/table9_serving.py quantifies the throughput + latency effect.

Online calibration: ``--calib-taps`` collects Wanda-style per-channel input
statistics from live traffic inside the unchanged jitted step programs;
``--recalibrate-every N`` re-scores + re-prunes the dense weights against
those statistics every N requests and hot-swaps the packed storage in place
(``Engine.repack``, no retrace); ``--save-calib snap.npz`` exports the
snapshot for ``python -m repro.launch.reprune`` offline.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.base import PruneConfig
from repro.data import calibration_batch
from repro.launch.mesh import parse_mesh
from repro.models.model import Model
from repro.serve import Engine, EngineConfig, Request, SamplingConfig
from repro.serve.scheduler import Scheduler, percentile


def build_engine(arch: str, batch: int, prompt_len: int, gen: int,
                 smoke: bool = True, pruned: str = None, max_len: int = None,
                 sampling: SamplingConfig = SamplingConfig(),
                 chunk: int = None, n_slots: int = None, paged: bool = True,
                 page_size: int = 16, n_pages: int = None,
                 paged_kernel: bool = None, extra_len: int = 0, mesh=None,
                 compressed24: str = None, compressed24_kernel: bool = None,
                 self_spec: bool = False, draft_k: int = 4,
                 chunked_prefill: bool = None, chunk_size: int = 16,
                 calib_taps: bool = False, prune_method: str = "wanda++"):
    """Returns (engine, cfg, model, params). Prunes first when requested.

    The returned ``params`` are the caller's dense copy (the engine packs
    its own compressed24 storage internally) — online recalibration
    re-scores THESE weights against live statistics and ``engine.repack``s
    the result, so the original magnitudes are never lost to compaction.

    ``self_spec`` builds the self-speculation drafter: a Wanda++ 2:4-pruned
    copy of the target's weights (core/pruner.py regional-gradient recipe),
    registered with the engine to propose ``draft_k`` tokens per verify
    step. The target itself stays whatever ``pruned`` made it.

    The default max_len covers prompt + generation plus the arch's vision
    prefix (VLM requests cache their patch embeddings ahead of the text)
    plus ``extra_len`` (e.g. a shared system-prompt prefix) plus the
    drafter's ``draft_k`` run-ahead headroom."""
    cfg = get_config(arch)
    if smoke:
        cfg = cfg.reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    pcfg = PruneConfig(method=prune_method, pattern=pruned or "2:4",
                       n_calib=8, calib_len=prompt_len, ro_iters=1,
                       ro_samples=4)
    if pruned:
        from repro.core.pruner import prune_model
        calib = calibration_batch(cfg.vocab_size, pcfg.n_calib, pcfg.calib_len)
        params, _ = prune_model(model, params, calib, pcfg)
        print(f"[serve] pruned with {pcfg.method} {pruned}")
    draft_params = None
    if self_spec:
        from repro.core.pruner import prune_model
        calib = calibration_batch(cfg.vocab_size, pcfg.n_calib, pcfg.calib_len)
        draft_params, _ = prune_model(model, params, calib, pcfg)
        print(f"[serve] self-speculation drafter: wanda++ "
              f"{pcfg.pattern}-pruned copy, draft_k={draft_k}")
    vis_len = cfg.vision_patches if cfg.frontend == "vision" else 0
    draft_pad = draft_k if self_spec else 0
    ecfg = EngineConfig(
        n_slots=n_slots or batch,
        max_len=max_len or (vis_len + extra_len + prompt_len + gen
                            + draft_pad),
        chunk=chunk or max(gen - 1, 1),
        prefill_buckets=tuple(sorted({prompt_len, max(prompt_len // 2, 1)})),
        paged=paged, page_size=page_size, n_pages=n_pages,
        paged_kernel=paged_kernel, mesh=mesh,
        compressed24=compressed24, compressed24_kernel=compressed24_kernel,
        draft_k=draft_pad,
        chunked_prefill=chunked_prefill, chunk_size=chunk_size,
        calib_taps=calib_taps,
    )
    engine = Engine(model, params, ecfg, sampling, draft_params=draft_params)
    if engine.compressed24:
        print(f"[serve] compressed 2:4 weights: {engine.compressed24} "
              f"projections packed (vals + 2-bit idx)")
    if engine.compressed24_draft:
        print(f"[serve] drafter serves compressed 2:4: "
              f"{engine.compressed24_draft} projections packed")
    if calib_taps:
        print("[serve] calibration taps on: per-channel input statistics "
              "accumulate from live traffic (zero extra traces)")
    return engine, cfg, model, params


def _stub_vision(cfg, rng):
    """Stub per-request vision-patch embeddings (the repo's VLM frontend is
    a stub: precomputed patch embeddings fed as a sequence prefix)."""
    if cfg.frontend != "vision":
        return None
    return rng.standard_normal(
        (cfg.vision_patches, cfg.d_model)).astype(np.float32)


def serve(arch: str, batch: int = 4, prompt_len: int = 32, gen: int = 16,
          smoke: bool = True, pruned: str = None, max_len: int = None,
          sampling: SamplingConfig = SamplingConfig(), paged: bool = True,
          page_size: int = 16, n_pages: int = None,
          paged_kernel: bool = None, mesh=None, compressed24: str = None,
          compressed24_kernel: bool = None, self_spec: bool = False,
          draft_k: int = 4):
    """One same-shape wave; prints TTFT and TPOT. Returns generated tokens."""
    engine, cfg, _, _ = build_engine(arch, batch, prompt_len, gen, smoke=smoke,
                               pruned=pruned, max_len=max_len,
                               sampling=sampling, paged=paged,
                               page_size=page_size, n_pages=n_pages,
                               paged_kernel=paged_kernel, mesh=mesh,
                               compressed24=compressed24,
                               compressed24_kernel=compressed24_kernel,
                               self_spec=self_spec, draft_k=draft_k)
    rng = np.random.default_rng(7)
    prompts = np.asarray(
        calibration_batch(cfg.vocab_size, batch, prompt_len, seed=7))
    vision = None
    if cfg.frontend == "vision":
        vision = [_stub_vision(cfg, rng) for _ in range(batch)]
    t0 = time.perf_counter()
    first = engine.admit_wave(list(prompts), list(range(batch)), [gen] * batch,
                              vision=vision)
    ttft = time.perf_counter() - t0
    out = first[:, None]
    tpot = 0.0
    if gen > 1 and engine.spec_decode:
        # spec chunks emit variable tokens/slot; let the engine's wave
        # driver loop chunks until every slot finishes, then compact
        t1 = time.perf_counter()
        out = engine._generate_spec(first, batch, gen)
        tpot = (time.perf_counter() - t1) / (gen - 1)
    elif gen > 1:
        t1 = time.perf_counter()
        toks, valid = engine.decode_chunk(gen - 1)
        t, _, _, _ = engine.harvest(toks, valid)
        tpot = (time.perf_counter() - t1) / (gen - 1)
        out = np.concatenate([out, t[:, :batch].T], axis=1)
    rate = f" ({batch / tpot:.0f} tok/s decode)" if tpot > 0 else ""
    print(f"[serve] batch={batch} TTFT={ttft*1e3:.1f}ms "
          f"TPOT={tpot*1e3:.2f}ms{rate}")
    print(f"[serve] generated tokens[0]: {out[0].tolist()}")
    return out


def serve_requests(arch: str, n_requests: int = 16, batch: int = 4,
                   prompt_len: int = 32, gen: int = 16, smoke: bool = True,
                   pruned: str = None,
                   sampling: SamplingConfig = SamplingConfig(),
                   paged: bool = True, page_size: int = 16,
                   n_pages: int = None, shared_prefix: int = 0,
                   paged_kernel: bool = None, mesh=None,
                   compressed24: str = None,
                   compressed24_kernel: bool = None,
                   self_spec: bool = False, draft_k: int = 4,
                   chunked_prefill: bool = None, chunk_size: int = 16,
                   calib_taps: bool = False, recalibrate_every: int = 0,
                   recalibrate_method: str = "wanda",
                   save_calib: str = None):
    """Mixed-length request stream through the continuous-batching scheduler.

    Eligible engines (pure token-KV, non-vision) default to chunked prefill:
    prompts stream through the decode chunks' prefill-chunk lane
    (``chunk_size`` tokens per step) instead of blocking bucket waves, so
    TTFT stops paying for other prompts' prefill. ``chunked_prefill=False``
    forces the waved baseline.

    ``shared_prefix > 0`` prepends a common system-prompt prefix of that many
    tokens to every request and registers it with the engine: its KV pages
    are prefetched once and mapped (refcounted) into each request, so only
    the per-request suffix is ever prefilled.

    ``recalibrate_every N > 0`` (implies ``calib_taps``) serves the stream in
    batches of N requests and, between batches, re-scores the DENSE weight
    copy with ``recalibrate_method`` against the engine's live per-channel
    statistics (``Engine.calibration_snapshot``), re-prunes at the engine's
    pattern, and hot-swaps the result in place via ``Engine.repack`` — no
    retrace, the traffic after the swap decodes against freshly calibrated
    masks. ``save_calib`` additionally writes each snapshot to an ``.npz``
    that ``repro.launch.reprune`` can consume offline."""
    calib_taps = calib_taps or recalibrate_every > 0 or bool(save_calib)
    engine, cfg, model, dense_params = build_engine(
        arch, batch, prompt_len, gen, smoke=smoke,
        pruned=pruned, extra_len=shared_prefix,
        sampling=sampling, chunk=max(gen // 2, 1),
        paged=paged, page_size=page_size,
        n_pages=n_pages, paged_kernel=paged_kernel,
        mesh=mesh, compressed24=compressed24,
        compressed24_kernel=compressed24_kernel,
        self_spec=self_spec, draft_k=draft_k,
        chunked_prefill=chunked_prefill,
        chunk_size=chunk_size, calib_taps=calib_taps)
    if engine.chunked_prefill:
        print(f"[serve] chunked prefill: {chunk_size} prompt tokens per "
              "decode step through the unified step program")
    rng = np.random.default_rng(7)
    prefix = None
    if shared_prefix > 0:
        prefix = rng.integers(0, cfg.vocab_size, shared_prefix).astype(np.int32)
        n_shared = engine.register_prefix(prefix)
        print(f"[serve] shared prefix registered: {n_shared}/{shared_prefix} "
              f"tokens ({n_shared // page_size} pages)")
    reqs = []
    for i in range(n_requests):
        body = rng.integers(0, cfg.vocab_size,
                            int(rng.integers(prompt_len // 2, prompt_len + 1)),
                            ).astype(np.int32)
        toks = body if prefix is None else np.concatenate([prefix, body])
        reqs.append(Request(i, toks,
                            int(rng.integers(max(gen // 2, 1), gen + 1)),
                            vision_embeds=_stub_vision(cfg, rng)))
    t0 = time.perf_counter()
    if recalibrate_every > 0:
        from repro.core import scores as SC
        from repro.core.pruner import reprune_from_stats
        comps, n_swaps = [], 0
        rp_cfg = PruneConfig(method=recalibrate_method,
                             pattern=pruned or "2:4")
        for lo in range(0, len(reqs), recalibrate_every):
            comps += Scheduler(engine).run(reqs[lo:lo + recalibrate_every])
            if lo + recalibrate_every >= len(reqs):
                break  # stream done: no traffic left to serve re-pruned
            snap = engine.calibration_snapshot()
            calib = None
            if SC.get_score(recalibrate_method).grad is not None:
                # gradient blends replay a token window; live channel stats
                # still come from the snapshot
                calib = calibration_batch(cfg.vocab_size, 8, prompt_len,
                                          seed=17 + lo)
            new_params = reprune_from_stats(model, dense_params,
                                            snap["stats"], rp_cfg,
                                            calib=calib)
            engine.repack(new_params)
            n_swaps += 1
        if n_swaps:
            print(f"[serve] recalibrated + repacked {n_swaps}x with "
                  f"{recalibrate_method} from live traffic")
    else:
        comps = Scheduler(engine).run(reqs)
    wall = time.perf_counter() - t0
    if save_calib:
        from repro.launch.reprune import save_snapshot
        snap = engine.calibration_snapshot()
        save_snapshot(save_calib, snap)
        print(f"[serve] calibration snapshot ({int(snap['tokens'])} tokens) "
              f"-> {save_calib}")
    n_tok = sum(len(c.tokens) for c in comps)
    if shared_prefix > 0:
        print(f"[serve] prefill tokens skipped via shared pages: "
              f"{engine.stats['shared_tokens_saved']}")
    ttfts = [c.ttft_s for c in comps]
    tpots = [t for c in comps for t in c.tpot_s]
    pct = percentile
    print(f"[serve] {len(comps)} requests, {n_tok} tokens in {wall:.2f}s "
          f"({len(comps) / wall:.1f} req/s, {n_tok / wall:.0f} tok/s)")
    print(f"[serve] TTFT p50={pct(ttfts, .5)*1e3:.0f}ms p95={pct(ttfts, .95)*1e3:.0f}ms  "
          f"TPOT p50={pct(tpots, .5)*1e3:.1f}ms p95={pct(tpots, .95)*1e3:.1f}ms")
    return comps


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama1-7b")
    ap.add_argument("--batch", type=int, default=4, help="slots / wave size")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--pruned", default=None, help="e.g. 2:4")
    ap.add_argument("--requests", type=int, default=0,
                    help=">0: run a mixed-length request stream through the "
                         "continuous-batching scheduler")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus sampling mass (applied after --top-k; "
                         ">= 1 disables)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--dense-pool", action="store_true",
                    help="use the dense (L, n_slots, max_len) KV pool "
                         "instead of the paged arena")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page (paged pool)")
    ap.add_argument("--n-pages", type=int, default=None,
                    help="KV arena pages; default n_slots * ceil(max_len / "
                         "page_size) (shrink it to cap KV HBM)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="with --requests: shared system-prompt tokens, "
                         "prefetched once into refcounted pages")
    ap.add_argument("--gather-decode", action="store_true",
                    help="force the materialising-gather paged read (the "
                         "parity reference); default picks the Pallas "
                         "paged-attention kernel on TPU, the gather "
                         "elsewhere")
    ap.add_argument("--paged-attn-kernel", action="store_true",
                    help="force the Pallas paged-attention kernel even "
                         "off-TPU (interpret mode — slow, correctness "
                         "only)")
    ap.add_argument("--compressed-24", default=None,
                    choices=["auto", "on", "off", "masked"],
                    help="serve 2:4-pruned projections from compacted "
                         "(vals + 2-bit idx) storage. auto (default): "
                         "compress whatever passes the 2:4 check; on: "
                         "require at least one compressed projection; "
                         "masked: keep dense weights + int8 masks (the "
                         "parity/throughput reference)")
    ap.add_argument("--sparse-24-kernel", action="store_true",
                    help="force the Pallas sparse_matmul24 decode kernel "
                         "even off-TPU (interpret mode — slow, correctness "
                         "only); default picks it on TPU, the XLA "
                         "decompress-once path elsewhere")
    ap.add_argument("--self-spec", action="store_true",
                    help="self-speculative decoding: draft with a wanda++ "
                         "2:4-pruned copy of the target's own weights, "
                         "verify with the target (greedy output is "
                         "bit-exact vs target-only decode)")
    ap.add_argument("--draft-k", type=int, default=4,
                    help="with --self-spec: drafter tokens proposed per "
                         "verify step (accepted prefix + 1 emitted)")
    ap.add_argument("--chunk-size", type=int, default=16,
                    help="with --requests: prompt tokens the prefill-chunk "
                         "lane processes per decode step (chunked prefill)")
    ap.add_argument("--no-chunked-prefill", action="store_true",
                    help="with --requests: force bucket-wave prefill (the "
                         "latency baseline) instead of chunked prefill "
                         "interleaved with decode")
    ap.add_argument("--calib-taps", action="store_true",
                    help="with --requests: collect Wanda-style per-channel "
                         "input statistics from live traffic inside the "
                         "jitted step programs (zero extra traces / host "
                         "syncs; greedy output is bit-exact vs taps off)")
    ap.add_argument("--recalibrate-every", type=int, default=0, metavar="N",
                    help="with --requests: every N requests, re-score the "
                         "dense weights against the live statistics "
                         "(--recalibrate-method), re-prune at the serving "
                         "pattern and hot-swap via Engine.repack (implies "
                         "--calib-taps)")
    ap.add_argument("--recalibrate-method", default="wanda",
                    help="pruning score for online recalibration (see "
                         "core/scores.py registry; default wanda — "
                         "statistics-only, no gradient replay)")
    ap.add_argument("--save-calib", default=None, metavar="FILE.npz",
                    help="with --requests: write the final calibration "
                         "snapshot to FILE.npz for offline re-pruning "
                         "(python -m repro.launch.reprune; implies "
                         "--calib-taps)")
    ap.add_argument("--mesh", default=None, metavar="DATA,MODEL",
                    help="shard the engine over a (data, model) device mesh "
                         "(e.g. 4,2): params by the sharding rule table, "
                         "slots/block tables over data, KV heads over "
                         "model. Needs data*model devices (CPU: set "
                         "XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=N). Default: single-device engine")
    args = ap.parse_args()
    mesh = parse_mesh(args.mesh) if args.mesh else None
    paged_kernel = True if args.paged_attn_kernel else \
        (False if args.gather_decode else None)
    sparse_kernel = True if args.sparse_24_kernel else None
    sampling = SamplingConfig(temperature=args.temperature, top_k=args.top_k,
                              top_p=args.top_p, seed=args.seed)
    if args.requests > 0:
        serve_requests(args.arch, args.requests, args.batch, args.prompt_len,
                       args.gen, smoke=args.smoke, pruned=args.pruned,
                       sampling=sampling, paged=not args.dense_pool,
                       page_size=args.page_size, n_pages=args.n_pages,
                       shared_prefix=args.shared_prefix,
                       paged_kernel=paged_kernel, mesh=mesh,
                       compressed24=args.compressed_24,
                       compressed24_kernel=sparse_kernel,
                       self_spec=args.self_spec, draft_k=args.draft_k,
                       chunked_prefill=False if args.no_chunked_prefill
                       else None,
                       chunk_size=args.chunk_size,
                       calib_taps=args.calib_taps,
                       recalibrate_every=args.recalibrate_every,
                       recalibrate_method=args.recalibrate_method,
                       save_calib=args.save_calib)
    else:
        serve(args.arch, args.batch, args.prompt_len, args.gen,
              smoke=args.smoke, pruned=args.pruned, sampling=sampling,
              paged=not args.dense_pool, page_size=args.page_size,
              n_pages=args.n_pages, paged_kernel=paged_kernel, mesh=mesh,
              compressed24=args.compressed_24,
              compressed24_kernel=sparse_kernel,
              self_spec=args.self_spec, draft_k=args.draft_k)


if __name__ == "__main__":
    main()
