"""Batched serving driver: prefill once, then greedy decode with a KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --smoke \
        --batch 4 --prompt-len 32 --gen 16 [--pruned 2:4]

Demonstrates the paper's deployment story: the same model runs dense or
Wanda++-pruned (2:4 zeros in the weights); benchmarks/table7 quantifies the
weight-traffic reduction the sparsity buys on the decode path.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import PruneConfig
from repro.data import calibration_batch
from repro.models.model import Model


def serve(arch: str, batch: int = 4, prompt_len: int = 32, gen: int = 16,
          smoke: bool = True, pruned: str = None, max_len: int = None):
    cfg = get_config(arch)
    if smoke:
        cfg = cfg.reduced()
    if cfg.is_encoder_only:
        raise SystemExit("encoder-only arch has no decode path")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    if pruned:
        from repro.core.pruner import prune_model
        pcfg = PruneConfig(method="wanda++", pattern=pruned, n_calib=8,
                           calib_len=prompt_len, ro_iters=1, ro_samples=4)
        calib = calibration_batch(cfg.vocab_size, pcfg.n_calib, pcfg.calib_len)
        params, _ = prune_model(model, params, calib, pcfg)
        print(f"[serve] pruned with wanda++ {pruned}")

    max_len = max_len or (prompt_len + gen)
    prompts = calibration_batch(cfg.vocab_size, batch, prompt_len, seed=7)

    # prefill: full forward, prime the cache, grab the first token
    t0 = time.perf_counter()
    logits, _, cache_s = jax.jit(
        lambda p, b: model.forward(p, b, return_cache=True))(
            params, {"tokens": prompts})
    first = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
    # pad the prefill cache out to max_len slots
    cache = model.init_cache(batch, max_len)
    if cfg.family in ("dense", "vlm", "moe"):
        k_s, v_s = cache_s
        ck = jax.lax.dynamic_update_slice(cache[0], k_s, (0, 0, 0, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache[1], v_s, (0, 0, 0, 0, 0))
        cache = (ck, cv)
    elif cfg.family == "ssm":
        cache = cache_s  # state caches carry no length dim
    ttft = time.perf_counter() - t0

    step = jax.jit(lambda p, c, i: model.decode_step(p, i, c))
    toks = [first]
    tok = first
    t1 = time.perf_counter()
    for i in range(gen - 1):
        logits, cache = step(params, cache,
                             {"token": tok, "pos": jnp.int32(prompt_len + i)})
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        toks.append(tok)
    jax.block_until_ready(tok)
    tpot = (time.perf_counter() - t1) / max(gen - 1, 1)
    out = jnp.stack(toks, axis=1)
    print(f"[serve] batch={batch} TTFT={ttft*1e3:.1f}ms TPOT={tpot*1e3:.2f}ms")
    print(f"[serve] generated tokens[0]: {out[0].tolist()}")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama1-7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--pruned", default=None, help="e.g. 2:4")
    args = ap.parse_args()
    serve(args.arch, args.batch, args.prompt_len, args.gen,
          smoke=args.smoke, pruned=args.pruned)


if __name__ == "__main__":
    main()
