"""Step functions lowered by the dry-run and driven by train.py / serve.py.

``make_train_step`` builds the full production step: gradient accumulation
(lax.scan over microbatches), remat'd blocks, global-norm clipping, AdamW
with configurable state dtype, cosine schedule, optional sparsity-preserving
grad masking and top-k gradient compression with error feedback.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig
from repro.models.model import Model
from repro.optim import (adamw_init, adamw_update, clip_by_global_norm,
                         cosine_warmup, topk_compress_update)
from repro.optim.optimizers import adafactor_init, adafactor_update


def init_train_state(model: Model, params, tc: TrainConfig,
                     compress_ratio: Optional[float] = None) -> Dict[str, Any]:
    sd = jnp.bfloat16 if tc.optimizer_state_dtype == "bfloat16" else jnp.float32
    opt = (adafactor_init(params) if tc.optimizer == "adafactor"
           else adamw_init(params, sd))
    state = {"params": params, "opt": opt, "step": jnp.zeros((), jnp.int32)}
    if compress_ratio:
        state["ef_error"] = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return state


def make_train_step(model: Model, tc: TrainConfig, trainable=None,
                    grad_mask=None, compress_ratio: Optional[float] = None,
                    act_pspec=None):
    """Returns step(state, batch) -> (state, metrics)."""

    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch, remat=tc.remat,
                                   remat_groups=tc.remat_groups,
                                   act_pspec=act_pspec)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    acc_dt = jnp.bfloat16 if tc.accum_dtype == "bfloat16" else jnp.float32

    def compute_grads(params, batch):
        if tc.accum_steps == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return loss, metrics, grads

        def split(x):
            return x.reshape(tc.accum_steps, x.shape[0] // tc.accum_steps,
                             *x.shape[1:])

        micro = jax.tree_util.tree_map(split, batch)

        def body(acc, mb):
            loss_a, metrics_a, g_a = acc
            (loss, metrics), g = grad_fn(params, mb)
            g_a = jax.tree_util.tree_map(
                lambda a, b: (a + b.astype(acc_dt)).astype(acc_dt), g_a, g)
            return (loss_a + loss, metrics_a, g_a), 0

        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, acc_dt), params)
        metrics0 = {"lm_loss": jnp.zeros((), jnp.float32),
                    "aux_loss": jnp.zeros((), jnp.float32)}
        (loss, metrics, grads), _ = jax.lax.scan(
            body, (jnp.zeros(()), metrics0, zeros), micro)
        grads = jax.tree_util.tree_map(
            lambda g: (g.astype(jnp.float32) / tc.accum_steps), grads)
        return loss / tc.accum_steps, metrics, grads

    def step(state, batch):
        params = state["params"]
        loss, metrics, grads = compute_grads(params, batch)
        if compress_ratio:
            grads, ef = topk_compress_update(grads, state["ef_error"],
                                             compress_ratio)
        grads, gnorm = clip_by_global_norm(grads, tc.grad_clip)
        lr = cosine_warmup(state["step"], tc.learning_rate, tc.warmup_steps,
                           tc.total_steps)
        update = adafactor_update if tc.optimizer == "adafactor" else adamw_update
        new_params, new_opt = update(
            params, grads, state["opt"], tc, lr,
            trainable=trainable, grad_mask=grad_mask)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        if compress_ratio:
            new_state["ef_error"] = ef
        out_metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr, **metrics}
        return new_state, out_metrics

    return step


def make_serve_step(model: Model):
    """Greedy single-token decode: (params, cache, inputs) -> (token, cache)."""

    def step(params, cache, inputs):
        logits, new_cache = model.decode_step(params, inputs, cache)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_token, new_cache

    return step


def make_prefill_step(model: Model):
    def step(params, inputs):
        # production prefill: only the last position's logits are needed
        logits, aux = model.forward(params, inputs, last_only=True)
        return jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)

    return step
