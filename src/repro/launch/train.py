"""Fault-tolerant training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --smoke \
        --steps 200 --ckpt-dir /tmp/run1

Fault tolerance in practice:
  * periodic async checkpoints (atomic publish; never blocks the step loop)
  * auto-resume from the newest valid checkpoint; the data stream is a pure
    function of step, so the token order replays exactly
  * elastic restore: the checkpoint stores logical metadata only — restoring
    onto a different mesh re-shards on load (see --reshard-test)
  * step-time watchdog flags stragglers (steps > k x median)
  * SIGTERM (preemption) handler: write a final checkpoint, exit cleanly
"""
from __future__ import annotations

import argparse
import signal
import statistics
import sys
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.configs.base import TrainConfig
from repro.data import synthetic_lm_stream
from repro.launch.steps import init_train_state, make_train_step
from repro.models.model import Model


def train_loop(arch: str, steps: int, ckpt_dir: Optional[str] = None,
               smoke: bool = True, ckpt_every: int = 50, batch: int = 8,
               seq_len: int = 64, tc: Optional[TrainConfig] = None,
               log_every: int = 10, mesh=None, die_at_step: Optional[int] = None):
    """Returns (final state, losses). `die_at_step` simulates a node failure
    (used by the fault-tolerance test)."""
    cfg = get_config(arch)
    if smoke:
        cfg = cfg.reduced()
    model = Model(cfg)
    tc = tc or TrainConfig(total_steps=steps, warmup_steps=max(steps // 10, 1))
    step_fn = jax.jit(make_train_step(model, tc), donate_argnums=(0,))

    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    params = model.init(jax.random.PRNGKey(0))
    state = init_train_state(model, params, tc)
    start_step = 0
    if mgr is not None:
        restored, extra = mgr.restore(state)
        if restored is not None:
            state, start_step = restored, extra["step"]
            print(f"[train] resumed from step {start_step}")

    # preemption: checkpoint and exit cleanly on SIGTERM
    preempted = {"flag": False}

    def _on_sigterm(signum, frame):
        preempted["flag"] = True

    old = signal.signal(signal.SIGTERM, _on_sigterm)

    stream = synthetic_lm_stream(cfg.vocab_size, batch, seq_len,
                                 start_step=start_step)
    losses, step_times = [], []
    try:
        for i, data in zip(range(start_step, steps), stream):
            t0 = time.perf_counter()
            batch_d = {"tokens": data["tokens"], "labels": data["labels"]}
            state, metrics = step_fn(state, batch_d)
            loss = float(metrics["loss"])
            losses.append(loss)
            dt = time.perf_counter() - t0
            step_times.append(dt)
            # straggler watchdog: flag slow steps (node degradation signal)
            if len(step_times) > 20:
                med = statistics.median(step_times[-20:])
                if dt > 3.0 * med:
                    print(f"[watchdog] step {i} took {dt:.3f}s "
                          f"(median {med:.3f}s) — straggler suspected")
            if i % log_every == 0:
                print(f"[train] step {i} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f}")
            if mgr is not None and (i + 1) % ckpt_every == 0:
                mgr.save(i + 1, state)
            if die_at_step is not None and i + 1 == die_at_step:
                raise SystemExit(42)  # simulated node failure
            if preempted["flag"]:
                print("[train] preemption signal — checkpointing and exiting")
                if mgr is not None:
                    mgr.save(i + 1, state, block=True)
                return state, losses
        if mgr is not None:
            mgr.save(steps, state, block=True)
    finally:
        signal.signal(signal.SIGTERM, old)
        if mgr is not None:
            mgr.wait()
    return state, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama1-7b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--die-at-step", type=int, default=None)
    args = ap.parse_args()
    _, losses = train_loop(args.arch, args.steps, args.ckpt_dir,
                           smoke=args.smoke, ckpt_every=args.ckpt_every,
                           batch=args.batch, seq_len=args.seq_len,
                           die_at_step=args.die_at_step)
    print(f"[train] done; final loss {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
