"""Decoder/encoder blocks per architecture family.

Every block exposes the same contract so the layer scan, the Wanda++ pruner,
and the serving path treat all families uniformly:

    block_apply(bp, x, cfg, positions, cache=None, cache_index=None,
                block_table=None, paged_kernel=True, lin=None, elin=None)
        -> (x_out, new_cache, aux)

``block_table`` selects the paged KV-cache path in ``layers.attention``
(``cache`` is then a (n_pages, page_size, KV, hd) arena slice) and
``paged_kernel`` picks the Pallas decode kernel (default) vs the gather
parity reference there; SSM state caches have no length axis, so SSM/hybrid
blocks accept and ignore both.

``PRUNABLE[family]`` maps each matmul's tap name (the string passed to
``lin``/``elin``) to its weight path inside the block param tree — the pruner
uses this to attach scores/masks to the right tensors.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers, mamba2, moe
from repro.models import state_spec as SPEC
from repro.models.layers import init_rmsnorm, rmsnorm, scoped
from repro.models.state_spec import CacheSpec, StateGroup, StateLeaf


# tap name -> weight path within block params. 2-D matmul weights only
# (norms / biases / SSM diagonals are never pruned, matching the paper).
PRUNABLE = {
    "dense": {
        "attn.wq": ("attn", "wq", "w"),
        "attn.wk": ("attn", "wk", "w"),
        "attn.wv": ("attn", "wv", "w"),
        "attn.wo": ("attn", "wo", "w"),
        "mlp.wg": ("mlp", "wg", "w"),
        "mlp.wu": ("mlp", "wu", "w"),
        "mlp.wd": ("mlp", "wd", "w"),
    },
    "encoder": {
        "attn.wq": ("attn", "wq", "w"),
        "attn.wk": ("attn", "wk", "w"),
        "attn.wv": ("attn", "wv", "w"),
        "attn.wo": ("attn", "wo", "w"),
        "mlp.w1": ("mlp", "w1", "w"),
        "mlp.w2": ("mlp", "w2", "w"),
    },
    "moe": {
        "attn.wq": ("attn", "wq", "w"),
        "attn.wk": ("attn", "wk", "w"),
        "attn.wv": ("attn", "wv", "w"),
        "attn.wo": ("attn", "wo", "w"),
        "moe.router": ("moe", "router", "w"),
        "moe.wg": ("moe", "wg"),  # (E, D, F) expert-stacked
        "moe.wu": ("moe", "wu"),
        "moe.wd": ("moe", "wd"),
        "moe.shared.wg": ("moe", "shared", "wg", "w"),
        "moe.shared.wu": ("moe", "shared", "wu", "w"),
        "moe.shared.wd": ("moe", "shared", "wd", "w"),
    },
    "ssm": {
        "mamba.in_proj": ("mamba", "in_proj", "w"),
        "mamba.out_proj": ("mamba", "out_proj", "w"),
    },
    "hybrid": {
        "mamba.in_proj": ("mamba", "in_proj", "w"),
        "mamba.out_proj": ("mamba", "out_proj", "w"),
    },
    # Zamba2's shared attention block (pruned once; weights shared across sites)
    "hybrid_shared": {
        "attn.wq": ("attn", "wq", "w"),
        "attn.wk": ("attn", "wk", "w"),
        "attn.wv": ("attn", "wv", "w"),
        "attn.wo": ("attn", "wo", "w"),
        "mlp.wg": ("mlp", "wg", "w"),
        "mlp.wu": ("mlp", "wu", "w"),
        "mlp.wd": ("mlp", "wd", "w"),
    },
}


def prunable_table(cfg: ModelConfig):
    if cfg.family in ("dense", "vlm"):
        return PRUNABLE["dense"]
    if cfg.family == "audio":
        return PRUNABLE["encoder"]
    if cfg.num_shared_experts == 0 and cfg.family == "moe":
        return {k: v for k, v in PRUNABLE["moe"].items() if "shared" not in k}
    return PRUNABLE[cfg.family]


# ---------------------------------------------------------------------------
# 2:4 compressed-weight serving: engine-build param transform
# ---------------------------------------------------------------------------

def _tget(t, path):
    for p in path:
        if not isinstance(t, dict) or p not in t:
            return None
        t = t[p]
    return t


def _tset(t, path, val):
    if len(path) == 1:
        return {**t, path[0]: val}
    return {**t, path[0]: _tset(t[path[0]], path[1:], val)}


def compress_params24(cfg: ModelConfig, params, *, keep_dense: bool = True,
                      masked: bool = False):
    """Detect 2:4-sparse projections and rewrite them for serving.

    Walks every prunable 2-D projection (``prunable_table``; expert stacks
    and non-``w`` leaves are skipped) over the stacked ``blocks`` axis —
    and Zamba2's unstacked ``shared_attn`` — and, where the weight passes
    ``sparsity_check24`` (with K % 8 == 0 for the 2-bit index packing):

      default      replace ``w`` with the compacted (``w24_vals``,
                   ``w24_idx``) pair (kernels/ops.py compact24 — 0.5625x
                   bf16 / 0.53125x f32 weight bytes). ``keep_dense=True``
                   (the off-TPU serving mode) additionally materializes the
                   dense copy ONCE via decompress24 — bit-exact, so greedy
                   decode matches the uncompressed engine token for token —
                   because without a sparse matmul unit a per-step
                   decompression only adds work. On TPU (``keep_dense=
                   False``) only the packed pair ships, and the Pallas
                   kernel reads it directly (layers.sparse24_lin).
      masked=True  attach the int8 keep-mask as ``mask24`` instead (keep
                   ``w``): the masked-dense reference mode the serving
                   benchmark gates against (layers.masked24_lin).

    Random-init or dense-trained weights never pass the sparsity check, so
    the transform is an exact no-op for non-pruned checkpoints. Returns
    ``(new_params, n_compressed)``.
    """
    from repro.kernels.ops import compact24, decompress24, sparsity_check24

    def xform(tree, table):
        n = 0
        if tree is None:
            return tree, 0
        for _, path in table.items():
            if path[-1] != "w":
                continue  # expert-stacked (E, D, F) leaves: no serve kernel
            w = _tget(tree, path)
            if w is None or w.ndim < 2 or w.shape[-2] % 8 != 0:
                continue
            if not sparsity_check24(w):
                continue
            pdict = dict(_tget(tree, path[:-1]))
            if masked:
                pdict["mask24"] = (w != 0).astype(jnp.int8)
            else:
                vals, idx = compact24(w)
                del pdict["w"]
                pdict["w24_vals"] = vals
                pdict["w24_idx"] = idx
                if keep_dense:
                    pdict["w"] = decompress24(vals, idx)
            tree = _tset(tree, path[:-1], pdict)
            n += 1
        return tree, n

    out = dict(params)
    out["blocks"], n = xform(params["blocks"], prunable_table(cfg))
    if cfg.family == "hybrid" and "shared_attn" in params:
        out["shared_attn"], ns = xform(params["shared_attn"],
                                       PRUNABLE["hybrid_shared"])
        n += ns
    return out, n


# ---------------------------------------------------------------------------
# dense / vlm / audio transformer block
# ---------------------------------------------------------------------------

def init_transformer_block(key, cfg: ModelConfig, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": init_rmsnorm(cfg.d_model, dtype),
        "attn": layers.init_attention(k1, cfg, dtype),
        "ln2": init_rmsnorm(cfg.d_model, dtype),
        "mlp": layers.init_mlp(k2, cfg, dtype),
    }


def transformer_block(bp, x, cfg, positions, cache=None, cache_index=None,
                      block_table=None, paged_kernel=True, seq_lens=None,
                      lin=None, elin=None):
    h, new_cache = layers.attention(
        bp["attn"], rmsnorm(bp["ln1"], x, cfg.norm_eps), cfg, positions,
        kv_cache=cache, cache_index=cache_index, block_table=block_table,
        paged_kernel=paged_kernel, lin=scoped(lin, "attn"),
    )
    x = x + h
    x = x + layers.mlp(bp["mlp"], rmsnorm(bp["ln2"], x, cfg.norm_eps), cfg,
                       lin=scoped(lin, "mlp"))
    return x, new_cache, jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# MoE block
# ---------------------------------------------------------------------------

def init_moe_block(key, cfg: ModelConfig, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": init_rmsnorm(cfg.d_model, dtype),
        "attn": layers.init_attention(k1, cfg, dtype),
        "ln2": init_rmsnorm(cfg.d_model, dtype),
        "moe": moe.init_moe(k2, cfg, dtype),
    }


def moe_block(bp, x, cfg, positions, cache=None, cache_index=None,
              block_table=None, paged_kernel=True, seq_lens=None, lin=None,
              elin=None):
    h, new_cache = layers.attention(
        bp["attn"], rmsnorm(bp["ln1"], x, cfg.norm_eps), cfg, positions,
        kv_cache=cache, cache_index=cache_index, block_table=block_table,
        paged_kernel=paged_kernel, lin=scoped(lin, "attn"),
    )
    x = x + h
    h, aux = moe.moe_mlp(bp["moe"], rmsnorm(bp["ln2"], x, cfg.norm_eps), cfg,
                         lin=scoped(lin, "moe"), elin=_scoped_elin(elin, "moe"))
    return x + h, new_cache, aux


def _scoped_elin(elin, prefix):
    if elin is None:
        elin = moe.default_elin
    return lambda name, w, xin, eq, occ=None: \
        elin(f"{prefix}.{name}", w, xin, eq, occ)


# ---------------------------------------------------------------------------
# SSM (Mamba2) block
# ---------------------------------------------------------------------------

def init_ssm_block(key, cfg: ModelConfig, dtype):
    return {
        "ln": init_rmsnorm(cfg.d_model, dtype),
        "mamba": mamba2.init_mamba_block(key, cfg, dtype),
    }


def ssm_block(bp, x, cfg, positions, cache=None, cache_index=None,
              block_table=None, paged_kernel=True, seq_lens=None, lin=None,
              elin=None):
    xin = rmsnorm(bp["ln"], x, cfg.norm_eps)
    ml = scoped(lin, "mamba")
    if cache is None or x.shape[1] > 1:
        ssm_state = cache[0] if cache is not None else None
        h, new_cache = mamba2.mamba_block(bp["mamba"], xin, cfg,
                                          ssm_state=ssm_state,
                                          seq_lens=seq_lens, lin=ml)
    else:
        h, new_cache = mamba2.mamba_decode_step(
            bp["mamba"], xin, cfg, cache[0], cache[1], lin=ml)
    return x + h, new_cache, jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# hybrid (Zamba2): mamba backbone + ONE shared attention block every k layers
# ---------------------------------------------------------------------------

def init_shared_attn_block(key, cfg: ModelConfig, dtype):
    return init_transformer_block(key, cfg, dtype)


def hybrid_layer(bp_mamba, shared_bp, x, cfg, positions, layer_idx,
                 mamba_cache=None, attn_cache=None, cache_index=None,
                 block_table=None, paged_kernel=True, seq_lens=None,
                 lin=None, elin=None):
    """One hybrid layer: maybe-shared-attention, then a mamba block.

    attn_cache: (k, v) slice for this layer's application site or None —
    with ``block_table`` it is that site's (n_pages, page_size, KV, hd)
    arena slice (paged serving).
    Returns (x, new_mamba_cache, new_attn_cache, aux).
    """
    every = cfg.hybrid_attn_every
    is_attn = (layer_idx % every) == 0

    def with_attn(x):
        y, kv, _ = transformer_block(
            shared_bp, x, cfg, positions, cache=attn_cache,
            cache_index=cache_index, block_table=block_table,
            paged_kernel=paged_kernel, lin=scoped(lin, "shared"))
        return y, kv

    def without_attn(x):
        if attn_cache is not None:
            return x, attn_cache
        B, S = x.shape[0], x.shape[1]
        hd = cfg.resolved_head_dim
        kv = (jnp.zeros((B, S, cfg.num_kv_heads, hd), x.dtype),
              jnp.zeros((B, S, cfg.num_kv_heads, hd), x.dtype))
        return x, kv

    x, new_attn_cache = jax.lax.cond(is_attn, with_attn, without_attn, x)
    x, new_mamba_cache, aux = ssm_block(
        {"ln": bp_mamba["ln"], "mamba": bp_mamba["mamba"]}, x, cfg, positions,
        cache=mamba_cache, cache_index=cache_index, seq_lens=seq_lens,
        lin=lin)
    return x, new_mamba_cache, new_attn_cache, aux


INIT = {
    "dense": init_transformer_block,
    "vlm": init_transformer_block,
    "audio": init_transformer_block,
    "moe": init_moe_block,
    "ssm": init_ssm_block,
    "hybrid": init_ssm_block,  # per-layer part; shared block separate
}


# ---------------------------------------------------------------------------
# per-family cache state specs (see models/state_spec.py)
# ---------------------------------------------------------------------------

def n_attn_apps(cfg: ModelConfig) -> int:
    """Application sites of the hybrid family's shared attention block."""
    return (cfg.num_layers + cfg.hybrid_attn_every - 1) // cfg.hybrid_attn_every


def _kv_group(cfg: ModelConfig, kv_dtype, apps: int, name="kv") -> StateGroup:
    hd = cfg.resolved_head_dim
    # pspec: head dim splits over the mesh `model` axis (same logical name
    # the wk/wv param rules use, so KV state lands where its heads compute)
    leaf = lambda n: StateLeaf(n, (cfg.num_kv_heads, hd), kv_dtype,
                               pspec=("kv_heads", None))
    return StateGroup(name, SPEC.KV, apps, (leaf("k"), leaf("v")))


def _mamba_group(cfg: ModelConfig, dtype, apps: int, name="mamba") -> StateGroup:
    conv_dim = cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state
    return StateGroup(name, SPEC.RECURRENT, apps, (
        StateLeaf("ssm", (cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state),
                  jnp.float32, pspec=("ssm_heads", None, None)),
        StateLeaf("conv", (cfg.ssm_conv - 1, conv_dim), dtype,
                  pspec=(None, "inner")),
    ))


def cache_spec(cfg: ModelConfig, param_dtype, kv_dtype=None) -> CacheSpec:
    """The family's declarative decode-state spec. Attention KV leaves use
    ``kv_dtype`` (int8 KV quantization); recurrent leaves keep their own
    dtypes (SSD state is always f32, the conv window follows the params).
    Encoder-only families have no decode state: empty spec."""
    kv_dt = kv_dtype if kv_dtype is not None else param_dtype
    if cfg.family in ("dense", "vlm", "moe"):
        return CacheSpec((_kv_group(cfg, kv_dt, cfg.num_layers),))
    if cfg.family == "ssm":
        return CacheSpec((_mamba_group(cfg, param_dtype, cfg.num_layers),))
    if cfg.family == "hybrid":
        return CacheSpec((
            _kv_group(cfg, kv_dt, n_attn_apps(cfg), name="attn"),
            _mamba_group(cfg, param_dtype, cfg.num_layers),
        ))
    return CacheSpec(())

APPLY = {
    "dense": transformer_block,
    "vlm": transformer_block,
    "audio": transformer_block,
    "moe": moe_block,
    "ssm": ssm_block,
}
