"""Chunked (flash-style) attention with a custom VJP — pure JAX.

Online-softmax over KV chunks inside a ``lax.scan``: the (Sq x Skv) score
matrix never materializes in HBM, bounding attention memory at
O(Sq * chunk). The custom VJP recomputes scores per chunk in the backward
pass (saving only out + logsumexp), so long-context prefill fits the v5e
HBM roofline. Lowered to plain HLO => works under SPMD on any backend.

Layout matches layers._sdpa: q (B, Sq, KV, G, hd); k, v (B, Skv, KV, hd).
Causality is positional: q_pos (B, Sq), kv_pos (B, Skv); None => bidirectional.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _chunks(x, axis, size):
    n = x.shape[axis]
    assert n % size == 0, (n, size)
    nc = n // size
    new_shape = x.shape[:axis] + (nc, size) + x.shape[axis + 1:]
    return jnp.moveaxis(x.reshape(new_shape), axis, 0)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def flash_attention(q, k, v, q_pos, kv_pos, scale: float, chunk: int):
    out, _ = _fwd_impl(q, k, v, q_pos, kv_pos, scale, chunk)
    return out


def _fwd_impl(q, k, v, q_pos, kv_pos, scale, chunk):
    B, Sq, KV, G, hd = q.shape
    Skv = k.shape[1]
    chunk = min(chunk, Skv)
    qf = q.astype(jnp.float32)
    kc = _chunks(k.astype(jnp.float32), 1, chunk)  # (nc, B, c, KV, hd)
    vc = _chunks(v.astype(jnp.float32), 1, chunk)
    pc = _chunks(kv_pos, 1, chunk) if kv_pos is not None else None

    m0 = jnp.full((B, KV, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, KV, G, Sq, hd), jnp.float32)

    def body(carry, xs):
        m, l, acc = carry
        if pc is None:
            k_i, v_i = xs
            mask = None
        else:
            k_i, v_i, p_i = xs
            mask = p_i[:, None, None, None, :] <= q_pos[:, None, None, :, None]
        s = jnp.einsum("bqkgh,bskh->bkgqs", qf, k_i) * scale
        if mask is not None:
            s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum("bkgqs,bskh->bkgqh", p, v_i)
        return (m_new, l, acc), 0

    xs = (kc, vc) if pc is None else (kc, vc, pc)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), xs)
    l_safe = jnp.maximum(l, 1e-30)
    out = (acc / l_safe[..., None])
    out = jnp.moveaxis(out, -2, 1)  # (B, KV, G, Sq, hd) -> (B, Sq, KV, G, hd)
    lse = m + jnp.log(l_safe)  # (B, KV, G, Sq)
    return out.astype(q.dtype), lse


def _flash_fwd(q, k, v, q_pos, kv_pos, scale, chunk):
    out, lse = _fwd_impl(q, k, v, q_pos, kv_pos, scale, chunk)
    return out, (q, k, v, q_pos, kv_pos, out, lse)


def _flash_bwd(scale, chunk, res, dout):
    q, k, v, q_pos, kv_pos, out, lse = res
    B, Sq, KV, G, hd = q.shape
    Skv = k.shape[1]
    chunk_ = min(chunk, Skv)
    qf = q.astype(jnp.float32)
    do = dout.astype(jnp.float32)
    of = out.astype(jnp.float32)
    # delta = rowwise(dout . out)
    delta = jnp.einsum("bqkgh,bqkgh->bkgq", do, of)  # (B,KV,G,Sq)

    kc = _chunks(k.astype(jnp.float32), 1, chunk_)
    vc = _chunks(v.astype(jnp.float32), 1, chunk_)
    pc = _chunks(kv_pos, 1, chunk_) if kv_pos is not None else None

    dq0 = jnp.zeros((B, Sq, KV, G, hd), jnp.float32)

    def body(dq, xs):
        if pc is None:
            k_i, v_i = xs
            mask = None
        else:
            k_i, v_i, p_i = xs
            mask = p_i[:, None, None, None, :] <= q_pos[:, None, None, :, None]
        s = jnp.einsum("bqkgh,bskh->bkgqs", qf, k_i) * scale
        if mask is not None:
            s = jnp.where(mask, s, NEG_INF)
        p = jnp.exp(s - lse[..., None])  # (B,KV,G,Sq,c)
        dv_i = jnp.einsum("bkgqs,bqkgh->bskh", p, do)
        dp = jnp.einsum("bqkgh,bskh->bkgqs", do, v_i)
        ds = p * (dp - delta[..., None]) * scale
        dq = dq + jnp.einsum("bkgqs,bskh->bqkgh", ds, k_i)
        dk_i = jnp.einsum("bkgqs,bqkgh->bskh", ds, qf)
        return dq, (dk_i, dv_i)

    xs = (kc, vc) if pc is None else (kc, vc, pc)
    dq, (dkc, dvc) = jax.lax.scan(body, dq0, xs)
    dk = jnp.moveaxis(dkc, 0, 1).reshape(B, Skv, KV, hd)
    dv = jnp.moveaxis(dvc, 0, 1).reshape(B, Skv, KV, hd)
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
            None, None)


flash_attention.defvjp(_flash_fwd, _flash_bwd)
