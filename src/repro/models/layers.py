"""Core neural layers: RMSNorm, RoPE / M-RoPE, GQA attention, (Sw)i(GLU) MLP.

Functional style: ``init_*`` build param dicts, ``apply`` functions are pure.
All block stacks are driven by ``lax.scan`` upstream, so every function here
must be shape-polymorphic in the batch/sequence dims only.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def _normal(key, shape, dtype, fan_in):
    return (jax.random.normal(key, shape, jnp.float32) / math.sqrt(fan_in)).astype(dtype)


def init_linear(key, d_in, d_out, dtype, bias=False):
    p = {"w": _normal(key, (d_in, d_out), dtype, d_in)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    if "lora_a" in p:  # LoRA adapter (scale folded as constant, see core/lora.py)
        y = y + 2.0 * ((x @ p["lora_a"]) @ p["lora_b"]).astype(y.dtype)
    return y


def default_lin(name, p, x):
    """Pluggable matmul backend. Swapped out to (a) tap per-layer inputs for
    Wanda/RGS statistics, (b) apply sparsity masks in-flight (masked24_lin),
    or (c) dispatch to the Pallas 2:4 compacted kernel on the serving path
    (sparse24_lin)."""
    return linear(p, x)


def sparse24_lin(use_kernel: bool = False):
    """Serve-path backend for 2:4-compressed projections (dispatch is
    content-based: params carrying ``w24_vals``/``w24_idx`` from
    blocks.compress_params24 take the compressed path, everything else falls
    through to ``linear``). ``use_kernel=True`` runs the Pallas compacted
    matmul (kernels/sparse_matmul24.py, 0.5625x bf16 weight traffic, bias
    fused); otherwise the engine-build dense copy (``w``, materialized once
    via decompress24 — bit-exact) serves through plain ``linear``, with a
    per-call decompression fallback when no dense copy was kept. The LoRA
    epilogue matches ``linear``'s exactly."""
    def lin(name, p, x):
        if "w24_vals" not in p:
            return linear(p, x)
        if not use_kernel and "w" in p:
            return linear(p, x)
        if use_kernel:
            from repro.kernels.ops import sparse_matmul24
            lead = x.shape[:-1]
            y = sparse_matmul24(x.reshape(-1, x.shape[-1]), p["w24_vals"],
                                p["w24_idx"], bias=p.get("b"))
            y = y.reshape(*lead, y.shape[-1])
        else:
            from repro.kernels.ops import decompress24
            y = x @ decompress24(p["w24_vals"], p["w24_idx"])
            if "b" in p:
                y = y + p["b"]
        if "lora_a" in p:
            y = y + 2.0 * ((x @ p["lora_a"]) @ p["lora_b"]).astype(y.dtype)
        return y
    return lin


def masked24_lin(name, p, x):
    """Masked-dense reference backend: serve (w, mask) with the int8 mask
    applied in-flight on every call — the pre-compression 2:4 serving mode
    (kernels/masked_matmul.py semantics; 1.25x dense weight traffic). Params
    without a ``mask24`` fall through to ``linear``. Numerically the mask
    multiply is an exact no-op on pruner output (w is already zeroed where
    mask == 0), which is what makes the compressed-vs-masked benchmark
    token-comparison bit-exact."""
    if "mask24" not in p:
        return linear(p, x)
    y = x @ (p["w"] * p["mask24"].astype(p["w"].dtype))
    if "b" in p:
        y = y + p["b"]
    if "lora_a" in p:
        y = y + 2.0 * ((x @ p["lora_a"]) @ p["lora_b"]).astype(y.dtype)
    return y


def scoped(lin, prefix):
    if lin is None:
        lin = default_lin
    return lambda name, p, x: lin(f"{prefix}.{name}", p, x)


def input_stats(x, weights=None):
    """Per-input-channel calibration statistics of one matmul call.

    x: (..., in). ``weights`` (optional, broadcastable to x.shape[:-1])
    down-weights or masks tokens — the serve engine passes its live/pad
    masks so idle decode lanes and prompt padding never enter the sums.
    Returns {"sumsq", "abssum", "sum": (in,), "count": ()} in f32 (the
    accumulators are intentionally f32: they run over an entire traffic
    window, and bf16 sums of squares saturate within a few thousand tokens).
    """
    x32 = x.astype(jnp.float32).reshape(-1, x.shape[-1])  # lint: allow(f32-cast)
    if weights is None:
        w = jnp.ones((x32.shape[0],), jnp.float32)  # lint: allow(f32-cast)
    else:
        w = jnp.broadcast_to(weights, x.shape[:-1]).reshape(-1)
        w = w.astype(jnp.float32)  # lint: allow(f32-cast)
    xw = x32 * w[:, None]
    return {"sumsq": jnp.sum(x32 * xw, axis=0),
            "abssum": jnp.sum(jnp.abs(xw), axis=0),
            "sum": jnp.sum(xw, axis=0),
            "count": jnp.sum(w)}


def acc_stats(old, new):
    """Accumulate two ``input_stats`` dicts (None-tolerant on the left)."""
    if old is None:
        return new
    return {k: old[k] + new[k] for k in new}


def stats_lin(lin, taps, weights=None):
    """Wrap any ``lin`` backend with a calibration tap: per-channel input
    stats land in ``taps[name]`` (accumulated), the matmul result is the
    wrapped backend's own — taps change no numerics on the forward path."""
    base = default_lin if lin is None else lin

    def tapped(name, p, x):
        taps[name] = acc_stats(taps.get(name), input_stats(x, weights))
        return base(name, p, x)

    return tapped


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p, x, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * p["scale"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (B, S, H, hd); positions: (B, S) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, hd/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
                sections: Tuple[int, int, int]) -> jnp.ndarray:
    """Multimodal RoPE (Qwen2-VL). positions: (3, B, S) (t, h, w)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs[None, None, None, :]  # (3,B,S,hd/2)
    # select which of the 3 position streams drives each frequency band;
    # sections are proportional so reduced head_dims keep the same split
    half = hd // 2
    total = sum(sections)
    edges = [round(half * sum(sections[: i + 1]) / total) for i in range(len(sections))]
    sizes = [edges[0]] + [edges[i] - edges[i - 1] for i in range(1, len(edges))]
    sec = jnp.concatenate(
        [jnp.full((s,), i, jnp.int32) for i, s in enumerate(sizes)]
    )  # (hd/2,)
    ang = jnp.take_along_axis(ang, sec[None, None, :][None], axis=0)[0]  # (B,S,hd/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def default_positions(batch: int, seq: int) -> jnp.ndarray:
    return jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32), (batch, seq))


# ---------------------------------------------------------------------------
# attention (GQA + optional qk_norm / qkv bias / M-RoPE / KV cache)
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, dtype):
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": init_linear(ks[0], cfg.d_model, cfg.num_heads * hd, dtype, cfg.qkv_bias),
        "wk": init_linear(ks[1], cfg.d_model, cfg.num_kv_heads * hd, dtype, cfg.qkv_bias),
        "wv": init_linear(ks[2], cfg.d_model, cfg.num_kv_heads * hd, dtype, cfg.qkv_bias),
        "wo": init_linear(ks[3], cfg.num_heads * hd, cfg.d_model, dtype, False),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(hd, dtype)
        p["k_norm"] = init_rmsnorm(hd, dtype)
    return p


# Sequences >= this use chunked flash attention (see models/flash.py)
FLASH_MIN_SEQ = 2048
FLASH_CHUNK = 512
# int8 KV-cache symmetric quantization scale (decode weight/cache traffic
# is the TPOT bound; int8 halves cache bytes — beyond-paper serving opt)
KV_QSCALE = 32.0


def _cache_write(c, new, index):
    """Write ``new`` (B, S, KV, hd) into cache ``c`` (B, S_max, KV, hd) at
    time offset ``index`` — a scalar (whole-batch decode) or a (B,) vector
    (slot-batched serving, every sequence at its own length)."""
    if getattr(index, "ndim", 0) == 1:
        return jax.vmap(
            lambda cb, nb, i: jax.lax.dynamic_update_slice(cb, nb, (i, 0, 0))
        )(c, new, index)
    return jax.lax.dynamic_update_slice(c, new, (0, index, 0, 0))


def _cache_mask(cache_index, B, S, S_kv):
    """Causal mask (B, S, S_kv) against a cache: position p attends cache
    slots <= its own write index."""
    kv_slots = jnp.arange(S_kv, dtype=jnp.int32)
    off = jnp.arange(S, dtype=jnp.int32)
    if getattr(cache_index, "ndim", 0) == 1:
        q_pos = cache_index[:, None] + off[None, :]  # (B, S)
        return kv_slots[None, None, :] <= q_pos[:, :, None]
    mask = kv_slots[None, None, :] <= (cache_index + off)[None, :, None]
    return jnp.broadcast_to(mask, (B, S, S_kv))


def _sdpa(q, k, v, mask, scale):
    """q: (B,Sq,KV,G,hd)  k,v: (B,Skv,KV,hd)  mask: (B,Sq,Skv) bool or None."""
    logits = jnp.einsum("bqkgh,bskh->bkgqs", q, k).astype(jnp.float32) * scale
    if mask is not None:
        logits = jnp.where(mask[:, None, None, :, :], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", w, v)
    return out


def attention(p, x, cfg: ModelConfig, positions, *, kv_cache=None,
              cache_index=None, attn_mask=None, block_table=None,
              paged_kernel=True, lin=None):
    """Returns (out, new_kv_cache).

    Training / prefill: ``kv_cache=None`` — causal (or bidirectional) full attn;
    new cache returned as the (k, v) of this call.
    Decode: ``kv_cache=(k,v)`` of shape (B, S_max, KV, hd); x is (B, 1, D) and
    ``cache_index`` is the write position — scalar int32 when the whole batch
    decodes in lockstep, or (B,) int32 for slot-batched serving where every
    sequence sits at its own length.
    Paged decode/prefill: ``kv_cache=(k,v)`` is a shared page arena of shape
    (n_pages, page_size, KV, hd) and ``block_table`` is (B, max_blocks) int32
    page indices per row (``n_pages`` == unmapped: such writes drop, reads are
    masked). x may be (B, S, D) for S >= 1 (chunked / shared-prefix prefill);
    each row's tokens land at cache positions ``cache_index[b] + [0, S)``.
    The paged read runs the Pallas paged-attention kernel (per-step KV
    traffic O(tokens cached), see kernels/paged_attention.py): the S == 1
    decode mode, or the Sq>1 chunked-prefill mode (causal per query row);
    ``paged_kernel=False`` keeps the ``.at[block_table].get`` gather — the
    bit-exact relayout of the dense path, retained as the parity reference.
    """
    if lin is None:
        lin = default_lin
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    H, KV = cfg.num_heads, cfg.num_kv_heads
    G = H // KV

    q = lin("wq", p["wq"], x).reshape(B, S, H, hd)
    k = lin("wk", p["wk"], x).reshape(B, S, KV, hd)
    v = lin("wv", p["wv"], x).reshape(B, S, KV, hd)

    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)

    if cfg.mrope_sections is not None:
        q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
        kv_pos = positions[0]  # temporal stream orders causality
    elif cfg.num_heads > 0 and cfg.rope_theta > 0:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        kv_pos = positions
    else:
        kv_pos = positions if positions.ndim == 2 else positions[0]

    if kv_cache is not None and block_table is not None:
        # paged path: scatter new KV through the block table, gather the
        # position-ordered view back for the (masked) attention read
        ck, cv = kv_cache  # (n_pages, page_size, KV, hd) — this layer's arena
        n_pages, page_size = ck.shape[0], ck.shape[1]
        MB = block_table.shape[1]
        idx = jnp.asarray(cache_index, jnp.int32)
        if idx.ndim == 0:
            idx = jnp.broadcast_to(idx, (B,))
        tok_pos = idx[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]  # (B,S)
        pidx = tok_pos // page_size
        page = jnp.where(
            pidx < MB,
            jnp.take_along_axis(block_table, jnp.minimum(pidx, MB - 1), axis=1),
            n_pages)  # past-the-table writes (frozen slots) must drop
        off = tok_pos % page_size
        if ck.dtype == jnp.int8:
            k_new = jnp.clip(jnp.round(k.astype(jnp.float32) * KV_QSCALE),
                             -127, 127).astype(jnp.int8)
            v_new = jnp.clip(jnp.round(v.astype(jnp.float32) * KV_QSCALE),
                             -127, 127).astype(jnp.int8)
        else:
            k_new, v_new = k.astype(ck.dtype), v.astype(cv.dtype)
        ck = ck.at[page, off].set(k_new, mode="drop")
        cv = cv.at[page, off].set(v_new, mode="drop")
        if paged_kernel:
            # online-softmax kernel walks the block table page-by-page; the
            # (B, MB*page_size) KV view never materialises. S == 1 is the
            # decode mode; S > 1 is the chunked-prefill mode (each query row
            # causal against in-chunk + already-paged KV, lengths = idx + S
            # since this call's scatter above already landed the chunk)
            from repro.kernels.ops import paged_attention
            qs = KV_QSCALE if ck.dtype == jnp.int8 else None
            qk = q.reshape(B, KV, G, hd) if S == 1 \
                else q.reshape(B, S, KV, G, hd)
            out = paged_attention(
                qk, ck, cv, block_table, idx + S,
                scale=1.0 / math.sqrt(hd), kv_qscale=qs)
            out = out.reshape(B, S, H * hd)
            return lin("wo", p["wo"], out), (ck, cv)
        k_full = ck.at[block_table].get(mode="fill", fill_value=0)
        v_full = cv.at[block_table].get(mode="fill", fill_value=0)
        k_full = k_full.reshape(B, MB * page_size, KV, hd)
        v_full = v_full.reshape(B, MB * page_size, KV, hd)
        if ck.dtype == jnp.int8:
            k_full = (k_full.astype(jnp.float32) / KV_QSCALE).astype(k.dtype)
            v_full = (v_full.astype(jnp.float32) / KV_QSCALE).astype(v.dtype)
        mask = _cache_mask(idx, B, S, MB * page_size)
        new_cache = (ck, cv)
    elif kv_cache is not None:
        ck, cv = kv_cache
        if ck.dtype == jnp.int8:
            kq = jnp.clip(jnp.round(k.astype(jnp.float32) * KV_QSCALE), -127, 127)
            vq = jnp.clip(jnp.round(v.astype(jnp.float32) * KV_QSCALE), -127, 127)
            ck = _cache_write(ck, kq.astype(jnp.int8), cache_index)
            cv = _cache_write(cv, vq.astype(jnp.int8), cache_index)
            k_full = (ck.astype(jnp.float32) / KV_QSCALE).astype(k.dtype)
            v_full = (cv.astype(jnp.float32) / KV_QSCALE).astype(v.dtype)
        else:
            ck = _cache_write(ck, k.astype(ck.dtype), cache_index)
            cv = _cache_write(cv, v.astype(cv.dtype), cache_index)
            k_full, v_full = ck, cv
        mask = _cache_mask(cache_index, B, S, ck.shape[1])
        new_cache = (ck, cv)
    else:
        k_full, v_full = k, v
        new_cache = (k, v)
        if attn_mask is None and S >= FLASH_MIN_SEQ:
            # chunked online-softmax attention: no (Sq x Skv) tensor in HBM
            from repro.models.flash import flash_attention
            qq = q.reshape(B, S, KV, G, hd)
            qp = kv_pos if cfg.causal else None
            out = flash_attention(qq, k, v, qp, qp, 1.0 / math.sqrt(hd),
                                  FLASH_CHUNK)
            out = out.reshape(B, S, H * hd)
            return lin("wo", p["wo"], out), new_cache
        if cfg.causal:
            mask = kv_pos[:, None, :] <= kv_pos[:, :, None]  # (B, Sq, Skv)
        else:
            mask = None
        if attn_mask is not None:
            mask = attn_mask if mask is None else (mask & attn_mask)

    q = q.reshape(B, S, KV, G, hd)
    out = _sdpa(q, k_full, v_full, mask, 1.0 / math.sqrt(hd))
    out = out.reshape(B, S, H * hd)
    return lin("wo", p["wo"], out), new_cache


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, dtype, d_ff=None):
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.act == "silu":
        return {
            "wg": init_linear(ks[0], cfg.d_model, d_ff, dtype),
            "wu": init_linear(ks[1], cfg.d_model, d_ff, dtype),
            "wd": init_linear(ks[2], d_ff, cfg.d_model, dtype),
        }
    return {
        "w1": init_linear(ks[0], cfg.d_model, d_ff, dtype),
        "w2": init_linear(ks[1], d_ff, cfg.d_model, dtype),
    }


def mlp(p, x, cfg: ModelConfig, lin=None):
    if lin is None:
        lin = default_lin
    if "wg" in p:
        return lin("wd", p["wd"], jax.nn.silu(lin("wg", p["wg"], x)) * lin("wu", p["wu"], x))
    return lin("w2", p["w2"], jax.nn.gelu(lin("w1", p["w1"], x)))
