"""Mamba2 block (SSD — state-space duality, arXiv:2405.21060).

Chunked SSD: quadratic attention-like math *within* fixed-size chunks, linear
recurrence *across* chunks via ``lax.scan`` (carry = SSM state). This is the
TPU-friendly formulation: every chunk op is an MXU einsum and the scan keeps
HLO size and activation memory independent of sequence length.

Decode is a single-token recurrence — O(1) state, which is what makes the
``long_500k`` cell runnable for the SSM/hybrid archs.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import default_lin, init_linear, linear, rmsnorm


def _inv_softplus(x):
    return x + math.log(-math.expm1(-x))


def init_mamba_block(key, cfg: ModelConfig, dtype):
    D = cfg.d_model
    di = cfg.d_inner
    ds, ng, nh, K = cfg.ssm_state, cfg.ssm_ngroups, cfg.ssm_nheads, cfg.ssm_conv
    d_in_proj = 2 * di + 2 * ng * ds + nh
    conv_dim = di + 2 * ng * ds
    ks = jax.random.split(key, 5)
    # dt init: softplus(dt_bias) ~ U[1e-3, 1e-1] (official init)
    u = jax.random.uniform(ks[2], (nh,), jnp.float32)
    dt = jnp.exp(u * (math.log(0.1) - math.log(1e-3)) + math.log(1e-3))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))
    return {
        "in_proj": init_linear(ks[0], D, d_in_proj, dtype),
        "conv_w": (jax.random.normal(ks[1], (K, conv_dim), jnp.float32) / math.sqrt(K)).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "dt_bias": dt_bias.astype(jnp.float32),
        "A_log": jnp.log(jax.random.uniform(ks[3], (nh,), jnp.float32, 1.0, 16.0)),
        "D": jnp.ones((nh,), jnp.float32),
        "norm": {"scale": jnp.ones((di,), dtype)},
        "out_proj": init_linear(ks[4], di, D, dtype),
    }


def _causal_conv(xBC, conv_w, conv_b):
    """Depthwise causal conv via K shifted adds (K is tiny). xBC: (B, S, C)."""
    K = conv_w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    S = xBC.shape[1]
    out = jnp.zeros_like(xBC)
    for k in range(K):
        out = out + pad[:, k : k + S, :] * conv_w[k]
    return jax.nn.silu(out + conv_b)


def _split_proj(cfg: ModelConfig, zxbcdt):
    di, ds, ng, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_ngroups, cfg.ssm_nheads
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di : 2 * di + 2 * ng * ds]
    dt = zxbcdt[..., 2 * di + 2 * ng * ds :]
    assert dt.shape[-1] == nh
    return z, xBC, dt


def _split_xbc(cfg: ModelConfig, xBC):
    di, ds, ng = cfg.d_inner, cfg.ssm_state, cfg.ssm_ngroups
    x = xBC[..., :di]
    B_ = xBC[..., di : di + ng * ds]
    C_ = xBC[..., di + ng * ds :]
    return x, B_, C_


def ssd_chunked(x, dt, A, B_, C_, cfg: ModelConfig, h0=None):
    """Chunked SSD scan.

    x: (B, S, H, P)  dt: (B, S, H) post-softplus  A: (H,) negative
    B_, C_: (B, S, G, N).  Returns (y (B,S,H,P), h_final (B,H,P,N)).
    """
    Bsz, S, H, P = x.shape
    G, N = B_.shape[-2], B_.shape[-1]
    Q = min(cfg.ssm_chunk, S)
    assert S % Q == 0, f"seq {S} not divisible by chunk {Q}"
    nc = S // Q
    rep = H // G

    xc = x.reshape(Bsz, nc, Q, H, P)
    dtc = dt.reshape(Bsz, nc, Q, H)
    Bc = B_.reshape(Bsz, nc, Q, G, N)
    Cc = C_.reshape(Bsz, nc, Q, G, N)

    if h0 is None:
        h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)

    def body(h, inp):
        xq, dtq, Bq, Cq = inp  # (B,Q,H,P) (B,Q,H) (B,Q,G,N) (B,Q,G,N)
        dtq = dtq.astype(jnp.float32)
        dA = dtq * A  # (B,Q,H) negative log-decay per step
        cs = jnp.cumsum(dA, axis=1)  # inclusive
        Bh = jnp.repeat(Bq, rep, axis=2).astype(jnp.float32)  # (B,Q,H,N)
        Ch = jnp.repeat(Cq, rep, axis=2).astype(jnp.float32)
        xf = xq.astype(jnp.float32)
        csT = cs.transpose(0, 2, 1)  # (B,H,Q)
        dtT = dtq.transpose(0, 2, 1)  # (B,H,Q)
        # intra-chunk ("attention" dual form)
        scores = jnp.einsum("bqhn,bkhn->bhqk", Ch, Bh)
        ddec = csT[:, :, :, None] - csT[:, :, None, :]  # cs[i]-cs[j]
        tri = jnp.tril(jnp.ones((Q, Q), bool))
        M = jnp.where(tri[None, None], jnp.exp(ddec), 0.0) * dtT[:, :, None, :]
        y = jnp.einsum("bhqk,bkhp->bqhp", scores * M, xf)
        # inter-chunk (contribution of carried state)
        y = y + jnp.einsum("bqhn,bhpn->bqhp", Ch * jnp.exp(cs)[..., None], h)
        # new carry
        dec_end = jnp.exp(cs[:, -1:, :] - cs)  # (B,Q,H)
        state = jnp.einsum("bqhn,bqhp->bhpn", Bh * (dec_end * dtq)[..., None], xf)
        h = h * jnp.exp(cs[:, -1, :])[:, :, None, None] + state
        return h, y.astype(x.dtype)

    xs = (
        xc.transpose(1, 0, 2, 3, 4),
        dtc.transpose(1, 0, 2, 3),
        Bc.transpose(1, 0, 2, 3, 4),
        Cc.transpose(1, 0, 2, 3, 4),
    )
    h_final, ys = jax.lax.scan(body, h0, xs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(Bsz, S, H, P)
    return y, h_final


def mamba_block(p, u, cfg: ModelConfig, *, ssm_state=None, conv_state=None,
                seq_lens=None, lin=None):
    """Full-sequence forward (train/prefill). u: (B, S, D).

    Returns (out, (ssm_state, conv_state)) — states returned for cache
    priming; ``conv_state`` is the raw (pre-conv) xBC window the decode
    recurrence continues from.

    ``seq_lens`` (B,) int32 implements snapshot-on-prefill for right-padded
    rows (length-bucketed serving admission): padding steps get ``dt = 0``,
    which in SSD is an exact state passthrough (decay ``exp(0·A) = 1``, zero
    input contribution), so ``ssm_state`` is the state after each row's LAST
    VALID token, and ``conv_state`` is gathered from the last ``K-1`` valid
    positions. Outputs at positions >= seq_len are garbage (never read).
    """
    if lin is None:
        lin = default_lin
    Bsz, S, _ = u.shape
    H, P = cfg.ssm_nheads, cfg.ssm_headdim
    G, N = cfg.ssm_ngroups, cfg.ssm_state
    zxbcdt = lin("in_proj", p["in_proj"], u)
    z, xBC, dt = _split_proj(cfg, zxbcdt)
    xBC_raw = xBC  # decode's conv window holds PRE-conv inputs
    xBC = _causal_conv(xBC_raw, p["conv_w"], p["conv_b"])
    x, B_, C_ = _split_xbc(cfg, xBC)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    if seq_lens is not None:
        valid = jnp.arange(S, dtype=jnp.int32)[None, :] < seq_lens[:, None]
        dt = dt * valid[:, :, None]
    A = -jnp.exp(p["A_log"])
    x4 = x.reshape(Bsz, S, H, P)
    B4 = B_.reshape(Bsz, S, G, N)
    C4 = C_.reshape(Bsz, S, G, N)
    # pad S up to a chunk multiple with dt = 0 steps (exact passthrough), so
    # bucketed prefill lengths need not divide ssm_chunk
    pad = -S % min(cfg.ssm_chunk, S)
    if pad:
        zp = lambda a: jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))
        x4, dt, B4, C4 = zp(x4), zp(dt), zp(B4), zp(C4)
    y, h_final = ssd_chunked(x4, dt, A, B4, C4, cfg, h0=ssm_state)
    y = y[:, :S]
    y = y + (p["D"][None, None, :, None] * x.reshape(Bsz, S, H, P)).astype(y.dtype)
    y = y.reshape(Bsz, S, cfg.d_inner)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = lin("out_proj", p["out_proj"], y)
    K = cfg.ssm_conv
    if seq_lens is not None:
        # window of each row's last K-1 VALID tokens (left zero-padded)
        idx = seq_lens[:, None] - (K - 1) + jnp.arange(K - 1,
                                                       dtype=jnp.int32)[None, :]
        got = jnp.take_along_axis(
            xBC_raw, jnp.clip(idx, 0, S - 1)[:, :, None], axis=1)
        new_conv = jnp.where((idx >= 0)[:, :, None], got, 0)
    else:
        new_conv = jnp.pad(xBC_raw, ((0, 0), (K - 1, 0), (0, 0)))[:, S : S + K - 1, :] \
            if S < K - 1 else xBC_raw[:, S - (K - 1):, :]
    return out, (h_final, new_conv)


def mamba_decode_step(p, u, cfg: ModelConfig, ssm_state, conv_state, lin=None):
    """Single-token recurrence. u: (B, 1, D); states from init_mamba_cache.

    ssm_state: (B, H, P, N) f32; conv_state: (B, K-1, conv_dim).
    """
    if lin is None:
        lin = default_lin
    Bsz = u.shape[0]
    H, P = cfg.ssm_nheads, cfg.ssm_headdim
    G, N = cfg.ssm_ngroups, cfg.ssm_state
    zxbcdt = lin("in_proj", p["in_proj"], u[:, 0, :])
    z, xBC, dt = _split_proj(cfg, zxbcdt)
    window = jnp.concatenate([conv_state, xBC[:, None, :].astype(conv_state.dtype)], axis=1)
    conv_out = jnp.einsum("bkc,kc->bc", window, p["conv_w"]) + p["conv_b"]
    xBC = jax.nn.silu(conv_out)
    new_conv_state = window[:, 1:, :]
    x, B_, C_ = _split_xbc(cfg, xBC)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B, H)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A)  # (B, H)
    xh = x.reshape(Bsz, H, P).astype(jnp.float32)
    Bh = jnp.repeat(B_.reshape(Bsz, G, N), H // G, axis=1).astype(jnp.float32)
    Ch = jnp.repeat(C_.reshape(Bsz, G, N), H // G, axis=1).astype(jnp.float32)
    new_state = ssm_state * dA[..., None, None] + jnp.einsum(
        "bhn,bhp->bhpn", Bh * dt[..., None], xh
    )
    y = jnp.einsum("bhn,bhpn->bhp", Ch, new_state) + p["D"][None, :, None] * xh
    y = y.reshape(Bsz, cfg.d_inner).astype(u.dtype)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = lin("out_proj", p["out_proj"], y)[:, None, :]
    return out, (new_state, new_conv_state)


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype):
    H, P, N = cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state
    conv_dim = cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state
    return (
        jnp.zeros((batch, H, P, N), jnp.float32),
        jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
    )
