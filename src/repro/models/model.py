"""Unified model wrapper: init / forward / loss / decode for every family.

Blocks are *stacked* on a leading layer axis and driven by ``lax.scan`` so the
HLO (and SPMD partitioning time) is depth-independent — this is what makes the
126-layer 405B dry-run compile on one CPU core.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import blocks as B
from repro.models.layers import (default_positions, init_rmsnorm, rmsnorm,
                                 stats_lin)


def _dtype(name: str):
    if not isinstance(name, str):
        return name
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "int8": jnp.int8}[name]


class Model:
    """Functional model: all methods are pure and jit/pjit friendly."""

    def __init__(self, cfg: ModelConfig, param_dtype=jnp.float32,
                 kv_dtype=None):
        self.cfg = cfg
        self.param_dtype = _dtype(param_dtype)
        self.kv_dtype = _dtype(kv_dtype) if kv_dtype is not None else None
        self.block_init = B.INIT[cfg.family]
        self.block_apply = B.APPLY.get(cfg.family)  # None for hybrid
        # declarative per-layer decode-state spec: cache init, serving
        # admit/release, and decode dispatch are loops over its groups
        self.cache_spec = B.cache_spec(cfg, self.param_dtype, self.kv_dtype)

    # ------------------------------------------------------------------
    # init
    # ------------------------------------------------------------------
    def init(self, key) -> Dict[str, Any]:
        cfg, dt = self.cfg, self.param_dtype
        k_emb, k_blocks, k_head, k_shared = jax.random.split(key, 4)
        params: Dict[str, Any] = {}
        if cfg.family != "audio":
            params["embed"] = (
                jax.random.normal(k_emb, (cfg.vocab_size, cfg.d_model), jnp.float32)
                / math.sqrt(cfg.d_model)
            ).astype(dt)
        keys = jax.random.split(k_blocks, cfg.num_layers)
        params["blocks"] = jax.vmap(lambda k: self.block_init(k, cfg, dt))(keys)
        if cfg.family == "hybrid":
            params["shared_attn"] = B.init_shared_attn_block(k_shared, cfg, dt)
        params["final_norm"] = init_rmsnorm(cfg.d_model, dt)
        if cfg.family == "audio" or not cfg.tie_embeddings:
            params["head"] = (
                jax.random.normal(k_head, (cfg.d_model, cfg.vocab_size), jnp.float32)
                / math.sqrt(cfg.d_model)
            ).astype(dt)
        return params

    # ------------------------------------------------------------------
    # embedding / head
    # ------------------------------------------------------------------
    def embed(self, params, tokens):
        return jnp.take(params["embed"], tokens, axis=0)

    def unembed(self, params, x):
        if "head" in params:
            return x @ params["head"]
        return x @ params["embed"].T

    # ------------------------------------------------------------------
    # input assembly per family
    # ------------------------------------------------------------------
    def _assemble(self, params, inputs):
        """Returns (x, positions) for a full-sequence pass."""
        cfg = self.cfg
        if cfg.family == "audio":
            x = inputs["frames"].astype(self.param_dtype)
            Bsz, S = x.shape[0], x.shape[1]
            return x, default_positions(Bsz, S)
        if cfg.family == "vlm":
            vis = inputs["vision_embeds"].astype(self.param_dtype)
            txt = self.embed(params, inputs["tokens"])
            x = jnp.concatenate([vis, txt], axis=1)
            positions = mrope_positions(cfg, x.shape[0], vis.shape[1],
                                        inputs["tokens"].shape[1])
            return x, positions
        tokens = inputs["tokens"]
        x = self.embed(params, tokens)
        Bsz, S = tokens.shape
        return x, inputs.get("positions", default_positions(Bsz, S))

    # ------------------------------------------------------------------
    # full-sequence forward (train / prefill)
    # ------------------------------------------------------------------
    def forward(self, params, inputs, *, remat=False, remat_groups=0,
                lin=None, elin=None, return_cache=False, last_only=False,
                act_pspec=None, seq_lens=None, collect_taps=False,
                tap_weights=None):
        """act_pspec: optional PartitionSpec pinned on the residual stream at
        every block boundary (sequence parallelism: the saved remat carries
        shard over `model`, cutting activation HBM by the TP degree).

        seq_lens: (B,) int32 valid prompt lengths for right-padded rows
        (length-bucketed serving prefill). Only recurrent-state blocks
        consume it — with it, the returned cache snapshots each row's state
        after its LAST VALID token instead of after the padding (attention
        KV needs no masking: stale positions are masked by cache position).

        collect_taps: gather per-linear input statistics (running ||X||^2 /
        |X| / X sums + token counts, see ``layers.input_stats``) inside the
        layer scan and return them stacked (L, ...) as an extra trailing
        output. ``tap_weights`` is a nonnegative mask broadcastable to the
        token axes (B, S) — padding rows/positions contribute zero. The taps
        ride the scan ys, so collecting adds no host sync and no retrace.
        """
        cfg = self.cfg
        x, positions = self._assemble(params, inputs)
        if act_pspec is not None:
            x = jax.lax.with_sharding_constraint(x, act_pspec)

        if self.cache_spec.mixed:
            if collect_taps:
                raise NotImplementedError(
                    f"{cfg.name}: calibration taps need a non-mixed layer scan")
            x, aux, cache = self._hybrid_forward(params, x, positions, remat,
                                                 lin, elin,
                                                 return_cache=return_cache,
                                                 seq_lens=seq_lens)
            taps = None
        else:
            apply = self.block_apply

            def body(carry, bp):
                h, aux = carry
                taps_l: Dict[str, Any] = {}
                l = stats_lin(lin, taps_l, tap_weights) if collect_taps else lin
                h, new_cache, a = apply(bp, h, cfg, positions,
                                        seq_lens=seq_lens, lin=l, elin=elin)
                if act_pspec is not None:
                    h = jax.lax.with_sharding_constraint(h, act_pspec)
                return (h, aux + a), ((new_cache if return_cache else 0),
                                      taps_l)

            if remat:
                body = jax.checkpoint(body)
            carry0 = (x, jnp.zeros((), jnp.float32))
            if remat_groups and not return_cache and not collect_taps \
                    and cfg.num_layers % remat_groups == 0 and remat_groups > 1:
                # two-level scan remat: only G group-boundary activations are
                # saved; each group recomputes its layers on the backward pass
                # (sqrt-style activation memory; ~+25% executed fwd FLOPs)
                G = remat_groups
                per = cfg.num_layers // G
                grouped = jax.tree_util.tree_map(
                    lambda a: a.reshape(G, per, *a.shape[1:]), params["blocks"])

                def group_body(carry, bg):
                    c, _ = jax.lax.scan(body, carry, bg)
                    return c, 0

                (x, aux), _ = jax.lax.scan(jax.checkpoint(group_body),
                                           carry0, grouped)
                cache, taps = None, None
            else:
                (x, aux), (cache, taps) = jax.lax.scan(body, carry0,
                                                       params["blocks"])

        if last_only:
            x = x[:, -1:, :]  # unembed only the final position (prefill)
        x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = self.unembed(params, x)
        if return_cache and collect_taps:
            return logits, aux, cache, taps
        if return_cache:
            return logits, aux, cache
        if collect_taps:
            return logits, aux, taps
        return logits, aux

    def _hybrid_forward(self, params, x, positions, remat, lin, elin,
                        return_cache=False, seq_lens=None):
        cfg = self.cfg

        def body(carry, bp):
            h, aux, idx = carry
            h, mamba_c, kv, a = B.hybrid_layer(
                bp, params["shared_attn"], h, cfg, positions, idx,
                seq_lens=seq_lens, lin=lin, elin=elin)
            return (h, aux + a, idx + 1), \
                ((mamba_c, kv) if return_cache else 0)

        if remat:
            body = jax.checkpoint(body)
        (x, aux, _), ys = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32), jnp.int32(0)), params["blocks"])
        if not return_cache:
            return x, aux, None
        (ssm, conv), (k_all, v_all) = ys  # stacked (L, B, ...) per layer
        # attention runs only at layers idx % every == 0; the scan emitted a
        # zeros kv for the rest — keep just the application sites, in order
        every = cfg.hybrid_attn_every
        cache = {"attn": (k_all[::every], v_all[::every]),
                 "mamba": (ssm, conv)}
        return x, aux, cache

    # ------------------------------------------------------------------
    # losses
    # ------------------------------------------------------------------
    def loss(self, params, batch, *, remat=False, remat_groups=0,
             aux_coef=0.01, lin=None, elin=None, act_pspec=None):
        cfg = self.cfg
        logits, aux = self.forward(params, batch, remat=remat,
                                   remat_groups=remat_groups, lin=lin,
                                   elin=elin, act_pspec=act_pspec)
        if cfg.family == "audio":
            lm = _masked_ce(logits, batch["labels"], batch["mask"])
        elif cfg.family == "vlm":
            P = batch["vision_embeds"].shape[1]
            T = batch["tokens"].shape[1]
            lm = _ce(logits[:, P - 1 : P + T - 1], batch["labels"])
        else:
            lm = _ce(logits, batch["labels"])
        total = lm + (aux_coef * aux if cfg.family == "moe" else 0.0)
        return total, {"lm_loss": lm, "aux_loss": aux}

    # ------------------------------------------------------------------
    # KV / state caches
    # ------------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int):
        """Per-slot decode-state pool, laid out by the family's CacheSpec:
        KV groups (apps, batch, max_len, KV, hd) pairs, recurrent groups
        fixed-shape (apps, batch, ...) leaves. Single-group families keep
        their bare-tuple formats ((k, v) / (ssm, conv)); hybrid packs to
        {"attn": (k, v), "mamba": (ssm, conv)}."""
        try:
            return self.cache_spec.init_dense(batch, max_len)
        except ValueError:
            raise ValueError(f"no cache for family {self.cfg.family}")

    def init_paged_cache(self, n_pages: int, page_size: int, n_slots: int = 0):
        """Paged serving pool: every KV group becomes a shared page arena of
        shape (apps, n_pages, page_size, KV, hd) addressed through per-slot
        block tables (see serve/paging.py), so KV HBM scales with the pages
        actually allocated, not n_slots x max_len. Recurrent groups have no
        length axis — they stay per-slot (pass ``n_slots`` for mixed specs
        like Zamba2). Raises ValueError when the spec has no pageable KV."""
        return self.cache_spec.init_paged(n_pages, page_size, n_slots)

    # ------------------------------------------------------------------
    # single-token decode
    # ------------------------------------------------------------------
    def decode_step(self, params, inputs, cache, *, lin=None, elin=None,
                    paged_kernel=True, collect_taps=False, tap_weights=None):
        """inputs: {"token": (B,) int32, "pos": () or (B,) int32, optional
        "block_table": (B, max_blocks) int32, optional "rope_pos": (B,)
        int32}.

        A scalar ``pos`` decodes the whole batch in lockstep (every sequence
        at the same length); a (B,) vector decodes a *slot batch* where each
        sequence sits at its own position (continuous-batching serving).
        With "block_table", each KV group of ``cache`` is the paged
        (apps, n_pages, page_size, KV, hd) arena: the read runs the Pallas
        paged-attention kernel by default, or the materialising gather (the
        dense path's bit-exact relayout) with ``paged_kernel=False``.
        "rope_pos" decouples the rotary position from the cache write index
        — a VLM slot's text token at cache position p carries rotary
        position p + (grid - n_patches) because the M-RoPE text stream
        restarts at the vision grid edge, not at the patch count.
        Returns (logits, cache), or (logits, cache, taps) with
        ``collect_taps`` (see :meth:`forward`; ``tap_weights`` masks out
        inactive slots so parked decode lanes contribute nothing).
        """
        cfg = self.cfg
        token, pos = inputs["token"], inputs["pos"]
        block_table = inputs.get("block_table")
        Bsz = token.shape[0]
        x = self.embed(params, token)[:, None, :]
        pos = jnp.asarray(pos, jnp.int32)
        rope = jnp.asarray(inputs.get("rope_pos", pos), jnp.int32)
        if rope.ndim == 1:
            pos2d = rope[:, None]  # (B, 1) per-slot positions
        else:
            pos2d = jnp.broadcast_to(rope, (Bsz, 1))
        if cfg.mrope_sections is not None:
            positions = jnp.broadcast_to(pos2d[None], (3, Bsz, 1))
        else:
            positions = pos2d

        if self.cache_spec.mixed:
            if collect_taps:
                raise NotImplementedError(
                    f"{cfg.name}: calibration taps need a non-mixed layer scan")
            x, new_cache = self._hybrid_decode(params, x, positions, pos,
                                               cache, block_table,
                                               paged_kernel, lin, elin)
            taps = None
        else:
            apply = self.block_apply

            def body(h, xs):
                bp, cache_l = xs
                taps_l: Dict[str, Any] = {}
                l = stats_lin(lin, taps_l, tap_weights) if collect_taps else lin
                h, new_c, _ = apply(bp, h, cfg, positions, cache=cache_l,
                                    cache_index=pos, block_table=block_table,
                                    paged_kernel=paged_kernel,
                                    lin=l, elin=elin)
                return h, (new_c, taps_l)

            x, (new_cache, taps) = jax.lax.scan(
                body, x, (params["blocks"], cache))

        x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = self.unembed(params, x)[:, 0, :]
        if collect_taps:
            return logits, new_cache, taps
        return logits, new_cache

    def decode_multi(self, params, inputs, cache, *, lin=None, elin=None,
                     paged_kernel=True, collect_taps=False, tap_weights=None):
        """Multi-token decode through the cache — the speculative-decoding
        verify forward. inputs: {"tokens": (B, S) int32, "pos": (B,) int32
        cache write index of tokens[:, 0], optional "rope_pos": (B,) int32
        rotary position of tokens[:, 0] (defaults to pos), optional
        "block_table": (B, max_blocks) int32}.

        Writes every position's KV at cache positions pos[b] + [0, S) (the
        same scatter/clamp semantics as ``decode_step``) and returns the
        FULL logits (B, S, V) — row i is the next-token distribution after
        tokens[:, i] — plus the cache. The paged read runs the Pallas
        kernel's Sq>1 mode when ``paged_kernel`` (the materialising gather
        stays the parity reference). Pure-KV specs only: a recurrent state
        cannot be rolled back to an accepted prefix, so speculative
        verification is undefined for it.
        """
        cfg = self.cfg
        if self.cache_spec.mixed or self.cache_spec.has_recurrent:
            raise NotImplementedError(
                f"{cfg.name}: multi-token verify needs a pure KV cache spec")
        tokens, pos = inputs["tokens"], jnp.asarray(inputs["pos"], jnp.int32)
        block_table = inputs.get("block_table")
        Bsz, S = tokens.shape
        if pos.ndim == 0:
            pos = jnp.broadcast_to(pos, (Bsz,))
        x = self.embed(params, tokens)
        rope = jnp.asarray(inputs.get("rope_pos", pos), jnp.int32)
        pos2d = rope[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]
        if cfg.mrope_sections is not None:
            positions = jnp.broadcast_to(pos2d[None], (3, Bsz, S))
        else:
            positions = pos2d
        apply = self.block_apply

        def body(h, xs):
            bp, cache_l = xs
            taps_l: Dict[str, Any] = {}
            l = stats_lin(lin, taps_l, tap_weights) if collect_taps else lin
            h, new_c, _ = apply(bp, h, cfg, positions, cache=cache_l,
                                cache_index=pos, block_table=block_table,
                                paged_kernel=paged_kernel,
                                lin=l, elin=elin)
            return h, (new_c, taps_l)

        x, (new_cache, taps) = jax.lax.scan(body, x, (params["blocks"], cache))
        x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
        if collect_taps:
            return self.unembed(params, x), new_cache, taps
        return self.unembed(params, x), new_cache

    def prefill_paged(self, params, inputs, cache, *, lin=None, elin=None,
                      paged_kernel=True, collect_taps=False, tap_weights=None):
        """Prefill straight through the paged KV pool (shared-prefix path).

        inputs: {"tokens": (B, S) int32 — each row's *suffix* (prompt minus
        its shared prefix), "pos": (B,) int32 — first cache position of each
        row (== its shared-prefix length; 0 for a fresh request), "last":
        (B,) int32 — index of each row's last real suffix token,
        "block_table": (B, max_blocks) int32}.

        Writes the suffix KV through the block table and attends over
        [shared prefix pages | suffix] per row — the shared pages were
        prefetched once by ``Engine.register_prefix`` and are never
        recomputed here. Returns (last-token logits (B, V), cache).
        """
        cfg = self.cfg
        if self.cache_spec.has_recurrent or cfg.frontend is not None:
            # capability gate, not a family ladder: shared pages can capture
            # positional KV but not recurrent state (the suffix's mamba scan
            # would need the prefix's final h), and a vision prefix is
            # embeddings, not shareable token pages
            raise NotImplementedError(
                f"{cfg.name}: paged prefill needs a pure token-KV spec")
        tokens, pos = inputs["tokens"], jnp.asarray(inputs["pos"], jnp.int32)
        block_table = inputs["block_table"]
        Bsz, S = tokens.shape
        x = self.embed(params, tokens)
        positions = pos[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]
        apply = self.block_apply

        def body(h, xs):
            bp, cache_l = xs
            taps_l: Dict[str, Any] = {}
            l = stats_lin(lin, taps_l, tap_weights) if collect_taps else lin
            h, new_c, _ = apply(bp, h, cfg, positions, cache=cache_l,
                                cache_index=pos, block_table=block_table,
                                paged_kernel=paged_kernel,
                                lin=l, elin=elin)
            return h, (new_c, taps_l)

        x, (new_cache, taps) = jax.lax.scan(body, x, (params["blocks"], cache))
        x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
        last = jnp.clip(jnp.asarray(inputs["last"], jnp.int32), 0, S - 1)
        x_last = jnp.take_along_axis(x, last[:, None, None], axis=1)[:, 0]
        if collect_taps:
            return self.unembed(params, x_last), new_cache, taps
        return self.unembed(params, x_last), new_cache

    def _hybrid_decode(self, params, x, positions, pos, cache, block_table,
                       paged_kernel, lin, elin):
        """Mixed-spec decode: the mamba leaves ride the layer scan, the
        shared attention block's KV (stacked over its application sites,
        dense rows or paged arenas) is carried whole and dynamically indexed
        at each site."""
        cfg = self.cfg
        every = cfg.hybrid_attn_every

        def body(carry, xs):
            h, ak, av, idx = carry
            bp, ssm_l, conv_l = xs
            app = idx // every
            ak_l = jax.lax.dynamic_index_in_dim(ak, app, 0, keepdims=False)
            av_l = jax.lax.dynamic_index_in_dim(av, app, 0, keepdims=False)
            h, new_mamba, (nak, nav), _ = B.hybrid_layer(
                bp, params["shared_attn"], h, cfg, positions, idx,
                mamba_cache=(ssm_l, conv_l), attn_cache=(ak_l, av_l),
                cache_index=pos, block_table=block_table,
                paged_kernel=paged_kernel, lin=lin, elin=elin)
            ak = jax.lax.dynamic_update_index_in_dim(ak, nak, app, 0)
            av = jax.lax.dynamic_update_index_in_dim(av, nav, app, 0)
            return (h, ak, av, idx + 1), new_mamba

        ssm, conv = cache["mamba"]
        carry0 = (x, cache["attn"][0], cache["attn"][1], jnp.int32(0))
        (x, ak, av, _), new_mamba = jax.lax.scan(
            body, carry0, (params["blocks"], ssm, conv))
        return x, {"attn": (ak, av), "mamba": new_mamba}


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _ce(logits, labels):
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def _masked_ce(logits, labels, mask):
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    per_tok = (logz - gold) * mask.astype(jnp.float32)
    return jnp.sum(per_tok) / jnp.maximum(jnp.sum(mask), 1)


def mrope_text_start(n_patches: int) -> int:
    """First M-RoPE position of the text stream: text starts after the max
    grid coordinate per the Qwen2-VL convention. THE one definition — both
    prefill position assembly (:func:`mrope_positions`) and the serving
    engine's decode-time rotary offset derive from it, so the conventions
    cannot drift apart."""
    return int(math.ceil(math.sqrt(n_patches)))


def mrope_positions(cfg: ModelConfig, batch: int, n_patches: int, n_text: int):
    """Qwen2-VL M-RoPE: vision prefix gets (t=0, h, w) grid positions; text
    tokens get equal (t, h, w) sequential positions continuing after the grid."""
    grid = mrope_text_start(n_patches)
    ph = jnp.repeat(jnp.arange(grid, dtype=jnp.int32), grid)[:n_patches]
    pw = jnp.tile(jnp.arange(grid, dtype=jnp.int32), grid)[:n_patches]
    pt = jnp.zeros((n_patches,), jnp.int32)
    tx = grid + jnp.arange(n_text, dtype=jnp.int32)
    p3 = jnp.stack([
        jnp.concatenate([pt, tx]),
        jnp.concatenate([ph, tx]),
        jnp.concatenate([pw, tx]),
    ])  # (3, S)
    return jnp.broadcast_to(p3[:, None, :], (3, batch, n_patches + n_text))


# ---------------------------------------------------------------------------
# ShapeDtypeStruct input specs for the dry-run (no allocation)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig, param_dtype=jnp.bfloat16,
                kv_dtype=None):
    """Stand-in inputs for (arch x shape). For decode shapes, also returns the
    cache spec via eval_shape (never allocated)."""
    Bsz, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind in ("train", "prefill"):
        if cfg.family == "audio":
            specs = {"frames": jax.ShapeDtypeStruct((Bsz, S, cfg.d_model), param_dtype)}
            if shape.kind == "train":
                specs["labels"] = jax.ShapeDtypeStruct((Bsz, S), i32)
                specs["mask"] = jax.ShapeDtypeStruct((Bsz, S), jnp.bool_)
            return specs, None
        if cfg.family == "vlm":
            P = cfg.vision_patches
            specs = {
                "vision_embeds": jax.ShapeDtypeStruct((Bsz, P, cfg.d_model), param_dtype),
                "tokens": jax.ShapeDtypeStruct((Bsz, S - P), i32),
            }
            if shape.kind == "train":
                specs["labels"] = jax.ShapeDtypeStruct((Bsz, S - P), i32)
            return specs, None
        specs = {"tokens": jax.ShapeDtypeStruct((Bsz, S), i32)}
        if shape.kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct((Bsz, S), i32)
        return specs, None

    # decode: one new token against a cache of length seq_len
    model = Model(cfg, param_dtype, kv_dtype=kv_dtype)
    cache = jax.eval_shape(lambda: model.init_cache(Bsz, S))
    specs = {"token": jax.ShapeDtypeStruct((Bsz,), i32),
             "pos": jax.ShapeDtypeStruct((), i32)}
    return specs, cache


def build_model(name_or_cfg, param_dtype=jnp.float32) -> Model:
    if isinstance(name_or_cfg, str):
        from repro.configs import get_config
        name_or_cfg = get_config(name_or_cfg)
    return Model(name_or_cfg, param_dtype)
