"""Mixture-of-Experts layer: top-k routing, sort-based ragged dispatch with
per-group capacity (GShard-style drops), optional shared experts.

FLOP-honest: only routed tokens hit expert matmuls (no dense E× dispatch
einsum), so the roofline compute term reflects active params. Expert weights
carry the "experts" logical axis → EP-sharded over the ``model`` mesh axis.

Grouping: the batch dim is the dispatch group (capacity is per sequence), so
the sort/scatter stays local to the data shard under pjit.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import default_lin, init_linear, linear, scoped


def default_elin(name, w, xin, eq, occ=None):
    """Pluggable expert-einsum backend (tap point for expert-conditional
    Wanda statistics and masked expert weights). ``occ`` is the routing
    occupancy (B, E, C), 1 where the expert slot holds a routed token —
    the dense einsum ignores it (unrouted slots are zero-filled), but
    stats-collecting backends must mask with it so padding slots neither
    contaminate per-expert ||X|| sums nor inflate token counts."""
    return jnp.einsum(eq, xin, w)


def init_moe(key, cfg: ModelConfig, dtype):
    E, D, F = cfg.num_experts, cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 5)
    scale = 1.0 / math.sqrt(D)
    p = {
        "router": init_linear(ks[0], D, E, dtype),
        "wg": (jax.random.normal(ks[1], (E, D, F), jnp.float32) * scale).astype(dtype),
        "wu": (jax.random.normal(ks[2], (E, D, F), jnp.float32) * scale).astype(dtype),
        "wd": (jax.random.normal(ks[3], (E, F, D), jnp.float32) / math.sqrt(F)).astype(dtype),
    }
    if cfg.num_shared_experts > 0:
        sf = cfg.num_shared_experts * F
        sks = jax.random.split(ks[4], 3)
        p["shared"] = {
            "wg": init_linear(sks[0], D, sf, dtype),
            "wu": init_linear(sks[1], D, sf, dtype),
            "wd": init_linear(sks[2], sf, D, dtype),
        }
    return p


def _capacity(cfg: ModelConfig, tokens_per_group: int) -> int:
    c = math.ceil(cfg.top_k * tokens_per_group * cfg.moe_capacity_factor / cfg.num_experts)
    return max(int(c), 1)


def _dispatch_group(xg, expert_ids, gate_vals, E: int, C: int):
    """Per-group ragged dispatch. xg: (S, D); expert_ids/gate_vals: (S, k).

    Returns (expert_in (E, C, D), slot (S*k,), kept (S*k,), order (S*k,)).
    ``slot``/``kept``/``order`` let the combine step scatter outputs back.
    """
    S, D = xg.shape
    k = expert_ids.shape[-1]
    flat_e = expert_ids.reshape(-1)  # (S*k,) copy i = token i//k, choice i%k
    order = jnp.argsort(flat_e)  # stable → FIFO within expert (GShard drop rule)
    se = flat_e[order]
    # position within the expert's segment of the sorted array
    seg_start = jnp.searchsorted(se, se, side="left")
    pos = jnp.arange(S * k, dtype=jnp.int32) - seg_start.astype(jnp.int32)
    kept = pos < C
    slot = jnp.where(kept, se * C + pos, E * C)  # dropped copies → trash row
    token_of = (order // k).astype(jnp.int32)
    buf = jnp.zeros((E * C + 1, D), xg.dtype)
    buf = buf.at[slot].set(xg[token_of], mode="drop")
    return buf[: E * C].reshape(E, C, D), slot, kept, order


def _combine_group(out_ec, slot, kept, order, gate_vals, S: int):
    """out_ec: (E, C, D) expert outputs → (S, D) weighted combine."""
    k = gate_vals.shape[-1]
    D = out_ec.shape[-1]
    flat_gate = gate_vals.reshape(-1)[order]  # sorted copy order
    token_of = (order // k).astype(jnp.int32)
    out_flat = out_ec.reshape(-1, D)
    contrib = jnp.where(
        kept[:, None],
        jnp.take(out_flat, jnp.minimum(slot, out_flat.shape[0] - 1), axis=0),
        0.0,
    )
    contrib = contrib * flat_gate[:, None].astype(contrib.dtype)
    y = jnp.zeros((S, D), out_ec.dtype)
    return y.at[token_of].add(contrib)


def moe_mlp(p, x, cfg: ModelConfig, lin=None, elin=None):
    """x: (B, S, D) → (B, S, D), plus aux load-balance loss (scalar, f32)."""
    if lin is None:
        lin = default_lin
    if elin is None:
        elin = default_elin
    B0, S0, D = x.shape
    g = cfg.moe_group_tokens
    if g and S0 % g == 0 and S0 != g:
        # sub-row dispatch groups (see ModelConfig.moe_group_tokens)
        x = x.reshape(B0 * (S0 // g), g, D)
    B, S, D = x.shape
    E, k = cfg.num_experts, cfg.top_k
    C = _capacity(cfg, S)

    logits = lin("router", p["router"], x).astype(jnp.float32)  # (B, S, E)
    gates = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(gates, k)  # (B, S, k)
    gate_vals = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-9)

    # aux load-balance loss (Switch-style): E * sum_e f_e * p_e
    me = jnp.mean(gates, axis=(0, 1))  # (E,)
    onehot_top1 = jax.nn.one_hot(expert_ids[..., 0], E, dtype=jnp.float32)
    fe = jnp.mean(onehot_top1, axis=(0, 1))
    aux = E * jnp.sum(me * fe)

    dispatch = jax.vmap(lambda xg, ei, gv: _dispatch_group(xg, ei, gv, E, C))
    expert_in, slot, kept, order = dispatch(x, expert_ids, gate_vals)
    # routing occupancy (B, E, C): True where the capacity slot holds a
    # routed token (the scatter trash row at E*C absorbs dropped copies)
    occ = jax.vmap(
        lambda sl: jnp.zeros((E * C + 1,), bool).at[sl].set(True)[: E * C]
        .reshape(E, C))(slot)
    # (B, E, C, D): batch groups sharded over data, experts over model
    h_g = elin("wg", p["wg"], expert_in, "becd,edf->becf", occ)
    h_u = elin("wu", p["wu"], expert_in, "becd,edf->becf", occ)
    out_ec = elin("wd", p["wd"], jax.nn.silu(h_g) * h_u, "becf,efd->becd", occ)

    combine = jax.vmap(lambda oe, sl, kp, od, gv: _combine_group(oe, sl, kp, od, gv, S))
    y = combine(out_ec, slot, kept, order, gate_vals)

    if "shared" in p:
        sp = p["shared"]
        sl = scoped(lin, "shared")
        y = y + sl("wd", sp["wd"], jax.nn.silu(sl("wg", sp["wg"], x)) * sl("wu", sp["wu"], x))
    if (B, S) != (B0, S0):
        y = y.reshape(B0, S0, D)
    return y, aux
