"""Declarative per-layer cache state specs: one serving path for every family.

Every servable family describes its decode-time state as a ``CacheSpec`` — a
tuple of ``StateGroup``s, each a stack of identical per-layer (or per
application-site) states of one of two kinds:

* ``KV``: attention key/value state with a **length axis**. Dense layout is
  ``(apps, batch, max_len, *leaf.shape)``; the paged layout is a shared page
  arena ``(apps, n_pages, page_size, *leaf.shape)`` addressed through per-slot
  block tables (serve/paging.py). Admission scatters prefill KV at positions
  ``[0, prefill_len)``; stale positions are never read because attention masks
  by cache position — release needs no reset.

* ``RECURRENT``: fixed-shape per-slot state with **no length axis** (Mamba2
  SSD state + conv window). Layout is ``(apps, batch, *leaf.shape)`` in both
  pool modes — recurrent state cannot page. Because there is no position to
  mask by, lifecycle is snapshot-on-prefill (the full-sequence forward returns
  the state after the last *valid* token), per-slot **scatter admit**, and
  **zero-reset on release**.

The spec turns ``Model.init_cache`` / ``init_paged_cache`` and the engine's
admit/release scatters into loops over groups instead of ``if cfg.family ==``
ladders; a hybrid model (Zamba2) is simply a two-group spec — its attention
sites page (and decode through the Pallas paged-attention kernel on TPU) while
its Mamba layers slot-scatter.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import jax.numpy as jnp

KV = "kv"
RECURRENT = "recurrent"


@dataclass(frozen=True)
class StateLeaf:
    """One array of a group's per-layer state.

    ``shape`` is the trailing per-token shape for ``KV`` leaves — e.g.
    ``(num_kv_heads, head_dim)`` — and the full per-slot shape for
    ``RECURRENT`` leaves — e.g. ``(nheads, headdim, ssm_state)``.

    ``pspec`` names the *logical* sharding axis of each ``shape`` dim (the
    vocabulary of distributed/sharding.py's rule tables: "kv_heads",
    "ssm_heads", "inner", ... or None for replicated). The serving engine
    maps these through the same logical->mesh rules the train/decode
    programs use, so a mesh places dense pools, page arenas, and recurrent
    leaves consistently with the params that read them. Empty == all
    replicated.
    """
    name: str
    shape: Tuple[int, ...]
    dtype: Any
    pspec: Tuple[Optional[str], ...] = field(default=())

    @property
    def logical(self) -> Tuple[Optional[str], ...]:
        """``pspec`` padded/validated against ``shape``."""
        if not self.pspec:
            return (None,) * len(self.shape)
        if len(self.pspec) != len(self.shape):
            raise ValueError(
                f"leaf {self.name}: pspec {self.pspec} does not match "
                f"shape {self.shape}")
        return tuple(self.pspec)


@dataclass(frozen=True)
class StateGroup:
    """A stack of ``apps`` identical per-layer states (the leading axis the
    layer scan unstacks). ``name`` keys the cache dict when a spec holds more
    than one group; a single-group spec packs to the group's bare leaf tuple
    (the legacy ``(k, v)`` / ``(ssm, conv)`` formats)."""
    name: str
    kind: str  # KV | RECURRENT
    apps: int
    leaves: Tuple[StateLeaf, ...]


@dataclass(frozen=True)
class CacheSpec:
    groups: Tuple[StateGroup, ...] = ()

    # -- introspection --------------------------------------------------
    @property
    def kv_groups(self) -> Tuple[StateGroup, ...]:
        return tuple(g for g in self.groups if g.kind == KV)

    @property
    def recurrent_groups(self) -> Tuple[StateGroup, ...]:
        return tuple(g for g in self.groups if g.kind == RECURRENT)

    @property
    def has_kv(self) -> bool:
        return bool(self.kv_groups)

    @property
    def has_recurrent(self) -> bool:
        return bool(self.recurrent_groups)

    @property
    def mixed(self) -> bool:
        return len(self.groups) > 1

    # -- cache pytree packing -------------------------------------------
    # Single group -> bare tuple of leaf arrays (keeps the seed formats:
    # dense (k, v), ssm (ssm, conv)); several groups -> {name: tuple}.
    def pack(self, by_group: Dict[str, Tuple]) -> Any:
        if len(self.groups) == 1:
            return by_group[self.groups[0].name]
        return {g.name: by_group[g.name] for g in self.groups}

    def unpack(self, cache: Any) -> Dict[str, Tuple]:
        if len(self.groups) == 1:
            return {self.groups[0].name: cache}
        return {g.name: cache[g.name] for g in self.groups}

    # -- init -----------------------------------------------------------
    def init_dense(self, batch: int, max_len: int) -> Any:
        """Per-slot pool: KV groups get a length axis, recurrent don't."""
        if not self.groups:
            raise ValueError("no decode state spec (encoder-only family?)")
        out = {}
        for g in self.groups:
            if g.kind == KV:
                out[g.name] = tuple(
                    jnp.zeros((g.apps, batch, max_len) + l.shape, l.dtype)
                    for l in g.leaves)
            else:
                out[g.name] = tuple(
                    jnp.zeros((g.apps, batch) + l.shape, l.dtype)
                    for l in g.leaves)
        return self.pack(out)

    def init_paged(self, n_pages: int, page_size: int, n_slots: int = 0):
        """Paged pool: KV groups become shared page arenas; recurrent groups
        (no length axis) stay per-slot and need ``n_slots``."""
        if not self.has_kv:
            raise ValueError("no pageable KV state in this family's spec")
        if self.has_recurrent and n_slots <= 0:
            raise ValueError("recurrent state groups need n_slots to size "
                             "their per-slot (non-paged) leaves")
        out = {}
        for g in self.groups:
            if g.kind == KV:
                out[g.name] = tuple(
                    jnp.zeros((g.apps, n_pages, page_size) + l.shape, l.dtype)
                    for l in g.leaves)
            else:
                out[g.name] = tuple(
                    jnp.zeros((g.apps, n_slots) + l.shape, l.dtype)
                    for l in g.leaves)
        return self.pack(out)

    # -- sharding --------------------------------------------------------
    def cache_logical(self, paged: bool) -> Any:
        """Cache-shaped pytree of logical-axis tuples for the pool layouts
        ``init_dense`` / ``init_paged`` build: the leading dims get
        ("layers", "batch", "kv_len") / ("layers", "pages", None) /
        ("layers", "batch") by kind, the trailing dims each leaf's
        :attr:`StateLeaf.pspec`. distributed/sharding.py maps the names to
        mesh axes (serve rules keep "kv_len"/"pages" replicated — any slot's
        block table must reach any page; heads split over `model`, slots
        over `data`)."""
        out = {}
        for g in self.groups:
            if g.kind == KV:
                lead = ("layers", "pages", None) if paged \
                    else ("layers", "batch", "kv_len")
            else:
                lead = ("layers", "batch")
            out[g.name] = tuple(lead + l.logical for l in g.leaves)
        return self.pack(out)

    # -- accounting ------------------------------------------------------
    def slot_state_bytes(self, max_len: int) -> int:
        """Worst-case decode-state bytes one slot can hold: a full max_len of
        KV positions plus the fixed recurrent leaves. The serving benchmark
        reports this as state-memory-per-slot."""
        total = 0
        for g in self.groups:
            for l in g.leaves:
                per = int(jnp.zeros((), l.dtype).dtype.itemsize)
                n = g.apps * per
                for d in l.shape:
                    n *= d
                total += n * (max_len if g.kind == KV else 1)
        return total


def with_draft_group(spec: CacheSpec, name: str = "draft") -> CacheSpec:
    """Self-speculative serving: extend a pure single-KV-group spec with a
    clone of that group for the drafter's KV. The cloned group shares the
    target group's per-leaf shapes/dtypes/pspecs, so the drafter's arena
    pages, admits, releases, and mesh-shards through exactly the same
    machinery — the cache pytree just becomes ``{"kv": (k, v), "draft":
    (k, v)}`` and the engine routes each forward at the right group.

    Only specs of one pageable KV group qualify: a recurrent group cannot
    re-run the drafter's state transition from the target's snapshots, and
    mixed (hybrid) specs would need per-site duplication the engine does
    not route. Raises ValueError otherwise.
    """
    if len(spec.groups) != 1 or spec.groups[0].kind != KV:
        kinds = ", ".join(f"{g.name}:{g.kind}" for g in spec.groups)
        raise ValueError(
            "self-speculation needs a spec of exactly one KV group "
            f"(got [{kinds}]); SSM/hybrid drafters are not supported")
    g = spec.groups[0]
    if g.name == name:
        raise ValueError(f"target KV group already named {name!r}")
    return CacheSpec(groups=(g, StateGroup(
        name=name, kind=KV, apps=g.apps, leaves=g.leaves)))


def _quantize_kv_like(leaf, new, qscale: float):
    """Match the engine's int8 KV-cache quantization (layers.KV_QSCALE)."""
    if leaf.dtype == jnp.int8:
        new = jnp.clip(jnp.round(new.astype(jnp.float32) * qscale), -127, 127)
    return new.astype(leaf.dtype)


def admit_dense(spec: CacheSpec, cache, states, slots, qscale: float):
    """Scatter one prefill wave's states into the per-slot pool.

    ``states`` is a cache-shaped pytree for the wave (KV leaves carry the
    bucketed prefill length on their length axis). Padding rows use slot
    index n_slots — out of range, dropped by the scatter.
    """
    pool = spec.unpack(cache)
    new = spec.unpack(states)
    out = {}
    for g in spec.groups:
        leaves = []
        for leaf, c, s in zip(g.leaves, pool[g.name], new[g.name]):
            if g.kind == KV:
                s = _quantize_kv_like(c, s, qscale)
                Lb = s.shape[2]
                leaves.append(c.at[:, slots, :Lb].set(s, mode="drop"))
            else:
                leaves.append(
                    c.at[:, slots].set(s.astype(c.dtype), mode="drop"))
        out[g.name] = tuple(leaves)
    return spec.pack(out)


def admit_paged(spec: CacheSpec, cache, states, slots, page, off, ok,
                qscale: float):
    """Paged-pool admit: KV leaves scatter through (page, off) computed from
    the wave's freshly-allocated block tables (out-of-range pages drop);
    recurrent leaves slot-scatter, gated on ``ok`` so a failed page
    allocation leaves NO trace of the wave anywhere in the cache."""
    pool = spec.unpack(cache)
    new = spec.unpack(states)
    out = {}
    for g in spec.groups:
        leaves = []
        for leaf, c, s in zip(g.leaves, pool[g.name], new[g.name]):
            if g.kind == KV:
                s = _quantize_kv_like(c, s, qscale)
                leaves.append(c.at[:, page, off].set(s, mode="drop"))
            else:
                scat = c.at[:, slots].set(s.astype(c.dtype), mode="drop")
                leaves.append(jnp.where(ok, scat, c))
        out[g.name] = tuple(leaves)
    return spec.pack(out)


def release_slots(spec: CacheSpec, cache, slots):
    """Zero-reset released slots' recurrent state (KV needs no reset — stale
    positions are masked by cache position; recurrent state has no position
    to mask by, so a freed slot must not leak its final state into whatever
    inspects the pool next)."""
    if not spec.has_recurrent:
        return cache
    pool = spec.unpack(cache)
    out = {}
    for g in spec.groups:
        if g.kind == RECURRENT:
            out[g.name] = tuple(
                c.at[:, slots].set(jnp.zeros((), c.dtype), mode="drop")
                for c in pool[g.name])
        else:
            out[g.name] = pool[g.name]
    return spec.pack(out)
