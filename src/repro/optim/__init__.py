from repro.optim.optimizers import (  # noqa: F401
    adamw_init, adamw_update, rmsprop_init, rmsprop_update,
    clip_by_global_norm, Optimizer, make_optimizer,
)
from repro.optim.schedule import cosine_warmup  # noqa: F401
from repro.optim.grad_compress import topk_compress_update  # noqa: F401
