"""Gradient compression with error feedback — a distributed-optimization
trick for the cross-pod (DCN) all-reduce at 1000+ node scale.

Top-k sparsification per leaf: only the k largest-|g| entries survive; the
residual is fed back into the next step's gradient (error feedback keeps
convergence). At mesh scale this turns the pod-axis all-reduce of dense
gradients into an exchange of (values, indices), cutting DCN bytes by ~1/ratio.

Under SPMD we model compression *before* the psum: each shard zeroes its
non-top-k entries, so the all-reduce moves (mostly) zeros — XLA cannot
exploit that on its own, but on real DCN fabrics a sparse collective (or
allgather of packed values) realizes the win; the roofline accounting in
benchmarks/table7 uses the packed-bytes model.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def topk_sparsify(g: jnp.ndarray, ratio: float) -> jnp.ndarray:
    """Zero all but the top `ratio` fraction (by |value|) of entries."""
    flat = g.reshape(-1)
    k = max(int(flat.shape[0] * ratio), 1)
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    return jnp.where(jnp.abs(g) >= thresh, g, 0).astype(g.dtype)


def topk_compress_update(grads, error_state, ratio: float = 0.1
                         ) -> Tuple[dict, dict]:
    """Apply error feedback + top-k sparsification.

    Returns (compressed grads to feed the all-reduce, new error state).
    """
    if error_state is None:
        error_state = jax.tree_util.tree_map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads)
    corrected = jax.tree_util.tree_map(
        lambda g, e: g.astype(jnp.float32) + e, grads, error_state)
    compressed = jax.tree_util.tree_map(
        lambda c: topk_sparsify(c, ratio), corrected)
    new_error = jax.tree_util.tree_map(
        lambda c, s: c - s, corrected, compressed)
    compressed = jax.tree_util.tree_map(
        lambda c, g: c.astype(g.dtype), compressed, grads)
    return compressed, new_error
