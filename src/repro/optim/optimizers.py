"""Pure-JAX optimizers (no optax in this environment).

AdamW with configurable state dtype — bf16 states halve optimizer HBM, which
is what lets the 405B config fit v5e chips under full (FSDP x TP) sharding.
Supports a `trainable` boolean pytree (LoRA fine-tuning freezes base weights)
and a `grad_mask` pytree (sparsity-preserving fine-tuning: masked weights
receive zero update, keeping N:M patterns exact).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


def _tmap(f, *ts):
    return jax.tree_util.tree_map(f, *ts)


def clip_by_global_norm(grads, max_norm: float):
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree_util.tree_leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return _tmap(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gn


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw_init(params, state_dtype=jnp.float32):
    zeros = lambda p: jnp.zeros(p.shape, state_dtype)
    return {"mu": _tmap(zeros, params), "nu": _tmap(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def adamw_update(params, grads, state, tc: TrainConfig, lr,
                 trainable=None, grad_mask=None):
    step = state["step"] + 1
    b1, b2 = tc.beta1, tc.beta2

    if grad_mask is not None:
        grads = _tmap(lambda g, m: g * m.astype(g.dtype) if m is not None else g,
                      grads, grad_mask)

    mu = _tmap(lambda m, g: (b1 * m.astype(jnp.float32)
                             + (1 - b1) * g.astype(jnp.float32)).astype(m.dtype),
               state["mu"], grads)
    nu = _tmap(lambda v, g: (b2 * v.astype(jnp.float32)
                             + (1 - b2) * jnp.square(g.astype(jnp.float32))).astype(v.dtype),
               state["nu"], grads)
    c1 = 1 - b1 ** step.astype(jnp.float32)
    c2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, m, v):
        mhat = m.astype(jnp.float32) / c1
        vhat = v.astype(jnp.float32) / c2
        delta = mhat / (jnp.sqrt(vhat) + tc.eps) + tc.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = _tmap(upd, params, mu, nu)
    if trainable is not None:
        new_params = _tmap(lambda n, o, t: n if t else o,
                           new_params, params, trainable)
    return new_params, {"mu": mu, "nu": nu, "step": step}


# ---------------------------------------------------------------------------
# Adafactor (factored second moment — near-zero optimizer HBM; what lets the
# 405B config fit v5e chips together with bf16 grad accumulation)
# ---------------------------------------------------------------------------

def adafactor_init(params):
    def st(p):
        if p.ndim >= 2:
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}
    return {"v": _tmap(st, params), "step": jnp.zeros((), jnp.int32)}


def _adafactor_leaf(p, g, s, lr, tc, beta2):
    g32 = g.astype(jnp.float32)
    if p.ndim >= 2:
        vr = beta2 * s["vr"] + (1 - beta2) * jnp.mean(g32 * g32, axis=-1)
        vc = beta2 * s["vc"] + (1 - beta2) * jnp.mean(g32 * g32, axis=-2)
        # rank-1 reconstruction of the second moment
        denom = (vr[..., None] * vc[..., None, :]
                 / jnp.maximum(jnp.mean(vr, axis=-1)[..., None, None], 1e-30))
        upd = g32 / (jnp.sqrt(denom) + tc.eps)
        new_s = {"vr": vr, "vc": vc}
    else:
        v = beta2 * s["v"] + (1 - beta2) * g32 * g32
        upd = g32 / (jnp.sqrt(v) + tc.eps)
        new_s = {"v": v}
    # Adafactor update clipping (d=1.0)
    rms = jnp.sqrt(jnp.mean(upd * upd) + 1e-30)
    upd = upd / jnp.maximum(1.0, rms)
    new_p = (p.astype(jnp.float32)
             - lr * (upd + tc.weight_decay * p.astype(jnp.float32))).astype(p.dtype)
    return new_p, new_s


def adafactor_update(params, grads, state, tc: TrainConfig, lr,
                     trainable=None, grad_mask=None):
    step = state["step"] + 1
    beta2 = 1.0 - (step.astype(jnp.float32) + 1.0) ** -0.8  # paper schedule
    if grad_mask is not None:
        grads = _tmap(lambda g, m: g * m.astype(g.dtype) if m is not None else g,
                      grads, grad_mask)
    is_state = lambda x: isinstance(x, dict) and ("vr" in x or "v" in x)
    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_s = jax.tree_util.tree_flatten(state["v"], is_leaf=is_state)[0]
    new_p, new_s = [], []
    for p, g, s in zip(flat_p, flat_g, flat_s):
        np_, ns_ = _adafactor_leaf(p, g, s, lr, tc, beta2)
        new_p.append(np_)
        new_s.append(ns_)
    new_params = jax.tree_util.tree_unflatten(tdef, new_p)
    new_state = {"v": jax.tree_util.tree_unflatten(tdef, new_s), "step": step}
    if trainable is not None:
        new_params = _tmap(lambda n, o, t: n if t else o,
                           new_params, params, trainable)
    return new_params, new_state


# ---------------------------------------------------------------------------
# RMSprop (Regional Optimizer uses this per the paper)
# ---------------------------------------------------------------------------

def rmsprop_init(params, state_dtype=jnp.float32):
    return _tmap(lambda p: jnp.zeros(p.shape, state_dtype), params)


def rmsprop_update(params, grads, state, lr, decay=0.99, eps=1e-8):
    new_state = _tmap(
        lambda v, g: (decay * v.astype(jnp.float32)
                      + (1 - decay) * jnp.square(g.astype(jnp.float32))).astype(v.dtype),
        state, grads)
    new_params = _tmap(
        lambda p, g, v: (p.astype(jnp.float32)
                         - lr * g.astype(jnp.float32)
                         / (jnp.sqrt(v.astype(jnp.float32)) + eps)).astype(p.dtype),
        params, grads, new_state)
    return new_params, new_state


# ---------------------------------------------------------------------------
# facade
# ---------------------------------------------------------------------------

@dataclass
class Optimizer:
    init: Callable
    update: Callable  # (params, grads, state, lr) -> (params, state)


def make_optimizer(tc: TrainConfig, trainable=None, grad_mask=None) -> Optimizer:
    sd = jnp.bfloat16 if tc.optimizer_state_dtype == "bfloat16" else jnp.float32

    def init(params):
        return adamw_init(params, sd)

    def update(params, grads, state, lr):
        grads, gn = clip_by_global_norm(grads, tc.grad_clip)
        p, s = adamw_update(params, grads, state, tc, lr,
                            trainable=trainable, grad_mask=grad_mask)
        return p, s, gn

    return Optimizer(init=init, update=update)
