"""LR schedules."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_warmup(step, base_lr: float, warmup: int, total: int, min_frac=0.1):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = base_lr * jnp.minimum(1.0, (step + 1) / max(warmup, 1))
    t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < warmup, warm, base_lr * cos)
