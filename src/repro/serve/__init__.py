"""Continuous-batching serving engine.

Layout:
    sampling.py  — ``SamplingConfig`` + pure on-device token sampling
    slots.py     — slot-batched request state (the KV-cache pool bookkeeping)
    engine.py    — jitted prefill / scan-decode programs + the ``Engine``
    scheduler.py — request queue, length-bucketed admission, timing stats
"""
from repro.serve.engine import Engine, EngineConfig, generate
from repro.serve.sampling import SamplingConfig, sample_tokens
from repro.serve.scheduler import Completion, Request
from repro.serve.slots import SlotState, init_slots

__all__ = [
    "Engine",
    "EngineConfig",
    "SamplingConfig",
    "sample_tokens",
    "SlotState",
    "init_slots",
    "Request",
    "Completion",
    "generate",
]
