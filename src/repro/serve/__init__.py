"""Continuous-batching serving engine.

Layout:
    sampling.py  — ``SamplingConfig`` + pure on-device token sampling
    slots.py     — slot-batched request state (per-slot scalars)
    paging.py    — paged KV pool: block tables + jit-safe page allocator
    engine.py    — jitted prefill / scan-decode programs + the ``Engine``
    scheduler.py — request queue, length-bucketed admission, timing stats
"""
from repro.serve.engine import (Engine, EngineConfig, PagesExhausted,
                                PrefixEntry, generate)
from repro.serve.paging import PageState, init_pages
from repro.serve.sampling import SamplingConfig, sample_tokens
from repro.serve.scheduler import Completion, Request
from repro.serve.slots import SlotState, init_slots

__all__ = [
    "Engine",
    "EngineConfig",
    "PagesExhausted",
    "PrefixEntry",
    "SamplingConfig",
    "sample_tokens",
    "SlotState",
    "init_slots",
    "PageState",
    "init_pages",
    "Request",
    "Completion",
    "generate",
]
