"""Continuous-batching inference engine: jitted prefill + scan decode.

The decode hot loop is ONE jitted program per chunk length: ``lax.scan``
over T steps of [batched decode_step -> sample -> finish-flag update], all
on device. The host syncs once per chunk (to harvest tokens and refill
freed slots), never per token — TPOT measures the hardware, not Python
dispatch, which is the whole point of the Wanda++ 2:4 deployment story
(Table 7: decode is weight-bandwidth-bound, sparsity halves the traffic).

Prefill runs as a separate jitted program per (wave, bucket-length) shape;
waves are padded to power-of-two sizes and prompt lengths to configured
buckets so trace counts stay O(#buckets), not O(#requests).

Every family serves through the same spec-driven plumbing: the model's
``CacheSpec`` (models/state_spec.py) declares each per-layer state group as
either attention KV (length axis — pageable, default paged) or fixed-shape
recurrent state (Mamba2 SSD state + conv window — snapshot-on-prefill,
per-slot scatter admit, zero-reset on release). Admission runs one
full-sequence forward with ``seq_lens`` (so recurrent snapshots land after
each row's LAST VALID token despite bucket padding) and scatters every
group through ``state_spec.admit_*``; a hybrid (Zamba2) spec pages its
shared-attention KV while its mamba layers slot-scatter. VLM requests carry
``vision_embeds`` — the vision prefix occupies the first cache positions
and the slot keeps a rotary offset (M-RoPE's text stream restarts at the
vision grid edge) so decode positions stay exact.

KV storage is a **paged pool** by default (``EngineConfig.paged``): slots
map per-slot block tables into a shared (L, n_pages, page_size, KV, hd)
arena (see serve/paging.py), so HBM scales with the tokens actually cached
instead of n_slots x max_len. On TPU, decode reads the arena through the
Pallas paged-attention kernel (``EngineConfig.paged_kernel``; see
kernels/paged_attention.py) — per-step KV traffic is O(tokens cached), not
O(max_blocks * page_size). Off-TPU the materialising gather stays the
default (the kernel would run through the Pallas interpreter there);
``paged_kernel=True/False`` forces either path. ``paged=False`` keeps the
dense (L, n_slots, max_len, KV, hd) pool as the parity/memory baseline; a
spec with no KV groups (pure SSM) has nothing to page and always uses the
per-slot pool.

The engine is **mesh-aware** (``EngineConfig.mesh``): given a
`(data, model)` mesh (launch/mesh.py), params shard by the same
distributed/sharding.py rule table the train/dryrun programs use (TP heads /
ffn over ``model``), and the runtime state shards with them — slot scalars,
per-slot pools, and block-table rows over ``data``; KV and recurrent head
dims over ``model`` via each CacheSpec leaf's ``pspec``; the page arena's
page axis, the free list, and the host mirrors (free-page count, prefix
registry) replicated, because any slot's block table must reach any page.
Every jitted program is built with explicit ``in_shardings``/
``out_shardings`` so the state never silently migrates. Sharded greedy
decode is bit-exact against the single-device engine, and sampled decode
draws from per-slot keys (serve/sampling.py) so meshed streams reproduce
the unmeshed ones token for token; ``mesh=None`` is exactly the
single-device engine.

Weights are served **2:4-compressed** when the checkpoint is 2:4-pruned
(``EngineConfig.compressed24``, default auto-detect): at engine build every
sparse projection packs ONCE into (w24_vals, w24_idx) — 2-bit packed
indices, 0.5625x bf16 weight bytes (kernels/ops.py compact24) — and block
matmuls dispatch through ``layers.sparse24_lin``: the Pallas compacted
matmul on TPU (``compressed24_kernel``), or a build-time dense
materialization elsewhere (bit-exact, so greedy tokens match the
uncompressed engine). ``compressed24="masked"`` instead serves the
(w, int8 mask) pair with the mask applied in-flight each step — the
masked-dense reference the serving benchmark gates against.

Shared prompt prefixes (:meth:`Engine.register_prefix`) live in a
**multi-prefix registry**: each registered prefix is prefetched once into
refcounted pages and mapped — never recomputed — into every request that
starts with it (longest match wins). When admission runs out of free pages,
idle prefixes (no live slot mapping them) are evicted LRU-first; a request
matching an evicted prefix transparently falls back to full prefill.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.models import state_spec as SSPEC
from repro.models.layers import KV_QSCALE
from repro.models.model import Model, mrope_text_start
from repro.serve import paging as PAGE
from repro.serve import slots as SLOT
from repro.serve.paging import PageState
from repro.serve.sampling import (SamplingConfig, process_logits,
                                  sample_tokens, slot_keys)
from repro.serve.slots import SlotState, init_slots


class PagesExhausted(RuntimeError):
    """Admission would need more KV pages than the free list holds (even
    after evicting idle shared prefixes); the scheduler reacts by requeueing
    until decode releases live slots."""


@dataclass
class PrefixEntry:
    """One registered shared prompt prefix (whole KV pages only)."""
    pid: int
    tokens: np.ndarray  # (length,) int32
    pages: np.ndarray  # (length // page_size,) int32 arena pages
    length: int  # shared tokens == len(pages) * page_size
    live: int = 0  # slots currently mapping these pages
    last_used: int = 0  # LRU stamp (engine admission clock)


@dataclass(frozen=True)
class EngineConfig:
    n_slots: int = 8  # max concurrent requests
    max_len: int = 128  # cache length cap per request (vision prefix incl.)
    chunk: int = 16  # decode steps per host round-trip
    eos_id: Optional[int] = None  # None => length-only termination
    prefill_buckets: Tuple[int, ...] = (16, 32, 64, 128)
    paged: bool = True  # block-table paged KV pool; False => dense pool
    page_size: int = 16  # tokens per KV page
    n_pages: Optional[int] = None  # arena size; None => n_slots * max_blocks
    # Pallas paged-attention decode kernel vs the materialising gather.
    # None == auto: kernel on TPU (where its O(tokens-cached) HBM walk is
    # the win), gather elsewhere (off-TPU the kernel only runs through the
    # Pallas interpreter — a correctness path, ~4x slower than the gather's
    # plain HLO). True/False force either path (tests, benchmarks, CLI).
    paged_kernel: Optional[bool] = None
    # 2:4 compressed-weight serving (models/blocks.py compress_params24).
    #   "auto" (== None)  detect 2:4-sparse projections at engine build and
    #                     pack them into (w24_vals, w24_idx) — 2-bit packed
    #                     indices, 0.5625x bf16 weight bytes. Non-pruned
    #                     checkpoints never pass the sparsity check, so auto
    #                     is an exact no-op for them.
    #   "on"              same, but raise if nothing is 2:4-sparse.
    #   "off"             serve the params untouched (masked-dense status quo).
    #   "masked"          attach int8 keep-masks and apply them in-flight
    #                     every step (layers.masked24_lin) — the reference
    #                     mode the serving benchmark gates against.
    # Greedy decode is bit-exact across auto/on/off/masked on the non-kernel
    # path (decompression is the exact inverse of the packing).
    compressed24: Optional[str] = None
    # Compressed projections through the Pallas compacted matmul vs the
    # engine-build dense copy. None == auto: kernel on TPU (where reading
    # 0.5625x the weight bytes is the decode win), dense copy elsewhere (a
    # per-step decompression without a sparse matmul unit only adds work;
    # the dense copy is materialized ONCE from the packed form, bit-exact).
    compressed24_kernel: Optional[bool] = None
    # (data, model) serving mesh (launch/mesh.py). Params shard by the
    # distributed/sharding.py rule table (TP heads/ffn over `model`); slot
    # state, per-slot pools, and block-table rows shard over `data`; KV /
    # recurrent head dims over `model` via each CacheSpec leaf's pspec; the
    # page arena's page axis and the host mirrors (free pages, prefix
    # registry) stay replicated. Divisibility is validated at Engine
    # construction — an indivisible n_slots (data) or kv_heads (model)
    # degrades that axis to replication with a RuntimeWarning (mirroring
    # sharding.py's per-dim rule) instead of failing inside jit.
    # None == the exactly-single-device engine, byte-for-byte unchanged.
    mesh: Optional[Mesh] = None
    # Self-speculative decoding: the drafter (a Wanda++ 2:4-pruned copy of
    # the target, passed as Engine(draft_params=...)) proposes draft_k
    # tokens per macro step; the target verifies all draft_k + 1 positions
    # in ONE batched forward and the accepted prefix is emitted with an
    # exact-rejection-sampling correction (greedy output is bit-exact vs
    # target-only decode). 0 == spec decode off. The drafter's KV lives in
    # the shared arena as a second CacheSpec group sharing the target's
    # block tables, so admission allocates draft_k extra positions of
    # headroom per slot (the drafter runs ahead of the accepted length).
    draft_k: int = 0
    # Chunked prefill (continuous batching v2): prompts stream into the
    # DECODE program as fixed-size chunks — each scan step runs the decode
    # slots plus one prefill-chunk lane writing chunk KV straight into the
    # arena via the block tables — so a newly admitted request emits tokens
    # without waiting for any other prompt's prefill (no bucket waves, no
    # prefill/decode phase distinction on the scheduler path). None == auto:
    # on for pure token-KV, non-vision specs (paged or dense pool); off for
    # recurrent/hybrid/VLM families, whose admission needs the full-sequence
    # forward (recurrent snapshot placement, vision prefixes) and keeps the
    # waved path. generate() always serves waved — it is the parity
    # baseline the chunked stream is pinned bit-exact against.
    chunked_prefill: Optional[bool] = None
    # prefill tokens the chunk lane processes per decode step; a prompt of
    # P tokens streams in as ceil(P / chunk_size) steps' lanes, its final
    # (ragged) chunk re-overlapping the previous chunk's tail so every lane
    # is exactly chunk_size wide (one traced shape, any prompt length)
    chunk_size: int = 16
    # Online calibration taps: every decode / prefill program additionally
    # accumulates per-linear input statistics (running ||X||^2 / |X| / X
    # sums + token counts, stacked per layer — the Wanda / Wanda++ / STADE /
    # CoNNect calibration state, see core/scores.py) from LIVE traffic.
    # The stats ride each jitted program as one extra donated carry: no
    # extra trace, no host sync — harvest stays the one round-trip, and
    # :meth:`Engine.calibration_snapshot` exports them for core.pruner.
    # False == exact status quo (signatures and traced programs unchanged).
    calib_taps: bool = False

    @property
    def max_blocks(self) -> int:
        return -(-self.max_len // self.page_size)

    @property
    def pool_pages(self) -> int:
        # the default arena matches the dense pool's worst case, so shrinking
        # n_pages below it is exactly the HBM saving paging buys
        return self.n_pages if self.n_pages is not None \
            else self.n_slots * self.max_blocks


def _bucket_len(buckets: Sequence[int], plen: int, max_len: int) -> int:
    for b in sorted(buckets):
        if b >= plen and b <= max_len:
            return b
    if plen <= max_len:
        return max_len
    raise ValueError(f"prompt of {plen} tokens exceeds max_len={max_len}")


def _pad_pow2(n: int, cap: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return min(p, cap)


def _vis_patches(v) -> int:
    return 0 if v is None else int(v.shape[0])


def _rope_delta(n_patches: int) -> int:
    """M-RoPE text positions restart at the vision grid edge: a text token
    at cache position p carries rotary position p + (start - n_patches),
    with ``start`` taken from the SAME helper prefill uses."""
    if n_patches == 0:
        return 0
    return mrope_text_start(n_patches) - n_patches


class Engine:
    """Slot-batched serving over a fixed decode-state pool.

    Drive it either with :meth:`generate` (one same-shape wave, single
    decode program, single device sync — the benchmark/test path) or with
    ``scheduler.Scheduler`` (continuous batching: admit-on-free interleaved
    with chunked decode). Serves every decoder family — dense, MoE, SSM
    (Mamba2), hybrid (Zamba2), VLM (Qwen2-VL) — through the model's
    CacheSpec; only encoder-only archs (no decode path) are rejected.
    """

    def __init__(self, model: Model, params, cfg: EngineConfig = EngineConfig(),
                 sampling: SamplingConfig = SamplingConfig(),
                 draft_params=None):
        mcfg = model.cfg
        if mcfg.is_encoder_only:
            raise ValueError(
                f"{mcfg.name}: encoder-only arch has no decode path")
        spec = model.cache_spec
        if not spec.groups:
            raise ValueError(
                f"{mcfg.name}: family {mcfg.family!r} declares no decode "
                "state (see models/state_spec.py)")
        if cfg.draft_k < 0:
            raise ValueError(f"draft_k={cfg.draft_k} must be >= 0")
        self.spec_decode = cfg.draft_k > 0
        if self.spec_decode and draft_params is None:
            raise ValueError(
                "draft_k > 0 needs draft_params (the self-speculation "
                "drafter — a pruned copy of the target's params)")
        if draft_params is not None and not self.spec_decode:
            raise ValueError("draft_params given but draft_k == 0")
        self.model = model
        self.params = params
        self.cfg = cfg
        # self-speculation extends the cache spec with a cloned "draft" KV
        # group (raises for recurrent/hybrid specs, which cannot draft)
        self.spec = SSPEC.with_draft_group(spec) if self.spec_decode else spec
        self.needs_vision = mcfg.frontend == "vision"
        # a spec with no KV group (pure SSM) has nothing to page: its
        # recurrent state is per-slot either way, so the paged machinery
        # (arena, block tables, host page mirrors) is never built
        self.paged = cfg.paged and spec.has_kv
        self.paged_kernel = cfg.paged_kernel if cfg.paged_kernel is not None \
            else jax.default_backend() == "tpu"
        # 2:4 compressed-weight serving: pack sparse projections ONCE at
        # build (before any mesh placement, so the packed leaves shard by
        # the same rule table), then dispatch every block matmul through
        # the matching lin backend. self._lin stays None when nothing
        # compressed — the model then runs its default linear path.
        mode = cfg.compressed24 if cfg.compressed24 is not None else "auto"
        if mode not in ("auto", "on", "off", "masked"):
            raise ValueError(
                f"compressed24={mode!r}: expected auto|on|off|masked")
        self.compressed24_kernel = cfg.compressed24_kernel \
            if cfg.compressed24_kernel is not None \
            else jax.default_backend() == "tpu"
        self.compressed24 = 0  # projections actually compressed/masked
        self._lin = None
        if mode != "off" and params is not None:
            from repro.models.blocks import compress_params24
            from repro.models.layers import masked24_lin, sparse24_lin
            params, n24 = compress_params24(
                mcfg, params, keep_dense=not self.compressed24_kernel,
                masked=(mode == "masked"))
            if mode == "on" and n24 == 0:
                raise ValueError(
                    "compressed24='on': no 2:4-sparse projection found "
                    "(serve a pruned checkpoint, or use 'auto')")
            if n24:
                self.params = params
                self.compressed24 = n24
                self._lin = masked24_lin if mode == "masked" \
                    else sparse24_lin(self.compressed24_kernel)
        # drafter weights go through the same compression pass with their
        # own lin dispatch: a 2:4-pruned drafter serves compressed (the
        # whole point of drafting with the Wanda++ artifact) even when the
        # dense target does not, and vice versa. No "on"-style raise here:
        # mode "on" polices the target; an accidentally-dense drafter still
        # serves, it just buys no weight-traffic win.
        self.compressed24_draft = 0
        self._draft_lin = None
        if self.spec_decode and mode != "off":
            from repro.models.blocks import compress_params24
            from repro.models.layers import masked24_lin, sparse24_lin
            dp, dn24 = compress_params24(
                mcfg, draft_params, keep_dense=not self.compressed24_kernel,
                masked=(mode == "masked"))
            if dn24:
                draft_params = dp
                self.compressed24_draft = dn24
                self._draft_lin = masked24_lin if mode == "masked" \
                    else sparse24_lin(self.compressed24_kernel)
        self.draft_params = draft_params
        # the weight tuple every jitted program takes as argument 0:
        # (target,) or (target, drafter). A tuple (not two args) keeps the
        # donate_argnums positions of cache/state/pstate/key identical
        # across both modes.
        self._wp = (self.params,) if not self.spec_decode \
            else (self.params, self.draft_params)
        # cache-length headroom the drafter needs to run ahead of the
        # accepted sequence: admission budgets draft_k extra positions
        self._draft_pad = cfg.draft_k if self.spec_decode else 0
        # chunked prefill: the chunk lane IS decode_multi (pure token-KV
        # specs only) and the first token samples off in-stream logits (no
        # full-sequence admission forward), so recurrent/hybrid/VLM
        # families keep the waved path. None == auto-enable when eligible.
        # judged on the MODEL's spec: the self-speculation "draft" group is
        # a second pure-KV arena, which the chunk lane fills just fine
        eligible = (not spec.mixed and not spec.has_recurrent
                    and not self.needs_vision)
        if cfg.chunked_prefill and not eligible:
            raise ValueError(
                f"{mcfg.name}: chunked prefill needs a pure token-KV, "
                "non-vision family (recurrent snapshot placement and "
                "vision prefixes require the full-sequence admission "
                "forward)")
        # a chunk never exceeds max_len: the dense pool's window write
        # would clamp-shift, and the overlap re-anchor assumes a chunk
        # fits the prompt's cache extent. Auto mode falls back to waved
        # when the configured chunk can't fit; forcing it is an error.
        fits = 1 <= cfg.chunk_size <= cfg.max_len
        self.chunked_prefill = (eligible and fits) \
            if cfg.chunked_prefill is None else bool(cfg.chunked_prefill)
        if self.chunked_prefill and not fits:
            raise ValueError(
                f"chunk_size={cfg.chunk_size} must be in "
                f"[1, max_len={cfg.max_len}]")
        # online calibration taps (Wanda++ statistics from live traffic):
        # pure token-KV, non-vision, target-only engines — the tap masks
        # ride the standard layer scans and the stats must describe the
        # served model's own linear inputs
        self.calib_taps = bool(cfg.calib_taps)  # lint: allow(host-sync)
        if self.calib_taps:
            if spec.mixed or spec.has_recurrent or self.needs_vision:
                raise ValueError(
                    f"{mcfg.name}: calib_taps needs a pure token-KV, "
                    "non-vision family (tap statistics ride the standard "
                    "layer scan of the decode/prefill programs)")
            if self.spec_decode:
                raise ValueError(
                    "calib_taps with speculative decoding is not supported "
                    "(tap a target-only engine)")
        self._fill: list = []  # chunked-prefill queue (see admit_chunked)
        self.sampling = sampling
        self.key = jax.random.PRNGKey(sampling.seed)
        self.pstate: Optional[PageState] = None
        if self.paged:
            # host mirror of the device free list (allocation is
            # deterministic, so admission can check capacity without a
            # device round-trip) — paged pools ONLY: a dense pool carrying
            # page counters would hand the scheduler stale accounting
            self._free_pages = cfg.pool_pages
            self._slot_pages = np.zeros(cfg.n_slots, np.int64)
            # multi-prefix registry: pid -> PrefixEntry, plus a per-slot
            # record of which prefix each live slot maps (-1 == none)
            self._prefixes: dict[int, PrefixEntry] = {}
            self._next_pid = 0
            self._lru_clock = 0
            self._slot_prefix = np.full(cfg.n_slots, -1, np.int64)
        # mesh-sharded serving: derive shardings from the one logical->mesh
        # rule table (distributed/sharding.py) against eval_shape'd pool
        # SHAPES — nothing is allocated yet, so _alloc_pools below can build
        # every pool as a jitted program with out_shardings (each shard
        # lands directly on its device; a host-side init would materialise
        # the FULL arena on one device first, the very per-chip HBM ceiling
        # the mesh exists to lift). Every runtime program is then built with
        # explicit in/out shardings. mesh=None keeps the single-device
        # engine exactly as before (no sharding args anywhere).
        self.mesh = cfg.mesh
        self._sh = None
        self._alloc_jits = None
        if self.mesh is not None:
            from repro.distributed import sharding as SHARD
            self._sh = SHARD.serve_state_shardings(
                self.mesh, mcfg, self.spec, jax.eval_shape(self._mk_cache),
                jax.eval_shape(self._mk_pstate) if self.paged else None,
                cfg.n_slots, self.paged)
            self._sh["params"] = SHARD.wave_param_shardings(
                self.mesh, mcfg, self._wp, "decode")
            self._wp = jax.device_put(self._wp, self._sh["params"])
            self.params = self._wp[0]
            if self.spec_decode:
                self.draft_params = self._wp[1]
            n_slots = cfg.n_slots
            self._alloc_jits = (
                jax.jit(lambda: init_slots(n_slots),
                        out_shardings=self._sh["slots"]),
                jax.jit(self._mk_cache, out_shardings=self._sh["cache"]),
                jax.jit(self._mk_pstate, out_shardings=self._sh["pstate"])
                if self.paged else None)
        self._alloc_pools()
        # calib stats live OUTSIDE reset(): they are collected traffic, not
        # slot state — reset_calibration() zeroes them explicitly
        self._calib = self._init_calib() if self.calib_taps else None
        self.stats = {"shared_tokens_saved": 0, "prefix_evictions": 0}
        # trace counters: the no-retrace-per-token guarantee is testable
        self.trace_counts = {"decode": 0, "prefill": 0}
        self._decode_jit = {}  # chunk length T -> compiled program
        W, C, S, PS, R = self._prog_shardings()
        # with taps on, every prefill program takes the running stats as one
        # extra donated (replicated) argument and returns the new stats
        ct = self.calib_taps
        if self.paged:
            self._prefill_jit = self._jit(
                self._prefill_paged_impl,
                (1, 2, 3, 4, 10) if ct else (1, 2, 3, 4),
                (W, C, S, PS, R, R, R, R, R, R) + ((R,) if ct else ()),
                (C, S, PS, R, R, R) + ((R,) if ct else ()))
            self._prefill_shared_jit = self._jit(
                self._prefill_shared_impl,
                (1, 2, 3, 4, 11) if ct else (1, 2, 3, 4),
                (W, C, S, PS, R, R, R, R, R, R, R) + ((R,) if ct else ()),
                (C, S, PS, R, R, R) + ((R,) if ct else ()))
            self._register_jit = self._jit(
                self._register_impl, (1, 2), (W, C, PS, R), (C, PS, R, R))
            self._unreserve_jit = self._jit(PAGE.unreserve, (0,), (PS, R), PS)
            # chunked admission maps pages WITHOUT any prefill forward (the
            # fill rides the decode chunks); the shared variant retraces
            # once per distinct prefix page count, like the waved program
            self._chunk_alloc_jit = self._jit(
                PAGE.alloc, (0,), (PS, R, R), (PS, R))
            self._chunk_alloc_shared_jit = self._jit(
                PAGE.alloc, (0,), (PS, R, R, R, R), (PS, R))
        else:
            self._prefill_jit = self._jit(
                self._prefill_pool_impl,
                (1, 2, 3, 9) if ct else (1, 2, 3),
                (W, C, S, R, R, R, R, R, R) + ((R,) if ct else ()),
                (C, S, R, R) + ((R,) if ct else ()))
        self._release_jit = self._jit(
            self._release_impl, (0, 1, 2), (C, S, PS, R), (C, S, PS))

    # ------------------------------------------------------------------
    # mesh plumbing
    # ------------------------------------------------------------------
    def _mk_cache(self):
        # built from self.spec (not model.init_*): under self-speculation
        # the engine's spec carries the extra "draft" KV group, so the pool
        # holds both arenas; without it this is exactly the model's cache
        cfg = self.cfg
        if self.paged:
            return self.spec.init_paged(cfg.pool_pages, cfg.page_size,
                                        n_slots=cfg.n_slots)
        return self.spec.init_dense(cfg.n_slots, cfg.max_len)

    def _mk_pstate(self):
        cfg = self.cfg
        return PAGE.init_pages(cfg.pool_pages, cfg.n_slots, cfg.max_blocks)

    def _init_calib(self):
        """Zeros pytree matching the stacked (L, ...) per-linear tap
        statistics — the shape comes from ONE eval_shape probe of the
        tapped forward (nothing runs, nothing allocates until tree_map)."""
        taps_abs = jax.eval_shape(
            lambda p, t: self.model.forward(
                p, {"tokens": t}, lin=self._lin, collect_taps=True)[2],
            self.params, jax.ShapeDtypeStruct((1, 2), jnp.int32))
        z = jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype), taps_abs)
        if self._sh is not None:
            z = jax.device_put(z, jax.tree_util.tree_map(
                lambda _: self._sh["repl"], z))
        return z

    def _alloc_pools(self):
        """Fresh slot state, cache, and page state (init + every reset).
        Under a mesh the initializers are jitted with ``out_shardings`` so
        each device allocates only ITS shard of the pools; the PRNG key is
        placed replicated. Host mirrors (_free_pages, _slot_pages, the
        prefix registry) are numpy-side and reset by the caller."""
        if self._sh is None:
            self.state = init_slots(self.cfg.n_slots)
            self.cache = self._mk_cache()
            self.pstate = self._mk_pstate() if self.paged else None
            return
        mk_state, mk_cache, mk_pstate = self._alloc_jits
        self.state = mk_state()
        self.cache = mk_cache()
        self.pstate = mk_pstate() if self.paged else None
        self.key = jax.device_put(self.key, self._sh["repl"])

    def _prog_shardings(self):
        """(params, cache, slot-state, page-state, replicated) sharding
        entries for the jitted programs. All None when unmeshed — self._jit
        then ignores them and builds the plain single-device jits. The
        slot-state entry is ONE sharding used as a pytree prefix for every
        SlotState scalar; the page-state entry falls back to replicated for
        dense pools (the pstate argument is None there)."""
        if self._sh is None:
            return None, None, None, None, None
        ps = self._sh["pstate"] if self._sh["pstate"] is not None \
            else self._sh["repl"]
        return (self._sh["params"], self._sh["cache"], self._sh["slots"],
                ps, self._sh["repl"])

    def _jit(self, fn, donate, in_sh, out_sh):
        if self._sh is None:
            # single-device engine: no mesh, shardings intentionally absent
            return jax.jit(fn, donate_argnums=donate)  # lint: allow(jit-shardings)
        return jax.jit(fn, donate_argnums=donate,
                       in_shardings=in_sh, out_shardings=out_sh)

    def _for_sampling(self, logits):
        """Under a mesh, pin sampled-path logits to REPLICATED before the
        categorical draw. The TP unembed leaves logits vocab-sharded, and
        jax's default (non-partitionable) threefry is not layout-invariant:
        random bits generated against a vocab-sharded operand differ from
        the single-device stream, which would break the same-seed parity
        guarantee. Greedy needs no constraint (argmax is layout-exact), so
        the pure-greedy programs keep the cheap sharded reduction."""
        if self._sh is not None and not self.sampling.greedy:
            logits = jax.lax.with_sharding_constraint(
                logits, self._sh["repl"])
        return logits

    # ------------------------------------------------------------------
    # jitted programs
    # ------------------------------------------------------------------
    def _decode_impl(self, wp, cache, state, key, block_tables, calib=None,
                     *, T):
        self.trace_counts["decode"] += 1
        params = wp[0]
        sc, eos = self.sampling, self.cfg.eos_id

        def step(carry, _):
            cache, state, key, calib = carry
            key, sub = jax.random.split(key)
            run = state.active & ~state.finished
            inputs = {"token": state.last_token, "pos": state.pos,
                      "rope_pos": state.pos + state.rope_delta}
            if block_tables is not None:
                inputs["block_table"] = block_tables
            if self.calib_taps:
                # frozen/parked slots re-feed their last token: run masks
                # them out of the statistics (their compute is discarded)
                logits, cache, taps = self.model.decode_step(
                    params, inputs, cache, paged_kernel=self.paged_kernel,
                    lin=self._lin, collect_taps=True,
                    tap_weights=run[:, None])
                calib = jax.tree_util.tree_map(jnp.add, calib, taps)
            else:
                logits, cache = self.model.decode_step(
                    params, inputs, cache, paged_kernel=self.paged_kernel,
                    lin=self._lin)
            nxt = sample_tokens(self._for_sampling(logits), sub, sc)
            # frozen slots keep re-feeding their last token at a fixed pos;
            # the KV write lands on a position admission will overwrite
            # (paged: on an unmapped block, where the scatter drops it) and
            # their recurrent state churn is erased by the admit scatter
            nxt = jnp.where(run, nxt, state.last_token)
            pos = state.pos + run.astype(jnp.int32)
            done = pos >= state.max_total
            if eos is not None:
                done = done | (nxt == eos)
            state = state._replace(last_token=nxt, pos=pos,
                                   finished=state.finished | (run & done))
            return (cache, state, key, calib), (nxt, run)

        (cache, state, key, calib), (toks, valid) = jax.lax.scan(
            step, (cache, state, key, calib), None, length=T)
        if self.calib_taps:
            return cache, state, key, toks, valid, calib
        return cache, state, key, toks, valid  # toks/valid: (T, n_slots)

    # -- self-speculative decode -----------------------------------------
    # PRNG tags for the spec-decode draws; each (tag, position) pair folds
    # into the macro step's key before the per-slot fold, so a slot's draw
    # depends only on (seed, step, tag, position, slot) — the same layout
    # invariance sample_tokens gets from slot_keys.
    _TAG_DRAFT, _TAG_ACCEPT, _TAG_RESAMPLE, _TAG_BONUS = 1, 2, 3, 4

    def _spec_keys(self, sub, tag: int, i: int):
        return slot_keys(
            jax.random.fold_in(jax.random.fold_in(sub, tag), i),
            self.cfg.n_slots)

    def _decode_spec_impl(self, wp, cache, state, key, block_tables, *, T):
        """T speculative macro steps. Each: the drafter proposes draft_k
        tokens autoregressively through its own KV group, the target
        verifies all draft_k + 1 positions in ONE batched ``decode_multi``
        forward, and the accepted prefix plus one corrected token is
        emitted (exact rejection sampling — greedy emission is the target's
        own argmax chain, bit-exact vs target-only decode).

        Cache-position invariant (both arenas): ``last_token`` sits at
        position ``pos`` with its KV *not yet written*; a macro step writes
        positions [pos, pos+k] in BOTH arenas (the drafter's k proposal
        steps write [pos, pos+k-1], plus one discarded-logits KV-fill step
        for d_k at pos+k — without it an all-accept step would advance past
        a draft-arena gap that is never rewritten) and
        advances pos by the emitted count, so every position < pos always
        holds accepted-sequence KV and the garbage a rejection leaves
        behind is overwritten by the next macro step before any read could
        reach it (attention masks by cache position).

        Emits (T*(k+1), n_slots) token/valid rows — position-major within
        each macro step, so harvest/scheduler consume them unchanged; a
        rejected proposal is simply an invalid row.
        """
        self.trace_counts["decode"] += 1
        S = self.cfg.draft_k + 1

        def step(carry, _):
            cache, state, key = carry
            key, sub = jax.random.split(key)
            cache, state, emit, val = self._spec_macro_step(
                wp, cache, state, sub, block_tables)
            return (cache, state, key), (emit.T, val.T)

        (cache, state, key), (toks, valid) = jax.lax.scan(
            step, (cache, state, key), None, length=T)
        n = toks.shape[-1]
        return (cache, state, key,
                toks.reshape(T * S, n), valid.reshape(T * S, n))

    def _spec_macro_step(self, wp, cache, state, sub, block_tables):
        """One speculative macro step (draft k -> KV-fill -> batched verify
        -> accept/correct -> bookkeeping); shared verbatim by the waved and
        chunked decode programs. Returns (cache, state, emit, val) with
        emit/val shaped (n_slots, k+1), position-major."""
        params, draft_params = wp
        sc, eos = self.sampling, self.cfg.eos_id
        k = self.cfg.draft_k
        S = k + 1
        run = state.active & ~state.finished
        caches = dict(self.spec.unpack(cache))
        pos0 = state.pos
        if not self.paged:
            # the dense pool's dynamic_update_slice CLAMPS its start
            # index: keep the whole S-token write in-bounds. Admission
            # headroom (max_total + k <= max_len) means this never
            # binds for a running slot — only frozen ones, whose
            # outputs are discarded and whose slot is rewritten from
            # scratch on re-admission.
            pos0 = jnp.minimum(pos0, self.cfg.max_len - S)
        rope0 = pos0 + state.rope_delta

        # 1) drafter proposes k tokens through its own arena
        cur = state.last_token
        d_toks, d_probs = [], []
        for i in range(k):
            inputs = {"token": cur, "pos": pos0 + i, "rope_pos": rope0 + i}
            if block_tables is not None:
                inputs["block_table"] = block_tables
            lg, caches["draft"] = self.model.decode_step(
                draft_params, inputs, caches["draft"],
                paged_kernel=self.paged_kernel, lin=self._draft_lin)
            if sc.greedy:
                cur = jnp.argmax(lg, axis=-1).astype(jnp.int32)
            else:
                plg = process_logits(self._for_sampling(lg), sc)
                cur = jax.vmap(jax.random.categorical)(
                    self._spec_keys(sub, self._TAG_DRAFT, i), plg
                ).astype(jnp.int32)
                d_probs.append(jax.nn.softmax(plg, axis=-1))
            d_toks.append(cur)
        d_toks = jnp.stack(d_toks, axis=1)  # (n_slots, k)
        # KV-fill for d_k at pos0+k (logits discarded): when all k
        # proposals are accepted, the next macro step resumes at
        # pos0+k+1 and the drafter attends position pos0+k — which no
        # later write ever covers. Greedy output would stay exact (the
        # emission is the target's chain), but the drafter would draft
        # against garbage from then on and acceptance would collapse.
        inputs = {"token": cur, "pos": pos0 + k, "rope_pos": rope0 + k}
        if block_tables is not None:
            inputs["block_table"] = block_tables
        _, caches["draft"] = self.model.decode_step(
            draft_params, inputs, caches["draft"],
            paged_kernel=self.paged_kernel, lin=self._draft_lin)

        # 2) target verifies [last, d_1..d_k] in one batched forward
        ver = jnp.concatenate([state.last_token[:, None], d_toks], axis=1)
        inputs = {"tokens": ver, "pos": pos0, "rope_pos": rope0}
        if block_tables is not None:
            inputs["block_table"] = block_tables
        t_logits, caches["kv"] = self.model.decode_multi(
            params, inputs, caches["kv"],
            paged_kernel=self.paged_kernel, lin=self._lin)  # (n, S, V)

        # 3) accept-prefix + corrected resample
        if sc.greedy:
            # row i of t_logits conditions on [.., last, d_1..d_i]: the
            # target's own greedy chain IS the emission — an accepted
            # d_j equals chain[j-1] by construction, and chain[acc] is
            # the bonus/correction token. Bit-exact vs target-only.
            emit = jnp.argmax(t_logits, axis=-1).astype(jnp.int32)
            ok = (d_toks == emit[:, :k]).astype(jnp.int32)
            acc = jnp.sum(jnp.cumprod(ok, axis=1), axis=1)
        else:
            nB, _, V = t_logits.shape
            p_all = jax.nn.softmax(process_logits(
                self._for_sampling(t_logits.reshape(nB * S, V)), sc
            ), axis=-1).reshape(nB, S, V)
            q_all = jnp.stack(d_probs, axis=1)  # (n, k, V)
            p_d = jnp.take_along_axis(
                p_all[:, :k], d_toks[..., None], axis=-1)[..., 0]
            q_d = jnp.take_along_axis(
                q_all, d_toks[..., None], axis=-1)[..., 0]
            u = jnp.stack([
                jax.vmap(jax.random.uniform)(
                    self._spec_keys(sub, self._TAG_ACCEPT, i))
                for i in range(k)], axis=1)  # (n, k)
            # u in [0, 1): draft == target gives the ratio exactly 1,
            # so every proposal is accepted (the satellite test's pin)
            ok = (u < p_d / jnp.maximum(q_d, 1e-30)).astype(jnp.int32)
            acc = jnp.sum(jnp.cumprod(ok, axis=1), axis=1)
            # corrected distribution at the first rejection: residual
            # max(p - q, 0) renormalized; all-zero residual implies
            # p == q, where rejection has probability 0 — the p_j
            # fallback only guards the unselected lanes' categorical
            res = jnp.maximum(p_all[:, :k] - q_all, 0.0)
            dist = jnp.where(
                jnp.sum(res, axis=-1, keepdims=True) > 0,
                res, p_all[:, :k])
            corr = [jax.vmap(jax.random.categorical)(
                self._spec_keys(sub, self._TAG_RESAMPLE, j),
                jnp.log(dist[:, j])) for j in range(k)]
            corr.append(jax.vmap(jax.random.categorical)(
                self._spec_keys(sub, self._TAG_BONUS, 0),
                jnp.log(p_all[:, k])))
            corr = jnp.stack(corr, axis=1).astype(jnp.int32)  # (n, S)
            base = jnp.concatenate(
                [d_toks, jnp.zeros_like(d_toks[:, :1])], axis=1)
            sel = jnp.arange(S, dtype=jnp.int32)[None, :] == acc[:, None]
            emit = jnp.where(sel, corr, base)

        # 4) emission masks + slot bookkeeping (budget, EOS, freeze)
        remaining = jnp.maximum(state.max_total - state.pos, 0)
        n_emit = jnp.where(run, jnp.minimum(acc + 1, remaining), 0)
        val = jnp.arange(S, dtype=jnp.int32)[None, :] < n_emit[:, None]
        if eos is not None:
            is_eos = val & (emit == eos)
            hit = is_eos.astype(jnp.int32)
            val = val & ((jnp.cumsum(hit, axis=1) - hit) == 0)
            n_emit = jnp.sum(val.astype(jnp.int32), axis=1)
        new_pos = state.pos + n_emit
        done = new_pos >= state.max_total
        if eos is not None:
            done = done | jnp.any(val & (emit == eos), axis=1)
        last = jnp.take_along_axis(
            emit, jnp.maximum(n_emit - 1, 0)[:, None], axis=1)[:, 0]
        state = state._replace(
            last_token=jnp.where(n_emit > 0, last, state.last_token),
            pos=new_pos,
            finished=state.finished | (run & done))
        cache = self.spec.pack(caches)
        return cache, state, emit, val

    # -- chunked prefill: the unified step program ------------------------
    # PRNG tag for the chunk lane's first-token draw: a distinct fold of
    # the step key, so the decode lane's sampling stream is untouched by
    # whether a chunk rides the step (greedy is key-independent either way)
    _TAG_CHUNK = 5

    def _chunk_step(self, wp, cache, state, sub, s, block_tables):
        """The prefill-chunk lane of the unified step program: run ONE
        prompt chunk (schedule slice ``s``, see :meth:`build_schedule`)
        through ``decode_multi`` at B=1, writing its KV straight into the
        slot's pages (paged) or pool row (dense). On the prompt's final
        chunk, sample the first token from the chunk's last valid position
        — the same logits row the waved prefill reads — and activate the
        slot; decode picks it up NEXT step, so the lanes never race on a
        slot. Idle lanes (slot == n_slots) run the same compute against an
        all-unmapped block-table row / a discarded pool-row copy, so
        varying fill load never changes the traced program.

        Returns (cache, state, first_token, admit_slot, chunk_taps);
        admit_slot == n_slots when no request activates this step, and
        chunk_taps is the lane's tap-statistics pytree (None with taps
        off)."""
        cfg = self.cfg
        lane_on = s["slot"] < cfg.n_slots
        caches = dict(self.spec.unpack(cache))
        groups = [("kv", wp[0], self._lin)]
        if self.spec_decode:
            # the drafter's arena fills from the SAME chunk stream: it
            # shares the target's block tables (pages already mapped), so
            # the draft fill is one more B=1 decode_multi, logits discarded
            groups.append(("draft", wp[1], self._draft_lin))
        tw, chunk_taps = None, None
        if self.calib_taps:
            # count only this chunk's FRESH tokens: the ragged final chunk
            # re-anchors over the previous chunk's tail (see
            # build_schedule), and re-run overlap positions must not be
            # double-counted; idle lanes contribute nothing
            idx = jnp.arange(cfg.chunk_size, dtype=jnp.int32)
            tw = ((idx >= s["fresh"]) & (idx < s["len"]) & lane_on)[None, :]
        logits = None
        for name, params, lin in groups:
            inp = {"tokens": s["toks"][None], "pos": s["pos"][None]}
            if self.paged:
                # an out-of-range slot (idle lane) gathers an all-unmapped
                # row: every KV write drops, every read fills zero
                inp["block_table"] = block_tables.at[s["slot"][None]].get(
                    mode="fill", fill_value=cfg.pool_pages)
                if self.calib_taps and name == "kv":
                    lg, caches[name], chunk_taps = self.model.decode_multi(
                        params, inp, caches[name],
                        paged_kernel=self.paged_kernel, lin=lin,
                        collect_taps=True, tap_weights=tw)
                else:
                    lg, caches[name] = self.model.decode_multi(
                        params, inp, caches[name],
                        paged_kernel=self.paged_kernel, lin=lin)
            else:
                # dense pool: slice the slot's cache row, run the lane at
                # B=1 against the copy, write back only when the lane is
                # live (the idle lane's garbage never lands)
                sl = jnp.minimum(s["slot"], cfg.n_slots - 1)
                row = jax.tree_util.tree_map(
                    lambda a: jax.lax.dynamic_slice_in_dim(a, sl, 1, axis=1),
                    caches[name])
                if self.calib_taps and name == "kv":
                    lg, new_row, chunk_taps = self.model.decode_multi(
                        params, inp, row, paged_kernel=self.paged_kernel,
                        lin=lin, collect_taps=True, tap_weights=tw)
                else:
                    lg, new_row = self.model.decode_multi(
                        params, inp, row, paged_kernel=self.paged_kernel,
                        lin=lin)
                new_row = jax.tree_util.tree_map(
                    lambda a, b: jnp.where(lane_on, a, b), new_row, row)
                caches[name] = jax.tree_util.tree_map(
                    lambda c, r: jax.lax.dynamic_update_slice_in_dim(
                        c, r, sl, axis=1),
                    caches[name], new_row)
            if logits is None:
                logits = lg  # first tokens come from the TARGET's logits
        cache = self.spec.pack(caches)

        # the prompt's last token sits at lane index len-1; its logits row
        # is the first-token distribution
        li = jnp.clip(s["len"] - 1, 0, cfg.chunk_size - 1)
        last = jax.lax.dynamic_index_in_dim(logits[0], li, 0,
                                            keepdims=False)[None]  # (1, V)
        first = sample_tokens(
            self._for_sampling(last),
            jax.random.fold_in(sub, self._TAG_CHUNK), self.sampling)
        fire = s["first"] & lane_on
        aslot = jnp.where(fire, s["slot"], cfg.n_slots)[None]  # (1,)
        state, _ = self._admit_state(
            state, aslot, first, s["plen"][None], s["max_new"][None],
            jnp.zeros((1,), jnp.int32))
        return cache, state, first[0], aslot[0], chunk_taps

    def _decode_chunked_impl(self, wp, cache, state, key, block_tables,
                             sched, calib=None, *, T):
        """The unified chunked-prefill step program: every scan step runs
        the decode lane over all live slots (identical math — and identical
        PRNG stream — to ``_decode_impl``) PLUS one prefill-chunk lane fed
        by ``sched``. A request admitted mid-chunk emits its first token
        the step its final chunk lands and decodes from the next step on —
        no other prompt's prefill ever blocks a running slot's tokens."""
        self.trace_counts["decode"] += 1
        params = wp[0]
        sc, eos = self.sampling, self.cfg.eos_id

        def step(carry, s):
            cache, state, key, calib = carry
            key, sub = jax.random.split(key)
            run = state.active & ~state.finished
            inputs = {"token": state.last_token, "pos": state.pos,
                      "rope_pos": state.pos + state.rope_delta}
            if block_tables is not None:
                inputs["block_table"] = block_tables
            if self.calib_taps:
                logits, cache, taps = self.model.decode_step(
                    params, inputs, cache, paged_kernel=self.paged_kernel,
                    lin=self._lin, collect_taps=True,
                    tap_weights=run[:, None])
                calib = jax.tree_util.tree_map(jnp.add, calib, taps)
            else:
                logits, cache = self.model.decode_step(
                    params, inputs, cache, paged_kernel=self.paged_kernel,
                    lin=self._lin)
            nxt = sample_tokens(self._for_sampling(logits), sub, sc)
            nxt = jnp.where(run, nxt, state.last_token)
            pos = state.pos + run.astype(jnp.int32)
            done = pos >= state.max_total
            if eos is not None:
                done = done | (nxt == eos)
            state = state._replace(last_token=nxt, pos=pos,
                                   finished=state.finished | (run & done))
            # chunk lane AFTER the decode lane: an activating slot was not
            # in `run`, so the lanes never touch the same slot's row
            cache, state, first, aslot, ctaps = self._chunk_step(
                wp, cache, state, sub, s, block_tables)
            if self.calib_taps:
                calib = jax.tree_util.tree_map(jnp.add, calib, ctaps)
            nxt = nxt.at[aslot].set(first, mode="drop")
            valid = run.at[aslot].set(True, mode="drop")
            return (cache, state, key, calib), (nxt, valid)

        (cache, state, key, calib), (toks, valid) = jax.lax.scan(
            step, (cache, state, key, calib), sched)
        if self.calib_taps:
            return cache, state, key, toks, valid, calib
        return cache, state, key, toks, valid  # toks/valid: (T, n_slots)

    def _decode_chunked_spec_impl(self, wp, cache, state, key, block_tables,
                                  sched, *, T):
        """Chunked-prefill variant of the speculative program: each macro
        step runs draft/verify exactly as ``_decode_spec_impl`` (shared
        body) plus one prefill-chunk lane filling BOTH arenas; an
        activating request's first token is emitted as position row 0 of
        its macro step, and its draft stream starts the next macro step."""
        self.trace_counts["decode"] += 1
        S = self.cfg.draft_k + 1

        def step(carry, s):
            cache, state, key = carry
            key, sub = jax.random.split(key)
            cache, state, emit, val = self._spec_macro_step(
                wp, cache, state, sub, block_tables)
            cache, state, first, aslot, _ = self._chunk_step(
                wp, cache, state, sub, s, block_tables)
            emit = emit.at[aslot, 0].set(first, mode="drop")
            val = val.at[aslot, 0].set(True, mode="drop")
            return (cache, state, key), (emit.T, val.T)

        (cache, state, key), (toks, valid) = jax.lax.scan(
            step, (cache, state, key), sched)
        n = toks.shape[-1]
        return (cache, state, key,
                toks.reshape(T * S, n), valid.reshape(T * S, n))

    def _sample_first(self, logits, lasts, key):
        """Per-row logits at index ``lasts`` -> each request's first token."""
        last = jnp.take_along_axis(
            logits, jnp.maximum(lasts, 0)[:, None, None], axis=1)[:, 0]
        key, sub = jax.random.split(key)
        return sample_tokens(self._for_sampling(last), sub, self.sampling), key

    def _admit_state(self, state, slots, first, plens, max_news, rope_delta):
        """Scatter slot metadata for an admitted wave; ``plens`` counts every
        cache position the prompt holds (vision prefix included)."""
        max_total = plens + jnp.maximum(max_news, 1) - 1
        state = SLOT.admit(state, slots, first, plens, max_total, rope_delta)
        done0 = max_total <= plens  # max_new == 1: the prefill token is it
        if self.cfg.eos_id is not None:
            done0 = done0 | (first == self.cfg.eos_id)
        state = state._replace(
            finished=state.finished.at[slots].set(done0, mode="drop"))
        return state, max_total

    def _prefill_taps(self, tokens, plens, slots):
        """Tap-weight mask for an admission wave: real rows (slot <
        n_slots — padding rows scatter to the drop slot) x valid prompt
        positions. None with taps off."""
        if not self.calib_taps:
            return None
        S = tokens.shape[1]
        return (jnp.arange(S, dtype=jnp.int32)[None, :] < plens[:, None]) \
            & (slots < self.cfg.n_slots)[:, None]

    def _forward_wave(self, params, tokens, plens, vis, lin,
                      tap_weights=None):
        """The admission forward: full-sequence pass over the (padded) wave,
        vision prefix prepended for VLM waves, seq_lens pinning recurrent
        snapshots to each row's last valid token. Returns (logits, states,
        effective prompt lens, per-row rope delta, taps-or-None)."""
        inputs = {"tokens": tokens}
        n_patches = 0
        if vis is not None:
            inputs["vision_embeds"] = vis
            n_patches = vis.shape[1]
        if self.calib_taps and tap_weights is not None:
            logits, _, states, taps = self.model.forward(
                params, inputs, return_cache=True, seq_lens=plens, lin=lin,
                collect_taps=True, tap_weights=tap_weights)
        else:
            logits, _, states = self.model.forward(params, inputs,
                                                   return_cache=True,
                                                   seq_lens=plens,
                                                   lin=lin)
            taps = None
        eff = plens + n_patches
        delta = jnp.full_like(plens, _rope_delta(n_patches))
        return logits, states, eff, delta, taps

    def _wave_states(self, wp, tokens, plens, vis, tap_weights=None):
        """Admission forward(s): the target's wave pass, plus — under
        self-speculation — the drafter's pass over the SAME wave inside the
        same jitted program (one prefill trace either way), its KV packed
        as the spec's "draft" group. First-token logits always come from
        the target, so admission semantics match target-only serving.
        Only the TARGET pass is tapped (the stats describe its inputs)."""
        logits, states, eff, delta, taps = self._forward_wave(
            wp[0], tokens, plens, vis, self._lin, tap_weights)
        if self.spec_decode:
            _, d_states, _, _, _ = self._forward_wave(
                wp[1], tokens, plens, vis, self._draft_lin)
            states = self.spec.pack({"kv": states, "draft": d_states})
        return logits, states, eff, delta, taps

    def _prefill_pool_impl(self, wp, cache, state, key, tokens, plens,
                           slots, max_news, vis, calib=None):
        """One admission wave into the per-slot pool (dense KV rows and/or
        recurrent leaves): forward the (padded) prompts, sample first
        tokens, scatter every spec group + slot metadata."""
        self.trace_counts["prefill"] += 1
        logits, states, eff, delta, taps = self._wave_states(
            wp, tokens, plens, vis, self._prefill_taps(tokens, plens, slots))
        first, key = self._sample_first(logits, eff - 1, key)
        cache = SSPEC.admit_dense(self.spec, cache, states, slots, KV_QSCALE)
        state, _ = self._admit_state(state, slots, first, eff, max_news,
                                     delta)
        if self.calib_taps:
            calib = jax.tree_util.tree_map(jnp.add, calib, taps)
            return cache, state, key, first, calib
        return cache, state, key, first

    def _prefill_paged_impl(self, wp, cache, state, pstate, key, tokens,
                            plens, slots, max_news, vis, calib=None):
        """Fresh-request admission into the paged pool. Same forward as the
        per-slot path (bit-exact parity); KV groups scatter through the
        freshly-allocated block tables, recurrent groups slot-scatter."""
        self.trace_counts["prefill"] += 1
        cfg = self.cfg
        logits, states, eff, delta, taps = self._wave_states(
            wp, tokens, plens, vis, self._prefill_taps(tokens, plens, slots))
        first, key = self._sample_first(logits, eff - 1, key)

        max_total = eff + jnp.maximum(max_news, 1) - 1
        n_blocks = (max_total + self._draft_pad
                    + cfg.page_size - 1) // cfg.page_size
        pstate, ok = PAGE.alloc(pstate, slots, n_blocks)
        bt = pstate.block_tables.at[slots].get(
            mode="fill", fill_value=cfg.pool_pages)  # (K, MB)

        K, Lb = tokens.shape
        S = Lb + (0 if vis is None else vis.shape[1])
        tpos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None, :],
                                (K, S))
        pidx = tpos // cfg.page_size
        page = jnp.where(
            pidx < cfg.max_blocks,
            jnp.take_along_axis(bt, jnp.minimum(pidx, cfg.max_blocks - 1),
                                axis=1),
            cfg.pool_pages)  # bucket padding past the allocation: dropped
        off = tpos % cfg.page_size
        cache = SSPEC.admit_paged(self.spec, cache, states, slots, page, off,
                                  ok, KV_QSCALE)

        new_state, _ = self._admit_state(state, slots, first, eff, max_news,
                                         delta)
        state = jax.tree_util.tree_map(
            lambda a, b: jnp.where(ok, a, b), new_state, state)
        if self.calib_taps:
            calib = jax.tree_util.tree_map(jnp.add, calib, taps)
            return cache, state, pstate, key, first, ok, calib
        return cache, state, pstate, key, first, ok

    def _prefill_shared_impl(self, wp, cache, state, pstate, key, tokens,
                             suff_lens, shared_lens, slots, max_news,
                             shared_pages, calib=None):
        """Shared-prefix admission (pure token-KV specs only): map the
        registered prefix pages (refcounted) into each slot's block table,
        then prefill ONLY the suffix through the paged pool — the shared
        pages' prefill is skipped entirely. Under self-speculation the
        suffix prefills BOTH arenas (the drafter attends the same shared
        pages — its arena got its copy at register_prefix time)."""
        self.trace_counts["prefill"] += 1
        cfg = self.cfg
        plens = shared_lens + suff_lens
        max_total = plens + jnp.maximum(max_news, 1) - 1
        n_blocks = (max_total + self._draft_pad
                    + cfg.page_size - 1) // cfg.page_size
        n_shared = shared_lens // cfg.page_size
        pstate, ok = PAGE.alloc(pstate, slots, n_blocks, n_shared, shared_pages)
        bt = pstate.block_tables.at[slots].get(
            mode="fill", fill_value=cfg.pool_pages)

        inp = {"tokens": tokens, "pos": shared_lens,
               "last": suff_lens - 1, "block_table": bt}
        caches = dict(self.spec.unpack(cache))
        if self.calib_taps:
            # suffix-only statistics: the shared prefix's activations were
            # counted once at register time by whoever computed them — the
            # mapped pages run no linear here, so there is nothing to tap
            tw = self._prefill_taps(tokens, suff_lens, slots)
            last, caches["kv"], taps = self.model.prefill_paged(
                wp[0], inp, caches["kv"],
                paged_kernel=self.paged_kernel, lin=self._lin,
                collect_taps=True, tap_weights=tw)
        else:
            last, caches["kv"] = self.model.prefill_paged(
                wp[0], inp, caches["kv"],
                paged_kernel=self.paged_kernel, lin=self._lin)
        if self.spec_decode:
            _, caches["draft"] = self.model.prefill_paged(
                wp[1], inp, caches["draft"],
                paged_kernel=self.paged_kernel, lin=self._draft_lin)
        cache = self.spec.pack(caches)
        key, sub = jax.random.split(key)
        first = sample_tokens(self._for_sampling(last), sub, self.sampling)

        new_state, _ = self._admit_state(state, slots, first, plens, max_news,
                                         jnp.zeros_like(plens))
        state = jax.tree_util.tree_map(
            lambda a, b: jnp.where(ok, a, b), new_state, state)
        if self.calib_taps:
            calib = jax.tree_util.tree_map(jnp.add, calib, taps)
            return cache, state, pstate, key, first, ok, calib
        return cache, state, pstate, key, first, ok

    def _register_impl(self, wp, cache, pstate, tokens):
        """Prefetch a shared prefix: reserve pages off the free list with a
        permanent hold and prefill the prefix KV into them once — into both
        arenas under self-speculation (one set of pages, two KV groups)."""
        cfg = self.cfg
        n_full = tokens.shape[1] // cfg.page_size
        pstate, pages, ok = PAGE.reserve(pstate, n_full)
        bt = jnp.full((1, cfg.max_blocks), cfg.pool_pages,
                      jnp.int32).at[0, :n_full].set(pages)
        inp = {"tokens": tokens, "pos": jnp.zeros((1,), jnp.int32),
               "last": jnp.asarray([tokens.shape[1] - 1], jnp.int32),
               "block_table": bt}
        caches = dict(self.spec.unpack(cache))
        _, caches["kv"] = self.model.prefill_paged(
            wp[0], inp, caches["kv"],
            paged_kernel=self.paged_kernel, lin=self._lin)
        if self.spec_decode:
            _, caches["draft"] = self.model.prefill_paged(
                wp[1], inp, caches["draft"],
                paged_kernel=self.paged_kernel, lin=self._draft_lin)
        return self.spec.pack(caches), pstate, pages, ok

    def _release_impl(self, cache, state, pstate, slots):
        """Free harvested slots in ONE program: clear the slot scalars, zero
        any recurrent state leaves (no positions to mask them by), and with
        a paged pool unmap the block tables, returning the pages to the
        free list."""
        state = SLOT.release(state, slots)
        cache = SSPEC.release_slots(self.spec, cache, slots)
        if pstate is not None:
            pstate = PAGE.release(pstate, slots)
        return cache, state, pstate

    def _decode_fn(self, T: int, chunked: bool = False):
        """Compiled decode program for a T-row chunk. Target-only: T scan
        steps, one token row each. Self-speculation: ceil(T / (k+1)) macro
        steps, each emitting k+1 rows (so the returned row count is T
        rounded up to a macro-step multiple). ``chunked`` selects the
        unified chunked-prefill program (same decode lane + one
        prefill-chunk lane per step, fed by a build_schedule pytree);
        waved and chunked programs are cached independently, so driving
        both never retraces either."""
        if (T, chunked) not in self._decode_jit:
            W, C, S, PS, R = self._prog_shardings()
            bt = PS.block_tables if (self._sh is not None and self.paged) \
                else R
            m = -(-T // (self.cfg.draft_k + 1)) if self.spec_decode else T
            ct = self.calib_taps  # extra donated stats carry in/out
            if chunked:
                impl = functools.partial(
                    self._decode_chunked_spec_impl if self.spec_decode
                    else self._decode_chunked_impl, T=m)
                # the schedule arrays ride replicated (every device scans
                # the same fill assignments)
                self._decode_jit[(T, chunked)] = self._jit(
                    impl, (1, 2, 3, 6) if ct else (1, 2, 3),
                    (W, C, S, R, bt, R) + ((R,) if ct else ()),
                    (C, S, R, R, R) + ((R,) if ct else ()))
            else:
                impl = functools.partial(
                    self._decode_spec_impl if self.spec_decode
                    else self._decode_impl, T=m)
                self._decode_jit[(T, chunked)] = self._jit(
                    impl, (1, 2, 3, 5) if ct else (1, 2, 3),
                    (W, C, S, R, bt) + ((R,) if ct else ()),
                    (C, S, R, R, R) + ((R,) if ct else ()))
        return self._decode_jit[(T, chunked)]

    # ------------------------------------------------------------------
    # host-side driver ops (used by scheduler.Scheduler and generate())
    # ------------------------------------------------------------------
    def reset(self):
        cfg = self.cfg
        survivors = []
        if self.paged:
            self._free_pages = cfg.pool_pages
            self._slot_pages[:] = 0
            self._slot_prefix[:] = -1
            survivors = [e.tokens for e in self._prefixes.values()]
            self._prefixes = {}
        self.stats = {"shared_tokens_saved": 0, "prefix_evictions": 0}
        self.key = jax.random.PRNGKey(self.sampling.seed)
        self._fill = []
        self._alloc_pools()
        for toks in survivors:  # registered prefixes survive resets
            self.register_prefix(toks)

    @property
    def free_pages(self) -> int:
        if not self.paged:
            raise ValueError(
                "dense pool keeps no page accounting (cfg.paged is False or "
                "the model has no pageable KV state)")
        return self._free_pages

    @property
    def prefix_pages(self) -> Optional[np.ndarray]:
        """All pages held by the prefix registry (None when empty)."""
        if not self.paged or not self._prefixes:
            return None
        return np.concatenate([e.pages for e in self._prefixes.values()])

    def evictable_pages(self, exclude=()) -> int:
        """Pages reclaimable by evicting idle (no live mapping) prefixes,
        minus any whose pid is in ``exclude``. The scheduler adds this to
        :attr:`free_pages` when budgeting, excluding the prefixes its
        candidate requests map — admission never evicts a prefix the wave
        itself matches."""
        if not self.paged:
            return 0
        return sum(len(e.pages) for e in self._prefixes.values()
                   if e.live == 0 and e.pid not in exclude)

    def prefix_match(self, prompt: np.ndarray) -> Optional[PrefixEntry]:
        """Longest registered prefix covering ``prompt`` with >= 1 suffix
        token left over (the suffix provides the first-token logits)."""
        if not self.paged:
            return None
        best = None
        for e in self._prefixes.values():
            if len(prompt) > e.length and \
                    (best is None or e.length > best.length) and \
                    np.array_equal(prompt[:e.length], e.tokens):
                best = e
        return best

    def _shared_len(self, prompt: np.ndarray) -> int:
        """Tokens of ``prompt`` covered by a registered prefix (whole pages
        only; 0 when no prefix matches or no suffix token would remain).
        Test/introspection convenience — production paths call
        :meth:`prefix_match` once and reuse the entry."""
        e = self.prefix_match(np.asarray(prompt))
        return e.length if e is not None else 0

    _UNMATCHED = object()  # pages_needed sentinel: "run the prefix scan"

    def pages_needed(self, prompt, max_new: int, match=_UNMATCHED,
                     n_vis: int = 0) -> int:
        """Fresh pages admission of this request would take (0 on a dense
        pool). ``n_vis`` counts vision-prefix positions the request caches
        ahead of its text. The scheduler checks this against
        :attr:`free_pages` plus :meth:`evictable_pages`. Pass ``match`` (a
        PrefixEntry or None from :meth:`prefix_match`) to skip re-scanning
        the registry."""
        if not self.paged:
            return 0
        prompt = np.asarray(prompt)
        mt = n_vis + len(prompt) + max(max_new, 1) - 1 + self._draft_pad
        n_blocks = -(-mt // self.cfg.page_size)
        if match is Engine._UNMATCHED:
            match = self.prefix_match(prompt)
        shared = match.length if match is not None else 0
        return n_blocks - shared // self.cfg.page_size

    def _evict_lru(self, need: int, keep=()) -> None:
        """Evict idle prefixes (live == 0, pid not in ``keep``), least-
        recently-used first, until ``need`` pages are free. All-or-nothing:
        when even a full sweep could not reach ``need``, NOTHING is evicted
        — the admission is going to fail either way, and destroying
        prefetched prefixes for a wave that still cannot land would make
        every later matching request silently pay full prefill. Dropping
        the registry's hold returns a prefix's pages to the free list in
        one scatter (PAGE.unreserve)."""
        idle = [e for e in self._prefixes.values()
                if e.live == 0 and e.pid not in keep]
        if self._free_pages + sum(len(e.pages) for e in idle) < need:
            return
        idle.sort(key=lambda e: e.last_used)
        for victim in idle:
            if self._free_pages >= need:
                break
            self.pstate = self._unreserve_jit(
                self.pstate, jnp.asarray(victim.pages, jnp.int32))
            self._free_pages += len(victim.pages)
            del self._prefixes[victim.pid]
            self.stats["prefix_evictions"] += 1

    def register_prefix(self, tokens) -> int:
        """Prefetch a shared prompt prefix (system prompt) into refcounted
        pages. Only whole pages are shared; returns the shared token count.
        Subsequent admissions whose prompt starts with those tokens map the
        pages instead of recomputing their prefill. Multiple prefixes may be
        registered (longest match wins at admission); re-registering the
        same tokens is a no-op returning the existing entry's length. When
        the free list is short, idle prefixes are evicted LRU-first to make
        room. Needs a paged pool of pure token KV: recurrent state and
        vision prefixes cannot be captured by shared pages."""
        if not self.paged:
            raise ValueError("shared-prefix reuse requires a paged KV pool")
        if self.spec.has_recurrent:
            raise ValueError(
                "shared-prefix pages cannot capture recurrent (SSM) state")
        if self.needs_vision:
            raise ValueError(
                "shared-prefix reuse is token-based; vision-prefixed "
                "requests cannot map prefetched pages")
        tokens = np.asarray(tokens, np.int32).reshape(-1)
        n_full = len(tokens) // self.cfg.page_size
        if n_full == 0:
            return 0
        shared_len = n_full * self.cfg.page_size
        if shared_len >= self.cfg.max_len:
            raise ValueError(
                f"shared prefix of {shared_len} tokens leaves no room under "
                f"max_len={self.cfg.max_len}")
        for e in self._prefixes.values():
            if e.length == shared_len and \
                    np.array_equal(e.tokens, tokens[:shared_len]):
                self._lru_clock += 1
                e.last_used = self._lru_clock
                return shared_len
        if n_full > self._free_pages:
            self._evict_lru(n_full)
        if n_full > self._free_pages:
            raise PagesExhausted(
                f"prefix needs {n_full} pages, {self._free_pages} free")
        self.cache, self.pstate, pages, ok = self._register_jit(
            self._wp, self.cache, self.pstate,
            jnp.asarray(tokens[:shared_len][None]))
        assert bool(ok), "host free-page mirror out of sync with device"
        self._free_pages -= n_full
        self._lru_clock += 1
        pid = self._next_pid
        self._next_pid += 1
        self._prefixes[pid] = PrefixEntry(
            pid=pid, tokens=tokens[:shared_len].copy(),
            pages=np.asarray(pages), length=shared_len,
            last_used=self._lru_clock)
        return shared_len

    def admit_wave(self, prompts, slot_ids, max_news, keep_pids=(),
                   matches=None, vision=None):
        """Prefill `prompts` (list of 1-D int arrays) into `slot_ids`.
        Returns each request's first generated token as a (K,) numpy array
        (this is the TTFT sync). Raises :class:`PagesExhausted` when the
        paged pool cannot hold the wave (no partial admission happens).

        ``vision``: optional list of per-request (P, d_model) vision-embed
        arrays (None entries for text requests). VLM requests MUST carry
        one — the model's forward has no text-only input path. The wave is
        split into sub-waves of equal patch count so each traces one shape.

        Paged engines split the wave further: requests matching a
        registered prefix go through the suffix-only shared program (one
        sub-wave per matched prefix), the rest through the fresh-prefill
        program. A wave that outgrows the free list first evicts idle
        prefixes it does not itself match (LRU), then raises
        :class:`PagesExhausted` if still short. ``keep_pids``: extra prefix
        ids to shield from eviction — the scheduler passes its admission
        round's full matched set so an early bucket wave cannot evict a
        prefix a later wave of the same round was budgeted against.
        ``matches``: per-prompt PrefixEntry-or-None list from
        :meth:`prefix_match`, to skip re-scanning the registry when the
        caller already matched (entries must still be registered — the
        scheduler's keep_pids shielding guarantees that within a round)."""
        assert len(prompts) == len(slot_ids) == len(max_news)
        prompts = [np.asarray(p, np.int32) for p in prompts]
        if vision is None:
            vision = [None] * len(prompts)
        if self.needs_vision and any(v is None for v in vision):
            raise ValueError(
                f"{self.model.cfg.name}: vlm requests must carry "
                "vision_embeds (the vision prefix feeds the first cache "
                "positions; there is no text-only forward)")
        if not self.needs_vision and any(v is not None for v in vision):
            # the forward would silently drop the embeds while the slot /
            # page bookkeeping still counted their positions
            raise ValueError(
                f"{self.model.cfg.name}: family "
                f"{self.model.cfg.family!r} has no vision frontend; "
                "requests must not carry vision_embeds")
        for p, mn, v in zip(prompts, max_news, vision):
            total = _vis_patches(v) + len(p) + max(mn, 1) - 1 \
                + self._draft_pad
            if total > self.cfg.max_len:
                pad = (f" (draft_k={self.cfg.draft_k} headroom included)"
                       if self._draft_pad else "")
                raise ValueError(
                    f"request needs {total} cache slots > "
                    f"max_len={self.cfg.max_len}{pad}")
        if not self.paged:
            first = np.zeros(len(prompts), np.int32)
            for idxs, vis_p in self._split_by_patches(vision):
                first[idxs] = self._admit_pool(
                    [prompts[i] for i in idxs], [slot_ids[i] for i in idxs],
                    [max_news[i] for i in idxs],
                    None if vis_p == 0 else np.stack(
                        [vision[i] for i in idxs]))
            return first
        if matches is None:
            matches = [None if v is not None else self.prefix_match(p)
                       for p, v in zip(prompts, vision)]
        need = [self.pages_needed(p, mn, match=e, n_vis=_vis_patches(v))
                for p, mn, e, v in zip(prompts, max_news, matches, vision)]
        if sum(need) > self._free_pages:
            self._evict_lru(sum(need), keep={
                e.pid for e in matches if e is not None} | set(keep_pids))
        if sum(need) > self._free_pages:
            raise PagesExhausted(
                f"wave needs {sum(need)} pages, {self._free_pages} free")
        first = np.zeros(len(prompts), np.int32)
        fresh = [i for i, e in enumerate(matches) if e is None]
        for idxs, vis_p in self._split_by_patches(vision, only=fresh):
            first[idxs] = self._admit_paged(
                [prompts[i] for i in idxs], [slot_ids[i] for i in idxs],
                [max_news[i] for i in idxs], [need[i] for i in idxs],
                None if vis_p == 0 else np.stack([vision[i] for i in idxs]))
        by_pid: dict = {}
        for i, e in enumerate(matches):
            if e is not None:
                by_pid.setdefault(e.pid, []).append(i)
        for pid, idxs in by_pid.items():
            entry = self._prefixes[pid]
            first[idxs] = self._admit_shared(
                [prompts[i] for i in idxs], [slot_ids[i] for i in idxs],
                [max_news[i] for i in idxs], [need[i] for i in idxs], entry)
        return first

    @property
    def fill_pending(self) -> bool:
        """Chunked-prefill work still queued (see :meth:`admit_chunked`)."""
        return bool(self._fill)

    def admit_chunked(self, prompt, slot_id: int, max_new: int,
                      keep_pids=(), match=_UNMATCHED) -> None:
        """Queue one request for chunked prefill into ``slot_id``: allocate
        every page it will ever need NOW (all-or-nothing — raises
        :class:`PagesExhausted` like admit_wave), map a matching registered
        prefix's pages refcounted, and enqueue the prompt suffix on the
        fill queue. No forward runs here: the prefill compute rides the
        next decode chunks' unified step program (:meth:`build_schedule` +
        ``decode_chunk(schedule=...)``), and the first token is sampled on
        device the step the final chunk lands — there is no separate
        prefill program, bucket zoo, or first-token sync on this path."""
        if not self.chunked_prefill:
            raise ValueError(
                "engine built without chunked prefill "
                "(cfg.chunked_prefill resolved False)")
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        total = len(prompt) + max(max_new, 1) - 1 + self._draft_pad
        if total > self.cfg.max_len:
            pad = (f" (draft_k={self.cfg.draft_k} headroom included)"
                   if self._draft_pad else "")
            raise ValueError(
                f"request needs {total} cache slots > "
                f"max_len={self.cfg.max_len}{pad}")
        start = 0
        if self.paged:
            if match is Engine._UNMATCHED:
                match = self.prefix_match(prompt)
            need = self.pages_needed(prompt, max_new, match=match)
            if need > self._free_pages:
                self._evict_lru(need, keep=(
                    {match.pid} if match is not None else set())
                    | set(keep_pids))
            if need > self._free_pages:
                raise PagesExhausted(
                    f"request needs {need} pages, {self._free_pages} free")
            slots = jnp.asarray([slot_id], jnp.int32)
            n_blocks = jnp.asarray(
                [-(-total // self.cfg.page_size)], jnp.int32)
            if match is not None:
                self.pstate, ok = self._chunk_alloc_shared_jit(
                    self.pstate, slots, n_blocks,
                    jnp.asarray([match.length // self.cfg.page_size],
                                jnp.int32),
                    jnp.asarray(match.pages, jnp.int32))
                start = match.length
            else:
                self.pstate, ok = self._chunk_alloc_jit(
                    self.pstate, slots, n_blocks)
            assert bool(ok), "host free-page mirror out of sync with device"
            self._book_pages([slot_id], [need])
            if match is not None:
                self._lru_clock += 1
                match.last_used = self._lru_clock
                match.live += 1
                self._slot_prefix[slot_id] = match.pid
                self.stats["shared_tokens_saved"] += match.length
        self._fill.append({
            "slot": int(slot_id), "toks": prompt[start:], "start": start,
            "plen": len(prompt), "max_new": int(max_new), "next": 0})

    def build_schedule(self, T: Optional[int] = None):
        """Carve the next decode chunk's prefill-lane assignments off the
        fill queue (host-side, FIFO — a request's chunks stay in order
        because each chunk attends the previous one's cached KV). Returns
        ``(schedule, first_rows)``: the device pytree
        ``decode_chunk(T, schedule=...)`` scans over, and ``{slot: row}``
        naming the emitted-token row where each completing request's first
        token lands (the scheduler's per-chunk TTFT attribution). Idle
        steps carry an out-of-range slot — same traced program, the lane's
        writes drop.

        Chunk boundaries: full ``chunk_size`` chunks, with the final
        ragged chunk re-anchored to start ``chunk_size`` tokens before the
        prompt's end — re-running the overlap recomputes bit-identical KV
        (same tokens, positions, and visible prefix), so ONE traced lane
        width covers every prompt length."""
        cfg = self.cfg
        T = T or cfg.chunk
        CS = cfg.chunk_size
        S = cfg.draft_k + 1 if self.spec_decode else 1
        steps = -(-T // S)
        toks = np.zeros((steps, CS), np.int32)
        slot = np.full((steps,), cfg.n_slots, np.int32)
        pos = np.zeros((steps,), np.int32)
        ln = np.ones((steps,), np.int32)
        first = np.zeros((steps,), bool)
        plen = np.ones((steps,), np.int32)
        max_new = np.ones((steps,), np.int32)
        # lane index of the chunk's first not-yet-processed token: the
        # ragged final chunk re-runs the previous chunk's tail for KV
        # parity, and the calibration tap lane must not count the overlap
        # positions twice (0 for every full chunk)
        fresh = np.zeros((steps,), np.int32)
        first_rows: dict = {}
        t = 0
        while t < steps and self._fill:
            f = self._fill[0]
            n = len(f["toks"])
            b = min(f["next"] + CS, n)
            a = f["next"] if b - f["next"] == CS else max(b - CS, 0)
            toks[t, : b - a] = f["toks"][a:b]
            slot[t] = f["slot"]
            pos[t] = f["start"] + a
            ln[t] = b - a
            first[t] = b == n
            plen[t] = f["plen"]
            max_new[t] = f["max_new"]
            fresh[t] = f["next"] - a
            if b == n:
                first_rows[f["slot"]] = t * S
                self._fill.pop(0)
            else:
                f["next"] = b
            t += 1
        sched = {"toks": jnp.asarray(toks), "slot": jnp.asarray(slot),
                 "pos": jnp.asarray(pos), "len": jnp.asarray(ln),
                 "first": jnp.asarray(first), "plen": jnp.asarray(plen),
                 "max_new": jnp.asarray(max_new),
                 "fresh": jnp.asarray(fresh)}
        if self._sh is not None:
            sched = jax.device_put(
                sched, jax.tree_util.tree_map(
                    lambda _: self._sh["repl"], sched))
        return sched, first_rows

    @staticmethod
    def _split_by_patches(vision, only=None):
        """Group request indices by vision patch count (0 == text) so every
        sub-wave stacks to one (K, P, D) shape."""
        groups: dict = {}
        idxs = range(len(vision)) if only is None else only
        for i in idxs:
            groups.setdefault(_vis_patches(vision[i]), []).append(i)
        return [(v, k) for k, v in sorted(groups.items())]

    def _wave_arrays(self, rows, slot_ids, max_news, n_vis=0):
        """Pad a wave to a (pow2 rows, bucketed length) shape; padding rows
        scatter to slot index n_slots -> dropped on device. ``n_vis`` vision
        positions ride ahead of the text, so the text bucket is capped at
        max_len - n_vis (the per-request budget check guarantees every
        prompt in the wave fits under that cap)."""
        K = len(rows)
        lens = [len(r) for r in rows]
        Lb = _bucket_len(self.cfg.prefill_buckets, max(lens),
                         self.cfg.max_len - n_vis)
        Kp = _pad_pow2(K, self.cfg.n_slots)
        toks = np.zeros((Kp, Lb), np.int32)
        for i, r in enumerate(rows):
            toks[i, : len(r)] = r
        len_v = np.asarray(lens + [1] * (Kp - K), np.int32)
        slot_v = np.asarray(list(slot_ids) + [self.cfg.n_slots] * (Kp - K),
                            np.int32)
        mn_v = np.asarray(list(max_news) + [1] * (Kp - K), np.int32)
        return toks, len_v, slot_v, mn_v, K

    def _pad_vis(self, vis, Kp):
        if vis is None:
            return None
        K, P, D = vis.shape
        if Kp > K:
            vis = np.concatenate(
                [vis, np.zeros((Kp - K, P, D), vis.dtype)], axis=0)
        return jnp.asarray(vis)

    def _book_pages(self, slot_ids, need):
        self._free_pages -= sum(need)
        for s, n in zip(slot_ids, need):
            self._slot_pages[s] = n

    def _admit_pool(self, prompts, slot_ids, max_news, vis=None):
        toks, plen_v, slot_v, mn_v, K = self._wave_arrays(
            prompts, slot_ids, max_news,
            n_vis=0 if vis is None else vis.shape[1])
        args = (self._wp, self.cache, self.state, self.key,
                jnp.asarray(toks), jnp.asarray(plen_v), jnp.asarray(slot_v),
                jnp.asarray(mn_v), self._pad_vis(vis, len(slot_v)))
        if self.calib_taps:
            self.cache, self.state, self.key, first, self._calib = \
                self._prefill_jit(*args, self._calib)
        else:
            self.cache, self.state, self.key, first = self._prefill_jit(*args)
        return np.asarray(first)[:K]

    def _admit_paged(self, prompts, slot_ids, max_news, need, vis=None):
        toks, plen_v, slot_v, mn_v, K = self._wave_arrays(
            prompts, slot_ids, max_news,
            n_vis=0 if vis is None else vis.shape[1])
        args = (self._wp, self.cache, self.state, self.pstate, self.key,
                jnp.asarray(toks), jnp.asarray(plen_v), jnp.asarray(slot_v),
                jnp.asarray(mn_v), self._pad_vis(vis, len(slot_v)))
        if self.calib_taps:
            (self.cache, self.state, self.pstate, self.key, first, ok,
             self._calib) = self._prefill_jit(*args, self._calib)
        else:
            self.cache, self.state, self.pstate, self.key, first, ok = \
                self._prefill_jit(*args)
        assert bool(ok), "host free-page mirror out of sync with device"
        self._book_pages(slot_ids, need)
        return np.asarray(first)[:K]

    def _admit_shared(self, prompts, slot_ids, max_news, need,
                      entry: PrefixEntry):
        suffixes = [p[entry.length:] for p in prompts]
        toks, slen_v, slot_v, mn_v, K = self._wave_arrays(
            suffixes, slot_ids, max_news)
        Kp = len(slot_v)
        sh_v = np.asarray([entry.length] * K + [0] * (Kp - K), np.int32)
        args = (self._wp, self.cache, self.state, self.pstate, self.key,
                jnp.asarray(toks), jnp.asarray(slen_v), jnp.asarray(sh_v),
                jnp.asarray(slot_v), jnp.asarray(mn_v),
                jnp.asarray(entry.pages))
        if self.calib_taps:
            (self.cache, self.state, self.pstate, self.key, first, ok,
             self._calib) = self._prefill_shared_jit(*args, self._calib)
        else:
            self.cache, self.state, self.pstate, self.key, first, ok = \
                self._prefill_shared_jit(*args)
        assert bool(ok), "host free-page mirror out of sync with device"
        self._book_pages(slot_ids, need)
        self._lru_clock += 1
        entry.last_used = self._lru_clock
        entry.live += K
        self._slot_prefix[slot_ids] = entry.pid
        self.stats["shared_tokens_saved"] += entry.length * K
        return np.asarray(first)[:K]

    def decode_chunk(self, T: Optional[int] = None, schedule=None):
        """Run T jitted decode steps; returns device (toks, valid) of shape
        (T, n_slots). Pass ``schedule`` (from :meth:`build_schedule`) to
        run the unified chunked-prefill program instead — the same decode
        lane plus the per-step prefill-chunk lane. No host sync happens
        here — harvest() does that."""
        T = T or self.cfg.chunk
        bt = self.pstate.block_tables if self.paged else None
        if schedule is None:
            if self.calib_taps:
                (self.cache, self.state, self.key, toks, valid,
                 self._calib) = self._decode_fn(T)(
                    self._wp, self.cache, self.state, self.key, bt,
                    self._calib)
            else:
                self.cache, self.state, self.key, toks, valid = \
                    self._decode_fn(T)(
                        self._wp, self.cache, self.state, self.key, bt)
        else:
            if self.calib_taps:
                (self.cache, self.state, self.key, toks, valid,
                 self._calib) = self._decode_fn(T, chunked=True)(
                    self._wp, self.cache, self.state, self.key, bt,
                    schedule, self._calib)
            else:
                self.cache, self.state, self.key, toks, valid = \
                    self._decode_fn(T, chunked=True)(
                        self._wp, self.cache, self.state, self.key, bt,
                        schedule)
        return toks, valid

    def harvest(self, toks, valid):
        """THE once-per-chunk host round-trip: chunk tokens + slot flags."""
        jax.block_until_ready(self.state.finished)  # lint: allow(host-sync)
        return (np.asarray(toks), np.asarray(valid),
                np.asarray(self.state.finished), np.asarray(self.state.pos))

    def release(self, slot_ids):
        slot_ids = np.asarray(slot_ids, np.int32)
        self.cache, self.state, self.pstate = self._release_jit(
            self.cache, self.state, self.pstate, jnp.asarray(slot_ids))
        if self.paged:
            self._free_pages += int(self._slot_pages[slot_ids].sum())
            self._slot_pages[slot_ids] = 0
            for s in slot_ids:
                pid = int(self._slot_prefix[s])
                if pid >= 0:
                    self._prefixes[pid].live -= 1
                    self._slot_prefix[s] = -1

    # ------------------------------------------------------------------
    # online calibration (Wanda++ statistics from live traffic)
    # ------------------------------------------------------------------
    def calibration_snapshot(self):
        """Export the accumulated per-linear input statistics as host
        arrays: ``{"stats": {name: {"sumsq"/"abssum"/"sum": (L, In),
        "count": (L,)}}, "xnorm": {name: (L, In)}, "tokens": float}``.
        The per-name stats dicts feed ``core.pruner.apply_prune`` /
        ``reprune_from_stats`` directly (every registered score reads from
        them); ``"xnorm"`` is the derived sqrt(||X||^2) the classic Wanda
        path consumes. ONE device round-trip — call it between chunks like
        :meth:`harvest`, never inside the decode loop. The running stats
        survive :meth:`reset` (they are collected traffic, not slot
        state); :meth:`reset_calibration` zeroes them."""
        if not self.calib_taps:
            raise ValueError("engine built without cfg.calib_taps")
        host = jax.device_get(self._calib)  # lint: allow(host-sync)
        stats = {name: {k: np.asarray(v)  # lint: allow(host-sync)
                        for k, v in d.items()}
                 for name, d in host.items()}
        xnorm = {name: np.sqrt(d["sumsq"]) for name, d in stats.items()}
        tokens = max((float(d["count"].max())  # lint: allow(host-sync)
                      for d in stats.values()),
                     default=0.0)
        return {"stats": stats, "xnorm": xnorm, "tokens": tokens}

    def reset_calibration(self):
        """Zero the running statistics — e.g. right after a re-prune, so
        the next calibration window reflects only post-reprune traffic."""
        if not self.calib_taps:
            raise ValueError("engine built without cfg.calib_taps")
        self._calib = self._init_calib()

    def repack(self, params):
        """Swap re-pruned TARGET weights into the serving engine in place:
        re-run the build-time 2:4 compression over the new dense params
        (same mode / kernel switches as construction) and replace the
        weight tuple. Every cached jitted program takes the weights as
        argument 0 — not a closure — so nothing retraces and
        ``trace_counts`` are untouched. Raises if the packed tree
        structure differs from the serving one (that WOULD retrace)."""
        if self.spec_decode:
            raise ValueError(
                "repack with a drafter is not supported (the draft/target "
                "pair must be re-pruned and rebuilt together)")
        mode = self.cfg.compressed24 if self.cfg.compressed24 is not None \
            else "auto"
        # an engine that packed nothing at build serves the dense tree; its
        # cached programs expect dense leaves, so newly-2:4 weights must
        # stay dense here even under "auto"
        if mode != "off" and self.compressed24:
            from repro.models.blocks import compress_params24
            params, n24 = compress_params24(
                self.model.cfg, params,
                keep_dense=not self.compressed24_kernel,
                masked=(mode == "masked"))
            if self.compressed24 and n24 != self.compressed24:
                raise ValueError(
                    f"repack found {n24} 2:4-sparse projections; the engine "
                    f"serves {self.compressed24} — a re-prune must preserve "
                    "which projections carry the 2:4 pattern")
        if jax.tree_util.tree_structure((params,)) != \
                jax.tree_util.tree_structure((self.params,)):
            raise ValueError(
                "repacked params change the weight tree structure "
                "(every cached program would retrace)")
        wp = (params,)
        if self._sh is not None:
            wp = jax.device_put(wp, self._sh["params"])
        self._wp = wp
        self.params = wp[0]

    # ------------------------------------------------------------------
    # one-wave convenience: same-shape batch, single decode program
    # ------------------------------------------------------------------
    def generate(self, prompts, max_new: int, vision=None):
        """Generate ``max_new`` tokens for a batch of equal-length prompts.

        One prefill + ONE jitted scan over the remaining max_new - 1 steps:
        a full generation costs exactly two device syncs (first-token and
        final harvest) regardless of max_new. With ``eos_id`` set, rows are
        truncated at their EOS: frozen slots re-feed their last token on
        device, and those repeats are masked out of the returned (B, T)
        array (padded with ``eos_id``) instead of leaking to the caller.
        ``vision``: optional (B, P, d_model) vision-embed batch (VLM).
        """
        prompts = np.asarray(prompts, np.int32)
        B = prompts.shape[0]
        if B > self.cfg.n_slots:
            raise ValueError(f"batch {B} > n_slots={self.cfg.n_slots}")
        self.reset()
        first = self.admit_wave(list(prompts), list(range(B)),
                                [max_new] * B,
                                vision=None if vision is None
                                else list(np.asarray(vision)))
        if max_new <= 1:
            return first[:, None]
        if self.spec_decode:
            return self._generate_spec(first, B, max_new)
        toks, valid = self.decode_chunk(max_new - 1)
        t, v, _, _ = self.harvest(toks, valid)
        t, v = t[:, :B].T, v[:, :B].T  # (B, max_new-1)
        if self.cfg.eos_id is None:
            assert v.all(), "same-shape wave must stay active to the end"
        else:
            t = np.where(v, t, self.cfg.eos_id)
        return np.concatenate([first[:, None], t], axis=1)

    def _generate_spec(self, first, B: int, max_new: int):
        """Speculative one-wave drive: a macro step emits 1..k+1 tokens per
        slot, so slots finish at different chunk counts — loop decode
        chunks until every slot is done, then compact each slot's valid
        rows in stream order (harvest's contract). Without eos_id every
        slot yields exactly max_new - 1 decode tokens; with it, rows past a
        slot's EOS are padded with eos_id like the target-only path."""
        need = max_new - 1
        rows_t, rows_v = [], []
        while True:
            toks, valid = self.decode_chunk(min(self.cfg.chunk, need))
            t, v, fin, _ = self.harvest(toks, valid)
            rows_t.append(t[:, :B])
            rows_v.append(v[:, :B])
            if fin[:B].all():
                break
        t = np.concatenate(rows_t, axis=0)
        v = np.concatenate(rows_v, axis=0)
        pad = self.cfg.eos_id if self.cfg.eos_id is not None else 0
        out = np.full((B, need), pad, np.int32)
        for b in range(B):
            seq = t[v[:, b], b][:need]
            if self.cfg.eos_id is None:
                assert len(seq) == need, \
                    "spec wave must emit every budgeted token"
            out[b, : len(seq)] = seq
        return np.concatenate([first[:, None], out], axis=1)


def generate(model: Model, params, prompts, max_new: int,
             sampling: SamplingConfig = SamplingConfig(),
             eos_id: Optional[int] = None, max_len: Optional[int] = None):
    """Functional one-shot wrapper: build an Engine sized to the batch."""
    prompts = np.asarray(prompts, np.int32)
    B, P = prompts.shape
    cfg = EngineConfig(n_slots=B, max_len=max_len or (P + max_new),
                       chunk=max(max_new - 1, 1), eos_id=eos_id,
                       prefill_buckets=(P,))
    eng = Engine(model, params, cfg, sampling)
    return eng.generate(prompts, max_new)
