"""Continuous-batching inference engine: jitted prefill + scan decode.

The decode hot loop is ONE jitted program per chunk length: ``lax.scan``
over T steps of [batched decode_step -> sample -> finish-flag update], all
on device. The host syncs once per chunk (to harvest tokens and refill
freed slots), never per token — TPOT measures the hardware, not Python
dispatch, which is the whole point of the Wanda++ 2:4 deployment story
(Table 7: decode is weight-bandwidth-bound, sparsity halves the traffic).

Prefill runs as a separate jitted program per (wave, bucket-length) shape;
waves are padded to power-of-two sizes and prompt lengths to configured
buckets so trace counts stay O(#buckets), not O(#requests).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import KV_QSCALE
from repro.models.model import Model
from repro.serve import slots as SLOT
from repro.serve.sampling import SamplingConfig, sample_tokens
from repro.serve.slots import SlotState, init_slots


@dataclass(frozen=True)
class EngineConfig:
    n_slots: int = 8  # KV-cache pool size == max concurrent requests
    max_len: int = 128  # cache length per slot
    chunk: int = 16  # decode steps per host round-trip
    eos_id: Optional[int] = None  # None => length-only termination
    prefill_buckets: Tuple[int, ...] = (16, 32, 64, 128)


def _bucket_len(buckets: Sequence[int], plen: int, max_len: int) -> int:
    for b in sorted(buckets):
        if b >= plen and b <= max_len:
            return b
    if plen <= max_len:
        return max_len
    raise ValueError(f"prompt of {plen} tokens exceeds max_len={max_len}")


def _pad_pow2(n: int, cap: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return min(p, cap)


class Engine:
    """Slot-batched serving over a fixed KV-cache pool.

    Drive it either with :meth:`generate` (one same-shape wave, single
    decode program, single device sync — the benchmark/test path) or with
    ``scheduler.Scheduler`` (continuous batching: admit-on-free interleaved
    with chunked decode).
    """

    def __init__(self, model: Model, params, cfg: EngineConfig = EngineConfig(),
                 sampling: SamplingConfig = SamplingConfig()):
        mcfg = model.cfg
        if mcfg.is_encoder_only:
            raise ValueError(
                f"{mcfg.name}: encoder-only arch has no decode path")
        if mcfg.family in ("ssm", "hybrid"):
            raise NotImplementedError(
                f"{mcfg.name}: slot management for SSM/conv state caches is a "
                "follow-up; the engine serves dense/moe families today")
        if mcfg.family == "vlm":
            # note: the seed CLI crashed on vlm too (its prompts carry no
            # vision_embeds) — this is a missing feature, not a regression
            raise NotImplementedError(
                f"{mcfg.name}: vlm serving needs vision-embed plumbing in "
                "requests (text-only prompts cannot feed the vision prefix)")
        if mcfg.family not in ("dense", "moe"):
            raise NotImplementedError(
                f"{mcfg.name}: family {mcfg.family!r} is not servable "
                "(dense/moe supported)")
        self.model = model
        self.params = params
        self.cfg = cfg
        self.sampling = sampling
        self.key = jax.random.PRNGKey(sampling.seed)
        self.state: SlotState = init_slots(cfg.n_slots)
        self.cache = model.init_cache(cfg.n_slots, cfg.max_len)
        # trace counters: the no-retrace-per-token guarantee is testable
        self.trace_counts = {"decode": 0, "prefill": 0}
        self._decode_jit = {}  # chunk length T -> compiled program
        self._prefill_jit = jax.jit(self._prefill_impl, donate_argnums=(1, 2, 3))

    # ------------------------------------------------------------------
    # jitted programs
    # ------------------------------------------------------------------
    def _decode_impl(self, params, cache, state, key, *, T):
        self.trace_counts["decode"] += 1
        sc, eos = self.sampling, self.cfg.eos_id

        def step(carry, _):
            cache, state, key = carry
            key, sub = jax.random.split(key)
            run = state.active & ~state.finished
            logits, cache = self.model.decode_step(
                params, {"token": state.last_token, "pos": state.pos}, cache)
            nxt = sample_tokens(logits, sub, sc)
            # frozen slots keep re-feeding their last token at a fixed pos;
            # the cache write lands on a position admission will overwrite
            nxt = jnp.where(run, nxt, state.last_token)
            pos = state.pos + run.astype(jnp.int32)
            done = pos >= state.max_total
            if eos is not None:
                done = done | (nxt == eos)
            state = state._replace(last_token=nxt, pos=pos,
                                   finished=state.finished | (run & done))
            return (cache, state, key), (nxt, run)

        (cache, state, key), (toks, valid) = jax.lax.scan(
            step, (cache, state, key), None, length=T)
        return cache, state, key, toks, valid  # toks/valid: (T, n_slots)

    def _prefill_impl(self, params, cache, state, key, tokens, plens, slots,
                      max_news):
        """One admission wave: forward the (padded) prompts, sample each
        request's first token, scatter KV + slot metadata into the pool."""
        self.trace_counts["prefill"] += 1
        logits, _, kvs = self.model.forward(params, {"tokens": tokens},
                                            return_cache=True)
        last = jnp.take_along_axis(
            logits, jnp.maximum(plens - 1, 0)[:, None, None], axis=1)[:, 0]
        key, sub = jax.random.split(key)
        first = sample_tokens(last, sub, self.sampling)

        ck, cv = cache
        k_s, v_s = kvs  # (L, K, Lb, KV, hd)
        if ck.dtype == jnp.int8:
            k_s = jnp.clip(jnp.round(k_s.astype(jnp.float32) * KV_QSCALE),
                           -127, 127)
            v_s = jnp.clip(jnp.round(v_s.astype(jnp.float32) * KV_QSCALE),
                           -127, 127)
        Lb = tokens.shape[1]
        ck = ck.at[:, slots, :Lb].set(k_s.astype(ck.dtype), mode="drop")
        cv = cv.at[:, slots, :Lb].set(v_s.astype(cv.dtype), mode="drop")

        max_total = plens + jnp.maximum(max_news, 1) - 1
        state = SLOT.admit(state, slots, first, plens, max_total)
        done0 = max_total <= plens  # max_new == 1: the prefill token is it
        if self.cfg.eos_id is not None:
            done0 = done0 | (first == self.cfg.eos_id)
        state = state._replace(
            finished=state.finished.at[slots].set(done0, mode="drop"))
        return (ck, cv), state, key, first

    def _decode_fn(self, T: int):
        if T not in self._decode_jit:
            self._decode_jit[T] = jax.jit(
                functools.partial(self._decode_impl, T=T),
                donate_argnums=(1, 2, 3))
        return self._decode_jit[T]

    # ------------------------------------------------------------------
    # host-side driver ops (used by scheduler.Scheduler and generate())
    # ------------------------------------------------------------------
    def reset(self):
        self.state = init_slots(self.cfg.n_slots)
        self.cache = self.model.init_cache(self.cfg.n_slots, self.cfg.max_len)
        self.key = jax.random.PRNGKey(self.sampling.seed)

    def admit_wave(self, prompts, slot_ids, max_news):
        """Prefill `prompts` (list of 1-D int arrays, same bucket length
        after padding) into `slot_ids`. Returns each request's first
        generated token as a (K,) numpy array (this is the TTFT sync)."""
        assert len(prompts) == len(slot_ids) == len(max_news)
        K = len(prompts)
        plens = [len(p) for p in prompts]
        Lb = _bucket_len(self.cfg.prefill_buckets, max(plens), self.cfg.max_len)
        for p, mn in zip(plens, max_news):
            if p + max(mn, 1) - 1 > self.cfg.max_len:
                raise ValueError(
                    f"request needs {p + mn - 1} cache slots > "
                    f"max_len={self.cfg.max_len}")
        Kp = _pad_pow2(K, self.cfg.n_slots)
        toks = np.zeros((Kp, Lb), np.int32)
        for i, p in enumerate(prompts):
            toks[i, : len(p)] = np.asarray(p, np.int32)
        plen_v = np.asarray(plens + [1] * (Kp - K), np.int32)
        # padding rows scatter to slot index n_slots -> dropped on device
        slot_v = np.asarray(list(slot_ids) + [self.cfg.n_slots] * (Kp - K),
                            np.int32)
        mn_v = np.asarray(list(max_news) + [1] * (Kp - K), np.int32)
        self.cache, self.state, self.key, first = self._prefill_jit(
            self.params, self.cache, self.state, self.key,
            jnp.asarray(toks), jnp.asarray(plen_v), jnp.asarray(slot_v),
            jnp.asarray(mn_v))
        return np.asarray(first)[:K]

    def decode_chunk(self, T: Optional[int] = None):
        """Run T jitted decode steps; returns device (toks, valid) of shape
        (T, n_slots). No host sync happens here — harvest() does that."""
        T = T or self.cfg.chunk
        self.cache, self.state, self.key, toks, valid = self._decode_fn(T)(
            self.params, self.cache, self.state, self.key)
        return toks, valid

    def harvest(self, toks, valid):
        """THE once-per-chunk host round-trip: chunk tokens + slot flags."""
        jax.block_until_ready(self.state.finished)
        return (np.asarray(toks), np.asarray(valid),
                np.asarray(self.state.finished), np.asarray(self.state.pos))

    def release(self, slot_ids):
        self.state = SLOT.release(
            self.state, jnp.asarray(np.asarray(slot_ids, np.int32)))

    # ------------------------------------------------------------------
    # one-wave convenience: same-shape batch, single decode program
    # ------------------------------------------------------------------
    def generate(self, prompts, max_new: int):
        """Generate ``max_new`` tokens for a batch of equal-length prompts.

        One prefill + ONE jitted scan over the remaining max_new - 1 steps:
        a full generation costs exactly two device syncs (first-token and
        final harvest) regardless of max_new.
        """
        prompts = np.asarray(prompts, np.int32)
        B = prompts.shape[0]
        if B > self.cfg.n_slots:
            raise ValueError(f"batch {B} > n_slots={self.cfg.n_slots}")
        self.reset()
        first = self.admit_wave(list(prompts), list(range(B)),
                                [max_new] * B)
        if max_new > 1:
            toks, valid = self.decode_chunk(max_new - 1)
            t, v, _, _ = self.harvest(toks, valid)
            t = t[:, :B].T  # (B, max_new-1)
            if self.cfg.eos_id is None:
                assert v[:, :B].T.all(), \
                    "same-shape wave must stay active to the end"
            return np.concatenate([first[:, None], t], axis=1)
        return first[:, None]


def generate(model: Model, params, prompts, max_new: int,
             sampling: SamplingConfig = SamplingConfig(),
             eos_id: Optional[int] = None, max_len: Optional[int] = None):
    """Functional one-shot wrapper: build an Engine sized to the batch."""
    prompts = np.asarray(prompts, np.int32)
    B, P = prompts.shape
    cfg = EngineConfig(n_slots=B, max_len=max_len or (P + max_new),
                       chunk=max(max_new - 1, 1), eos_id=eos_id,
                       prefill_buckets=(P,))
    eng = Engine(model, params, cfg, sampling)
    return eng.generate(prompts, max_new)
