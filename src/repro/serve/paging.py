"""Paged KV-cache pool: block tables + a jit-compatible page allocator.

The engine's KV arena is (L, n_pages, page_size, KV, hd); each slot owns a
row of ``block_tables`` — (max_blocks,) int32 page indices, position-ordered,
with ``n_pages`` marking an unmapped block (out-of-range, so scatters drop
and gathers are masked). ``ref`` counts live mappings per page: 0 == free,
>1 == shared (a registered prompt prefix mapped into several slots, plus a
permanent hold from :meth:`Engine.register_prefix`).

Everything here is pure and shape-static so admission/release stay inside
the engine's jitted programs: the "free list" is materialised on the fly as
a rank->page permutation of the pages with ``ref == 0`` (lowest index
first, so allocation order is deterministic and the host can mirror the
free count exactly).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class PageState(NamedTuple):
    ref: jnp.ndarray  # (n_pages,) int32 — live mappings; 0 == free
    block_tables: jnp.ndarray  # (n_slots, max_blocks) int32; n_pages == unmapped


def init_pages(n_pages: int, n_slots: int, max_blocks: int) -> PageState:
    return PageState(
        ref=jnp.zeros((n_pages,), jnp.int32),
        block_tables=jnp.full((n_slots, max_blocks), n_pages, jnp.int32),
    )


def _free_by_rank(ref: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(rank -> page-index permutation of the free pages, free count)."""
    P = ref.shape[0]
    free = ref == 0
    rank = jnp.cumsum(free.astype(jnp.int32)) - 1  # (P,) rank of each free page
    by_rank = jnp.full((P,), P, jnp.int32).at[
        jnp.where(free, rank, P)
    ].set(jnp.arange(P, dtype=jnp.int32), mode="drop")
    return by_rank, free.sum()


def alloc(state: PageState, slots: jnp.ndarray, n_blocks: jnp.ndarray,
          n_shared: Optional[jnp.ndarray] = None,
          shared_pages: Optional[jnp.ndarray] = None):
    """Map pages for a wave of K freshly-admitted slots.

    slots: (K,) int32 target slots; rows with ``slot == n_slots`` are wave
      padding and allocate nothing.
    n_blocks: (K,) int32 total blocks each request needs (shared included).
    shared_pages: (SB,) int32 pages of the registered shared prefix, mapped
      read-only (refcounted) at blocks [0, n_shared[i]); None => no sharing.
    n_shared: (K,) int32 leading shared blocks per row (0 => fresh request).

    Returns ``(new_state, ok)``. ``ok`` is a scalar bool; when False (free
    list exhausted) the state comes back UNCHANGED so the caller can requeue
    the wave — no partial allocation ever lands.
    """
    P = state.ref.shape[0]
    S, MB = state.block_tables.shape
    K = slots.shape[0]
    if n_shared is None:
        n_shared = jnp.zeros((K,), jnp.int32)
    blk = jnp.arange(MB, dtype=jnp.int32)[None, :]
    valid = (slots < S)[:, None]
    is_shared = valid & (blk < n_shared[:, None])
    need_new = valid & (blk >= n_shared[:, None]) & (blk < n_blocks[:, None])

    by_rank, n_free = _free_by_rank(state.ref)
    ok = need_new.sum() <= n_free
    # the j-th needed (row-major) block gets the j-th free page
    want = jnp.cumsum(need_new.reshape(-1).astype(jnp.int32)) - 1
    new_pages = jnp.where(
        need_new.reshape(-1),
        by_rank.at[want].get(mode="fill", fill_value=P),
        P,
    ).reshape(K, MB)

    if shared_pages is None or shared_pages.shape[0] == 0:
        shared_rows = jnp.full((K, MB), P, jnp.int32)
    else:
        SB = shared_pages.shape[0]
        shared_rows = jnp.full((K, MB), P, jnp.int32).at[:, :SB].set(
            jnp.broadcast_to(shared_pages.astype(jnp.int32), (K, SB)))
    rows = jnp.where(is_shared, shared_rows, new_pages)  # (K, MB)

    ref = state.ref.at[rows.reshape(-1)].add(
        (is_shared | need_new).reshape(-1).astype(jnp.int32), mode="drop")
    tables = state.block_tables.at[slots].set(rows, mode="drop")
    new = PageState(ref=ref, block_tables=tables)
    state = jax.tree_util.tree_map(lambda a, b: jnp.where(ok, a, b), new, state)
    return state, ok


def release(state: PageState, slots: jnp.ndarray) -> PageState:
    """Unmap released slots; their pages return to the free list in the same
    scatter that clears the tables. Refcounted (shared-prefix) pages survive
    until the last mapping — including the registry's permanent hold — drops.

    Invariants (the allocator runs inside jitted programs, so misuse cannot
    raise on device — it is *defined away* here and caught on host by
    :func:`check_invariants`):

    * releasing an already-released slot is a no-op: its table rows were
      cleared to the out-of-range sentinel, so the decrement scatter drops —
      a double release can never push a page's refcount below its true
      mapping count;
    * refcounts are floored at 0, so even a forged slots array cannot drive
      ``ref`` negative and later resurrect a live page through the
      ``ref == 0`` free-list scan.
    """
    P = state.ref.shape[0]
    rows = state.block_tables.at[slots].get(mode="fill", fill_value=P)
    flat = rows.reshape(-1)
    ref = state.ref.at[flat].add(-jnp.ones_like(flat), mode="drop")
    tables = state.block_tables.at[slots].set(P, mode="drop")
    return PageState(ref=jnp.maximum(ref, 0), block_tables=tables)


def unreserve(state: PageState, pages: jnp.ndarray) -> PageState:
    """Drop the registry's permanent hold on ``pages`` (prefix eviction —
    the inverse of :func:`reserve`). The caller must ensure no live slot
    still maps them (the engine tracks per-prefix live counts on host and
    only evicts at live == 0): unreserving a page a slot still maps leaves
    ``ref > 0`` so the page is NOT handed out again, but the registry's
    bookkeeping is then out of sync — :func:`check_invariants` flags it.
    Refcounts are floored at 0 so a double unreserve cannot corrupt the
    free list."""
    ref = state.ref.at[pages].add(-1, mode="drop")
    return PageState(ref=jnp.maximum(ref, 0),
                     block_tables=state.block_tables)


def reserve(state: PageState, n: int):
    """Take the first ``n`` free pages with a +1 ref that no slot owns (the
    shared-prefix registry's permanent hold). ``n`` is static. Returns
    ``(state, pages (n,), ok)``; state unchanged when ok is False."""
    P = state.ref.shape[0]
    by_rank, n_free = _free_by_rank(state.ref)
    ok = n <= n_free
    pages = by_rank.at[jnp.arange(n, dtype=jnp.int32)].get(
        mode="fill", fill_value=P)
    ref = state.ref.at[pages].add(1, mode="drop")
    new = PageState(ref=ref, block_tables=state.block_tables)
    state = jax.tree_util.tree_map(lambda a, b: jnp.where(ok, a, b), new, state)
    return state, pages, ok


def check_invariants(state: PageState, shared_pages=(), reserved=0) -> None:
    """Host-side sanity checks (tests only).

    * no page is mapped by two live slots unless it is a shared-prefix page
    * a slot never maps the same page twice
    * ref[page] == #mappings (+1 permanent hold for each registered page)
    * free pages (ref == 0) are mapped nowhere
    """
    import numpy as np

    ref = np.asarray(state.ref)
    bt = np.asarray(state.block_tables)
    P = ref.shape[0]
    assert (ref >= 0).all(), "negative refcount"
    counts = np.zeros(P, np.int64)
    for s in range(bt.shape[0]):
        mapped = bt[s][bt[s] < P]
        assert len(set(mapped.tolist())) == len(mapped), \
            f"slot {s} maps a page twice"
        np.add.at(counts, mapped, 1)
    shared = {int(p) for p in np.asarray(shared_pages).reshape(-1)}
    for p in range(P):
        hold = 1 if p in shared else 0
        assert ref[p] == counts[p] + hold, \
            f"page {p}: ref {ref[p]} != {counts[p]} mappings + {hold} hold"
        if counts[p] > 1:
            assert p in shared, \
                f"page {p} mapped by {counts[p]} slots but not shared"
        if ref[p] == 0:
            assert counts[p] == 0
    assert int((ref > 0).sum()) >= reserved
