"""On-device token sampling for the serving engine.

``SamplingConfig`` is a frozen (hashable) dataclass so it can close over the
jitted decode program as a static value — greedy vs temperature vs top-k
select different traced graphs, never a per-token host branch.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class SamplingConfig:
    temperature: float = 0.0  # 0 => greedy argmax
    top_k: int = 0  # 0 => sample the full softmax
    seed: int = 0  # PRNG seed for the engine's sampling stream

    @property
    def greedy(self) -> bool:
        return self.temperature <= 0.0


def sample_tokens(logits, key, sc: SamplingConfig):
    """logits (B, V) -> sampled token ids (B,) int32. Pure and jit-safe;
    ``sc`` must be static at trace time."""
    if sc.greedy:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits.astype(jnp.float32) / sc.temperature
    if sc.top_k > 0:
        # keep EXACTLY top_k candidates: comparing against the k-th value
        # (`logits < kth`) would keep every logit tied with it, silently
        # inflating k. lax.top_k breaks ties by lowest index, so masking by
        # its returned indices is deterministic.
        _, idx = jax.lax.top_k(logits, sc.top_k)
        keep = jnp.zeros(logits.shape, bool).at[
            jnp.arange(logits.shape[0])[:, None], idx].set(True)
        logits = jnp.where(keep, logits, -jnp.inf)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)
