"""On-device token sampling for the serving engine.

``SamplingConfig`` is a frozen (hashable) dataclass so it can close over the
jitted decode program as a static value — greedy vs temperature vs top-k vs
top-p select different traced graphs, never a per-token host branch.

Draws are keyed **per slot** (:func:`slot_keys`): the chunk key is folded
with each row's index, so a slot's stream depends only on (seed, step,
slot) — not on the batch width a wave was padded to, and not on how a
serving mesh lays the batch out. The mesh parity suite
(tests/test_serve_distributed.py) pins sampled decode bit-exact between the
single-device and sharded engines on the strength of this.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class SamplingConfig:
    temperature: float = 0.0  # 0 => greedy argmax
    top_k: int = 0  # 0 => no top-k truncation
    # nucleus mass in (0, 1]; >= 1 => no top-p truncation. 0 is rejected
    # rather than read as "disabled": small values degenerate toward top-1,
    # so a silent flip to full-softmax at exactly 0 would invert intent.
    top_p: float = 1.0
    seed: int = 0  # PRNG seed for the engine's sampling stream

    def __post_init__(self):
        if self.top_p <= 0.0:
            raise ValueError(
                f"top_p={self.top_p} must be > 0 (use 1.0 to disable; "
                "values near 0 approach greedy)")

    @property
    def greedy(self) -> bool:
        return self.temperature <= 0.0


def _nucleus_mask(logits, top_p: float):
    """Keep the SMALLEST prefix of the probability-sorted vocab whose mass
    reaches ``top_p`` — i.e. a token survives iff the mass strictly before
    it is < top_p. Same exact-ties discipline as top-k: ``jnp.argsort`` is
    stable, so tied logits at the nucleus boundary are kept lowest-index
    first, never all-or-none (which would silently inflate the nucleus)."""
    order = jnp.argsort(-logits, axis=-1)  # descending, ties by lowest index
    svals = jnp.take_along_axis(logits, order, axis=-1)
    probs = jax.nn.softmax(svals, axis=-1)
    before = jnp.cumsum(probs, axis=-1) - probs  # exclusive prefix mass
    keep_sorted = before < top_p  # always keeps the top-1 token
    return jnp.zeros(logits.shape, bool).at[
        jnp.arange(logits.shape[0])[:, None], order].set(keep_sorted)


def slot_keys(key, n: int):
    """One PRNG key per slot row: ``fold_in(key, row)``. The fold is PINNED
    to the row index, so a row's draw depends only on (key, row) — never on
    the batch width (wave padding rows cannot shift live rows' streams) and
    never on how a mesh lays the batch out across devices. This is what
    makes sampled decode bit-reproducible between the single-device engine
    and a `(data, model)`-sharded one."""
    return jax.vmap(jax.random.fold_in, in_axes=(None, 0))(
        key, jnp.arange(n, dtype=jnp.uint32))


def process_logits(logits, sc: SamplingConfig):
    """The sampling transform minus the draw: (B, V) raw logits ->
    temperature-scaled f32 logits with the top-k, then top-p survivors kept
    and everything else at -inf. ``sample_tokens`` draws categorically from
    this; speculative decoding's exact rejection sampling computes both the
    target and drafter *processed* distributions (``processed_probs``)
    through the SAME transform — that identity is what makes acceptance
    probability p_t/p_d exact, so spec decode with draft == target accepts
    every proposal. Non-greedy configs only."""
    if sc.greedy:
        raise ValueError("process_logits is the stochastic path; greedy "
                         "sampling is argmax and has no distribution")
    logits = logits.astype(jnp.float32) / sc.temperature
    if sc.top_k > 0:
        # keep EXACTLY top_k candidates: comparing against the k-th value
        # (`logits < kth`) would keep every logit tied with it, silently
        # inflating k. lax.top_k breaks ties by lowest index, so masking by
        # its returned indices is deterministic.
        _, idx = jax.lax.top_k(logits, sc.top_k)
        keep = jnp.zeros(logits.shape, bool).at[
            jnp.arange(logits.shape[0])[:, None], idx].set(True)
        logits = jnp.where(keep, logits, -jnp.inf)
    if sc.top_p < 1.0:  # __post_init__ guarantees top_p > 0
        logits = jnp.where(_nucleus_mask(logits, sc.top_p), logits, -jnp.inf)
    return logits


def processed_probs(logits, sc: SamplingConfig):
    """(B, V) raw logits -> the exact probability distribution
    ``sample_tokens`` draws from (f32, masked tokens at exactly 0)."""
    return jax.nn.softmax(process_logits(logits, sc), axis=-1)


def sample_tokens(logits, key, sc: SamplingConfig):
    """logits (B, V) -> sampled token ids (B,) int32. Pure and jit-safe;
    ``sc`` must be static at trace time. top-k truncation applies first,
    then top-p renormalizes over the survivors (the usual composition).
    Each row draws from its own :func:`slot_keys` key (see there for why)."""
    if sc.greedy:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = process_logits(logits, sc)
    return jax.vmap(
        lambda k, l: jax.random.categorical(k, l, axis=-1)
    )(slot_keys(key, logits.shape[0]), logits).astype(jnp.int32)
