"""Request scheduler: admit-on-free continuous batching over an Engine.

Loop shape (one iteration == one host round-trip):

    1. harvest the last decode chunk -> per-slot tokens + finished flags
    2. release finished slots, emit Completions
    3. admit queued requests into free slots, one prefill wave per
       length bucket (so a long prompt never pads a short one)
    4. launch the next jitted decode chunk

Prefill interleaves with decode at chunk granularity: while a chunk is a
single device program, admission happens between chunks, exactly like the
iteration-level scheduling of Orca/vLLM-style engines.
"""
from __future__ import annotations

import math
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.serve.engine import Engine, PagesExhausted, _bucket_len


def percentile(xs, p: float) -> float:
    """Nearest-rank percentile (p in [0, 1]): the smallest value with at
    least p*n samples <= it, i.e. rank ceil(p*n) (1-based). 0.0 on empty
    input. Shared by the serve CLI and benchmarks so their p50/p95 always
    agree. (int(len(xs)*p) would be off by one: p95 of 20 samples must be
    the 19th value, not the max.)"""
    xs = sorted(xs)
    if not xs:
        return 0.0
    # the 1e-9 nudge keeps float products like 0.07 * 100 == 7.000...001
    # from overshooting the true integer rank by one ulp
    rank = math.ceil(p * len(xs) - 1e-9)
    return xs[min(max(rank - 1, 0), len(xs) - 1)]


@dataclass
class Request:
    rid: int
    tokens: np.ndarray  # (P,) int32 prompt
    max_new: int = 16
    # VLM: precomputed vision-patch embeddings (n_patches, d_model) that
    # prefill feeds ahead of the text tokens (they occupy the request's
    # first cache positions). None for text-only requests; REQUIRED when
    # the engine serves a vision-frontend model.
    vision_embeds: Optional[np.ndarray] = None

    @property
    def n_vis(self) -> int:
        return 0 if self.vision_embeds is None else \
            int(self.vision_embeds.shape[0])


@dataclass
class Completion:
    rid: int
    prompt_len: int
    tokens: np.ndarray  # (n_generated,) int32, includes the prefill token
    ttft_s: float  # submit -> first token
    tpot_s: List[float] = field(default_factory=list)  # per decoded token
    # submit -> admission (slot granted; first chunk queued / wave begun).
    # ttft_s - admit_s is the prefill-path latency — admission of the
    # request's first chunk to its first emitted token — the number
    # chunked prefill attacks, with slot-capacity queueing factored out.
    admit_s: float = 0.0
    # forward rows the engine computed between this request's admission and
    # its first token — the deterministic, host-independent counterpart of
    # ttft_s - admit_s. Waved: the request's own wave charges every member
    # its full bucket-padded prefill (wave_size * padded_len). Chunked: the
    # unified steps from admission through the first-token step, each
    # costing its traced shape (chunk_size lane rows + n_slots decode
    # rows), whether lanes are live or not.
    ttft_rows: int = 0


class Scheduler:
    """Drives an Engine over an arbitrary request stream."""

    def __init__(self, engine: Engine):
        self.engine = engine
        n = engine.cfg.n_slots
        self._slot_rid: List[Optional[int]] = [None] * n
        self.peak_live = 0  # max concurrently-live slots seen during run()
        # total forward rows the run's traced programs computed (prefill
        # waves at their padded shapes + every step's full decode/lane
        # width) — tokens-emitted / rows_computed is the padding-waste
        # metric benchmark section 11 gates on
        self.rows_computed = 0

    def run(self, requests: List[Request], progress=None) -> List[Completion]:
        if self.engine.chunked_prefill:
            return self._run_chunked(requests, progress)
        return self._run_waved(requests, progress)

    def _run_waved(self, requests: List[Request],
                   progress=None) -> List[Completion]:
        """Bucket-wave admission: prefill runs as separate jitted waves
        between decode chunks. The serving path for families the chunk
        lane cannot fill (recurrent/hybrid snapshot placement, vision
        prefixes) and the parity baseline chunked prefill is pinned
        against."""
        eng = self.engine
        eng.reset()
        self.peak_live = 0  # per-run metric; a Scheduler may be reused
        self.rows_computed = 0
        queue = deque(requests)
        t_submit = {r.rid: time.perf_counter() for r in requests}
        partial: Dict[int, List[int]] = {}
        ttft: Dict[int, float] = {}
        tpot: Dict[int, List[float]] = {}
        admit: Dict[int, float] = {}
        trows: Dict[int, int] = {}
        req_of = {r.rid: r for r in requests}
        done: List[Completion] = []

        self._slot_rid = [None] * eng.cfg.n_slots
        pending_chunk = None

        while queue or any(r is not None for r in self._slot_rid):
            # -- 1+2: harvest the in-flight chunk, free finished slots ------
            if pending_chunk is not None:
                toks, valid, t_launch = pending_chunk
                t_np, v_np, fin, _pos = eng.harvest(toks, valid)
                chunk_dt = time.perf_counter() - t_launch  # dispatch+compute
                T = t_np.shape[0]
                # the decode program computes every slot lane each step,
                # live or not (T emitted rows = steps * draft span)
                self.rows_computed += T * eng.cfg.n_slots
                freed = []
                for s, rid in enumerate(self._slot_rid):
                    if rid is None:
                        continue
                    new = t_np[v_np[:, s], s]
                    partial[rid].extend(int(t) for t in new)
                    if eng.spec_decode:
                        # a spec chunk's row count is inflated by rejected
                        # proposals; per-token latency is the chunk time
                        # over the tokens this slot actually got
                        tpot[rid].extend([chunk_dt / max(len(new), 1)]
                                         * len(new))
                    else:
                        tpot[rid].extend([chunk_dt / T] * len(new))
                    if fin[s]:
                        done.append(Completion(
                            rid, len(req_of[rid].tokens),
                            np.asarray(partial.pop(rid), np.int32),
                            ttft.pop(rid), tpot.pop(rid),
                            admit_s=admit.pop(rid),
                            ttft_rows=trows.pop(rid)))
                        self._slot_rid[s] = None
                        freed.append(s)
                        if progress:
                            progress(done[-1])
                if freed:
                    eng.release(freed)
                pending_chunk = None

            # -- 3: admission, one wave per prompt-length bucket ------------
            free = [s for s, r in enumerate(self._slot_rid) if r is None]
            if free and queue:
                # take requests while slots AND KV pages last; the budget
                # counts idle shared prefixes as reclaimable (the engine
                # evicts them LRU-first inside admit_wave) — EXCEPT the
                # prefixes the taken requests themselves map, which
                # admission refuses to evict. A request that doesn't fit
                # stays queued and is retried after the next harvest frees
                # pages (admission never partially lands — see
                # Engine.admit_wave / PagesExhausted)
                take: List[Request] = []
                taken_need = 0
                matched: set = set()
                match_of: Dict[int, object] = {}  # rid -> PrefixEntry|None
                while queue and len(take) < len(free):
                    r0 = queue[0]
                    if eng.paged:
                        # vision requests never map token prefixes (their
                        # vision prefix occupies the leading cache positions)
                        ent = None if r0.vision_embeds is not None else \
                            eng.prefix_match(np.asarray(r0.tokens))
                        need = eng.pages_needed(r0.tokens, r0.max_new,
                                                match=ent, n_vis=r0.n_vis)
                        new_matched = matched | (
                            {ent.pid} if ent is not None else set())
                        budget = eng.free_pages + \
                            eng.evictable_pages(exclude=new_matched)
                        if taken_need + need > budget:
                            if not take and \
                                    all(r is None for r in self._slot_rid):
                                raise ValueError(
                                    f"request {r0.rid} needs {need} KV pages"
                                    f" > pool capacity {budget}; it can "
                                    "never be admitted")
                            break
                        taken_need += need
                        matched = new_matched
                        match_of[r0.rid] = ent
                    take.append(queue.popleft())
                waves: Dict[tuple, List[Request]] = {}
                for r in take:
                    # bucket by padded text length AND patch count so each
                    # wave prefills one traced shape (the engine re-splits
                    # mixed patch counts, but pre-grouping keeps waves full)
                    b = _bucket_len(eng.cfg.prefill_buckets, len(r.tokens),
                                    eng.cfg.max_len)
                    waves.setdefault((b, r.n_vis), []).append(r)
                t_round = time.perf_counter()  # admission round began
                wave_items = sorted(waves.items())
                for wi, (b, wave) in enumerate(wave_items):
                    slots = [free.pop(0) for _ in wave]
                    t_wave = time.perf_counter()
                    try:
                        first = eng.admit_wave(
                            [r.tokens for r in wave], slots,
                            [r.max_new for r in wave],
                            keep_pids=matched,
                            matches=[match_of.get(r.rid) for r in wave]
                            if eng.paged else None,
                            vision=[r.vision_embeds for r in wave])
                    except PagesExhausted:
                        # the budget's reclaimable slack was optimistic (the
                        # pages belong to a prefix this very wave maps, so
                        # the engine refused to evict it); requeue the
                        # unadmitted requests in submission order and retry
                        # after the next harvest releases pages (`free` need
                        # not be repaired — it is rebuilt every iteration)
                        if all(r2 is None for r2 in self._slot_rid):
                            raise  # nothing live will ever free these pages
                        order = {r.rid: k for k, r in enumerate(take)}
                        left = [r for _, w in wave_items[wi:] for r in w]
                        left.sort(key=lambda r: order[r.rid])
                        queue.extendleft(reversed(left))
                        break
                    t_first = time.perf_counter()  # host has the wave's tokens
                    # TTFT = queue wait until this round + the request's OWN
                    # wave's prefill; bucket order within a round is an
                    # engine artifact, so a later wave must not be charged
                    # for the earlier waves' prefill time
                    wave_rows = len(wave) * (b[0] + b[1])
                    self.rows_computed += wave_rows
                    for r, s, f in zip(wave, slots, first):
                        self._slot_rid[s] = r.rid
                        partial[r.rid] = [int(f)]
                        ttft[r.rid] = (t_round - t_submit[r.rid]) \
                            + (t_first - t_wave)
                        admit[r.rid] = t_round - t_submit[r.rid]
                        tpot[r.rid] = []
                        # every wave member waits out the whole padded wave
                        trows[r.rid] = wave_rows
                # instantly-finished requests (max_new==1 / prefill EOS) are
                # swept up by the finished flags of the next harvest
            self.peak_live = max(
                self.peak_live,
                sum(r is not None for r in self._slot_rid))

            # -- 4: next decode chunk (single jitted program) ---------------
            if any(rid is not None for rid in self._slot_rid):
                t0 = time.perf_counter()
                toks, valid = eng.decode_chunk()
                pending_chunk = (toks, valid, t0)

        return done

    def _run_chunked(self, requests: List[Request],
                     progress=None) -> List[Completion]:
        """Continuous batching v2: per-request chunk-budget admission into
        the unified step program. Admission allocates a request's pages and
        queues its prompt chunks — NO prefill program, bucket zoo, or
        first-token sync exists on this path; the prompt streams through
        the decode chunks' prefill-chunk lane while every live slot keeps
        emitting a token per step. The first token arrives IN the decode
        stream the step the final chunk lands, and TTFT is attributed to
        that step's position within the chunk (admission of the request's
        first chunk -> first emitted token), not to the chunk boundary.
        TPOT covers decoded tokens only (the first token is TTFT's)."""
        eng = self.engine
        eng.reset()
        self.peak_live = 0
        self.rows_computed = 0
        queue = deque(requests)
        t_submit = {r.rid: time.perf_counter() for r in requests}
        partial: Dict[int, List[int]] = {}
        ttft: Dict[int, float] = {}
        tpot: Dict[int, List[float]] = {}
        admit: Dict[int, float] = {}
        trows: Dict[int, int] = {}
        admit_step: Dict[int, int] = {}
        req_of = {r.rid: r for r in requests}
        done: List[Completion] = []
        # every unified step computes the full traced width: chunk_size
        # lane rows + n_slots decode lanes (spec: draft span per lane),
        # live or idle — the row cost of one scan step
        S = eng.cfg.draft_k + 1 if eng.spec_decode else 1
        step_rows = eng.cfg.chunk_size + eng.cfg.n_slots * S
        steps_done = 0  # unified steps harvested so far this run

        self._slot_rid = [None] * eng.cfg.n_slots
        pending_chunk = None

        while queue or any(r is not None for r in self._slot_rid):
            # -- 1+2: harvest the in-flight chunk, free finished slots ------
            if pending_chunk is not None:
                toks, valid, t_launch, first_rows = pending_chunk
                t_np, v_np, fin, _pos = eng.harvest(toks, valid)
                chunk_dt = time.perf_counter() - t_launch
                R = t_np.shape[0]
                self.rows_computed += (R // S) * step_rows
                freed = []
                for s, rid in enumerate(self._slot_rid):
                    if rid is None:
                        continue
                    new = t_np[v_np[:, s], s]
                    partial[rid].extend(int(t) for t in new)
                    n_dec = len(new)
                    if rid not in ttft and len(new):
                        # first token: TTFT ends at its row WITHIN the
                        # chunk (the schedule knows which step sampled it)
                        row = first_rows.get(s, int(np.argmax(v_np[:, s])))
                        ttft[rid] = (t_launch - t_submit[rid]) \
                            + chunk_dt * (row + 1) / R
                        # unified steps from admission through the step
                        # that sampled the first token, at the traced
                        # per-step width — the deterministic TTFT
                        trows[rid] = (steps_done - admit_step.pop(rid)
                                      + -(-(row + 1) // S)) * step_rows
                        n_dec -= 1
                    if n_dec:
                        # spec chunks inflate R with rejected proposals;
                        # per-token latency is then the chunk time over the
                        # tokens the slot actually got (same rule as waved)
                        per = chunk_dt / n_dec if eng.spec_decode \
                            else chunk_dt / R
                        tpot[rid].extend([per] * n_dec)
                    if fin[s]:
                        done.append(Completion(
                            rid, len(req_of[rid].tokens),
                            np.asarray(partial.pop(rid), np.int32),
                            ttft.pop(rid), tpot.pop(rid),
                            admit_s=admit.pop(rid),
                            ttft_rows=trows.pop(rid)))
                        self._slot_rid[s] = None
                        freed.append(s)
                        if progress:
                            progress(done[-1])
                if freed:
                    eng.release(freed)
                steps_done += R // S
                pending_chunk = None

            # -- 3: admission, per request (chunk-budget, no waves) ---------
            free = [s for s, r in enumerate(self._slot_rid) if r is None]
            while queue and free:
                r0 = queue[0]
                if r0.vision_embeds is not None:
                    raise ValueError(
                        "chunked prefill serves text-only requests "
                        "(vision-frontend engines keep the waved path)")
                if eng.paged:
                    ent = eng.prefix_match(np.asarray(r0.tokens))
                    need = eng.pages_needed(r0.tokens, r0.max_new, match=ent)
                    budget = eng.free_pages + eng.evictable_pages(
                        exclude={ent.pid} if ent is not None else set())
                    if need > budget:
                        if all(r is None for r in self._slot_rid):
                            raise ValueError(
                                f"request {r0.rid} needs {need} KV pages > "
                                f"pool capacity {budget}; it can never be "
                                "admitted")
                        break  # retry once decode releases live slots
                    try:
                        eng.admit_chunked(r0.tokens, free[0], r0.max_new,
                                          match=ent)
                    except PagesExhausted:
                        break
                else:
                    eng.admit_chunked(r0.tokens, free[0], r0.max_new)
                s = free.pop(0)
                queue.popleft()
                self._slot_rid[s] = r0.rid
                partial[r0.rid] = []
                tpot[r0.rid] = []
                # admission of the request's FIRST chunk: its prompt is
                # queued on the fill lane from this instant, so the
                # prefill-path latency clock (ttft_s - admit_s) starts here
                admit[r0.rid] = time.perf_counter() - t_submit[r0.rid]
                admit_step[r0.rid] = steps_done
            self.peak_live = max(
                self.peak_live,
                sum(r is not None for r in self._slot_rid))

            # -- 4: next unified chunk: decode lanes + prefill-chunk lane ---
            if any(rid is not None for rid in self._slot_rid):
                sched, first_rows = eng.build_schedule()
                t0 = time.perf_counter()
                toks, valid = eng.decode_chunk(schedule=sched)
                pending_chunk = (toks, valid, t0, first_rows)

        return done
