"""Slot-batched request state for continuous batching.

The engine owns a fixed pool of ``n_slots`` request slots backed by one
KV cache of shape (L, n_slots, max_len, KV, hd) (``model.init_cache``).
Every per-slot scalar lives in ``SlotState`` — a NamedTuple of device
arrays, so the whole thing threads through ``lax.scan`` as a pytree and
admission/release are single scatter ops.

Slot lifecycle:  free --admit--> active --(EOS | length)--> finished
                 --harvest/release--> free
A slot is *frozen* (still computed, outputs masked) from the step it
finishes until the host harvests it at the next chunk boundary.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp


# Cache position of a slot that holds no request (fresh pool / released).
# Far out of range on purpose: a frozen slot keeps re-feeding its last token
# through the decode program, and parking its write index past any possible
# cache extent makes that KV write DROP (paged: block index >= max_blocks ->
# unmapped sentinel; dense: dynamic_update_slice clamps to the last row,
# which decode rewrites before reading). Under chunked prefill this is a
# correctness requirement, not hygiene: a freshly-mapped block table (and
# any refcounted shared-prefix pages in it) must never take a stale-position
# garbage write while the slot's prompt is still streaming in as chunks.
FREE_POS = 1 << 30


class SlotState(NamedTuple):
    last_token: jnp.ndarray  # (S,) int32 — token fed at the next decode step
    pos: jnp.ndarray  # (S,) int32 — cache write index == tokens cached so far
    prompt_len: jnp.ndarray  # (S,) int32
    max_total: jnp.ndarray  # (S,) int32 — prompt_len + max_new - 1 (cache cap)
    active: jnp.ndarray  # (S,) bool — slot holds a live request
    finished: jnp.ndarray  # (S,) bool — done, awaiting host harvest
    rope_delta: jnp.ndarray  # (S,) int32 — rotary pos = pos + rope_delta
    # (0 for text slots; a VLM slot carries grid - n_patches because the
    # M-RoPE text stream restarts at the vision grid edge)


def init_slots(n_slots: int) -> SlotState:
    # distinct buffers per field: the engine donates the whole state into
    # its jitted programs, and XLA rejects donating one buffer twice
    i32 = jnp.int32
    return SlotState(
        last_token=jnp.zeros((n_slots,), i32),
        pos=jnp.full((n_slots,), FREE_POS, i32),
        prompt_len=jnp.zeros((n_slots,), i32),
        max_total=jnp.zeros((n_slots,), i32),
        active=jnp.zeros((n_slots,), bool),
        finished=jnp.zeros((n_slots,), bool),
        rope_delta=jnp.zeros((n_slots,), i32),
    )


def admit(state: SlotState, slots, first_token, prompt_len,
          max_total, rope_delta=None) -> SlotState:
    """Scatter a wave of freshly-prefilled requests into their slots.

    slots: (K,) int32 slot indices; padding rows use index n_slots which is
    out of bounds and therefore dropped by the scatter (mode="drop") — this
    keeps admission shapes bucketable so the program is traced once per
    bucket, not once per wave.
    """
    kw = dict(mode="drop")
    if rope_delta is None:
        rope_delta = jnp.zeros_like(prompt_len)
    return SlotState(
        last_token=state.last_token.at[slots].set(first_token, **kw),
        pos=state.pos.at[slots].set(prompt_len, **kw),
        prompt_len=state.prompt_len.at[slots].set(prompt_len, **kw),
        max_total=state.max_total.at[slots].set(max_total, **kw),
        active=state.active.at[slots].set(True, **kw),
        finished=state.finished.at[slots].set(False, **kw),
        rope_delta=state.rope_delta.at[slots].set(rope_delta, **kw),
    )


def release(state: SlotState, slots) -> SlotState:
    """Free harvested slots (admit-on-free: the scheduler refills them).
    The write position parks at FREE_POS so the freed slot's frozen decode
    writes drop instead of landing in whatever pages the next admission
    maps (see FREE_POS)."""
    kw = dict(mode="drop")
    return state._replace(
        pos=state.pos.at[slots].set(FREE_POS, **kw),
        active=state.active.at[slots].set(False, **kw),
        finished=state.finished.at[slots].set(False, **kw),
    )


def check_invariants(state: SlotState) -> None:
    """Host-side sanity checks (used by tests; cheap, call sparingly)."""
    import numpy as np

    active = np.asarray(state.active)
    finished = np.asarray(state.finished)
    pos = np.asarray(state.pos)
    plen = np.asarray(state.prompt_len)
    mt = np.asarray(state.max_total)
    assert not (finished & ~active).any(), "finished slot must be active"
    live = active & ~finished
    assert (pos[live] >= plen[live]).all(), "live slot behind its prompt"
    assert (pos[live] <= mt[live]).all(), "live slot past its budget"
    assert (pos[finished] <= mt[finished]).all()
