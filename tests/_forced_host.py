"""Shared env for subprocess tests that force a multi-device CPU host.

The parent pytest process must keep seeing exactly 1 device, so SPMD tests
spawn children with ``--xla_force_host_platform_device_count`` set. ONE
definition of that env (used by tests/test_distributed.py and
tests/test_serve_distributed.py) so hardening — like pinning
``JAX_PLATFORMS=cpu`` so a dryrun shell's TPU flags can never leak into a
child — lands everywhere at once. benchmarks/table9_serving.py's
``mesh_section`` builds the same env inline (benchmarks must not import
from tests/).
"""
import os

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def forced_cpu_env(devices: int) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    return env
