"""Optional-``hypothesis`` shim for the property tests.

When ``hypothesis`` is installed the real library is re-exported unchanged.
When it is missing (the CI container does not ship it), ``@given`` degrades
to a deterministic ``pytest.mark.parametrize`` over a fixed sample of each
strategy — the same assertions run on a representative grid of inputs, so
the file still collects and the properties still get exercised.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies  # noqa: F401
    st = strategies
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - depends on environment
    import functools
    import inspect
    import itertools
    import random

    import pytest

    HAVE_HYPOTHESIS = False
    _MAX_EXAMPLES = 8  # per @given, after taking the product of strategies

    class _Strategy:
        def __init__(self, examples):
            self.examples = list(examples)

    class _Strategies:
        @staticmethod
        def integers(lo=0, hi=1 << 30):
            rng = random.Random(0xC0FFEE ^ lo ^ hi)
            span = hi - lo
            ex = [lo, hi, lo + span // 2]
            ex += [lo + rng.randrange(span + 1) for _ in range(5)]
            return _Strategy(dict.fromkeys(ex))  # dedup, keep order

        @staticmethod
        def floats(lo, hi, **_kw):
            mid = (lo + hi) / 2.0
            return _Strategy(dict.fromkeys([lo, hi, mid, lo + (hi - lo) * 0.25]))

        @staticmethod
        def sampled_from(xs):
            return _Strategy(xs)

        @staticmethod
        def booleans():
            return _Strategy([False, True])

    st = strategies = _Strategies()

    class settings:  # noqa: N801 - mirrors hypothesis API
        def __init__(self, *a, **kw):
            pass

        @staticmethod
        def register_profile(name, **kw):
            pass

        @staticmethod
        def load_profile(name):
            pass

        def __call__(self, fn):
            return fn

    def given(*strats, **kw_strats):
        """Parametrize over a deterministic subsample of the strategy product."""
        def deco(fn):
            sig = inspect.signature(fn)
            names = [p for p in sig.parameters if p != "self"]
            pos_names = names[: len(strats)]
            all_names = pos_names + list(kw_strats)
            pools = [s.examples for s in strats] + \
                    [s.examples for s in kw_strats.values()]
            combos = list(itertools.product(*pools))
            if len(combos) > _MAX_EXAMPLES:
                rng = random.Random(0)
                keep = sorted(rng.sample(range(len(combos)), _MAX_EXAMPLES))
                combos = [combos[i] for i in keep]
            if len(all_names) == 1:
                values = [c[0] for c in combos]
            else:
                values = combos
            mark = pytest.mark.parametrize(",".join(all_names), values)

            @functools.wraps(fn)
            def wrapper(*a, **kw):
                return fn(*a, **kw)

            return mark(wrapper)
        return deco
