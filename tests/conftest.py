import numpy as np
import pytest

# NOTE: no XLA_FLAGS here — tests must see exactly 1 CPU device.
# Multi-device SPMD tests spawn subprocesses (test_distributed.py).


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
