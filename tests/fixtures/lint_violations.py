"""Deliberate jitlint violations — exactly one construct per rule.

This file is LINTED by tests/test_analysis.py (golden report) and never
imported; the code below is intentionally wrong. The module directive opts
it into the path-scoped rule sets (bf16 compute, mesh-aware) that real
modules get from their location/imports.
"""
# lint: module(bf16-compute, mesh-aware)
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


@jax.jit
def host_sync_in_jit(x):
    return x.item()  # host-sync: device round-trip inside a trace


def _copy_body(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def hard_interpret(x):
    # pallas-interpret: hard-coded interpret (the PR 6 bug class), and
    # pallas-params: no compiler_params declaration
    return pl.pallas_call(
        _copy_body, out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=True)(x)


def jit_without_shardings(fn):
    # jit-shardings: mesh-aware module, no in/out shardings
    return jax.jit(fn, donate_argnums=(0,))


def f32_in_bf16_path(x):
    return x.astype(jnp.float32)  # f32-cast in a bf16 compute path


def suppressed_jit(fn):
    # single-device helper: the inline allow must suppress this one
    return jax.jit(fn)  # lint: allow(jit-shardings)
