"""Static-analysis subsystem: rule coverage (golden fixture report),
baseline round-trip/staleness, the two historical-bug regression probes
(hard interpret default, mid-head sharding split), and the VMEM budget
model's accept/reject behavior."""
import os
import textwrap

import pytest

from repro.analysis import (apply_baseline, load_baseline, render_findings,
                            write_baseline)
from repro.analysis import jitlint

FIXTURE = os.path.join(os.path.dirname(__file__), "fixtures",
                       "lint_violations.py")

GOLDEN_REPORT = """\
== fixture: 5 finding(s) ==
  host-sync          tests/fixtures/lint_violations.py:16 [host_sync_in_jit] .item() in jitted region forces a device round-trip
  pallas-interpret   tests/fixtures/lint_violations.py:26 [hard_interpret] pallas_call with hard-coded interpret=True (PR 6 bug class: must resolve via ops._interpret_default)
  pallas-params      tests/fixtures/lint_violations.py:26 [hard_interpret] pallas_call without compiler_params (dimension_semantics + vmem_limit_bytes)
  jit-shardings      tests/fixtures/lint_violations.py:33 [jit_without_shardings] jax.jit in a mesh-aware module without explicit in_shardings/out_shardings (state may silently migrate through one device)
  f32-cast           tests/fixtures/lint_violations.py:37 [f32_in_bf16_path] astype(float32) in a bf16 compute path"""


def _fixture_findings():
    return jitlint.lint_file(
        FIXTURE, relpath="tests/fixtures/lint_violations.py")


# ---------------------------------------------------------------------------
# jitlint: rule coverage + golden report + suppression mechanics
# ---------------------------------------------------------------------------

def test_fixture_covers_every_rule_golden():
    findings = _fixture_findings()
    assert sorted({f.rule for f in findings}) == sorted(jitlint.RULES)
    assert render_findings("fixture", findings) == GOLDEN_REPORT


def test_inline_allow_suppresses():
    # the fixture's suppressed_jit carries `# lint: allow(jit-shardings)`
    # on an otherwise-violating jax.jit — it must produce no finding
    findings = _fixture_findings()
    assert not any(f.scope == "suppressed_jit" for f in findings)


def test_baseline_roundtrip_and_staleness(tmp_path):
    findings = _fixture_findings()
    bl = tmp_path / "baseline.txt"
    write_baseline(str(bl), findings, header="test")
    entries = load_baseline(str(bl))
    # round-trip: everything suppressed, nothing stale
    res = apply_baseline(findings, entries)
    assert not res.unsuppressed and not res.stale
    assert len(res.suppressed) == len(findings)
    # a fixed violation leaves a stale entry -> run must fail
    res = apply_baseline([f for f in findings if f.rule != "f32-cast"],
                         entries)
    assert len(res.stale) == 1 and "f32-cast" in res.stale[0]
    # a new violation is unsuppressed -> run must fail
    extra = findings[0].__class__("host-sync", "x.py", 1, "f", "y.item()",
                                  "new")
    res = apply_baseline(findings + [extra], entries)
    assert res.unsuppressed == [extra]


def test_baseline_key_survives_line_drift():
    f = _fixture_findings()[0]
    moved = f.__class__(f.rule, f.path, f.line + 40, f.scope,
                        "  " + f.snippet + "  ", f.message)
    assert moved.key == f.key


def test_repo_lint_is_clean_against_baseline():
    """The shipped tree + shipped baseline == zero unsuppressed findings
    and zero stale entries (what `make analyze` enforces)."""
    from repro.analysis.__main__ import default_baseline_path
    res = apply_baseline(jitlint.lint_tree(),
                         load_baseline(default_baseline_path()))
    assert not res.unsuppressed, "\n".join(
        f.render() for f in res.unsuppressed)
    assert not res.stale, res.stale


# ---------------------------------------------------------------------------
# regression probe 1: the PR 6 bug class — a pallas wrapper whose interpret
# default is hard-coded (would run the Python interpreter on real TPUs)
# ---------------------------------------------------------------------------

def test_hard_interpret_default_is_caught(tmp_path):
    src = textwrap.dedent("""\
        import jax
        from jax.experimental import pallas as pl

        def _body(x_ref, o_ref):
            o_ref[...] = x_ref[...]

        def kernel(x, *, interpret: bool = True):
            return pl.pallas_call(
                _body, out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
                interpret=interpret)(x)
        """)
    p = tmp_path / "bad_kernel.py"
    p.write_text(src)
    findings = jitlint.lint_file(str(p), relpath="bad_kernel.py")
    assert any(f.rule == "pallas-interpret"
               and "defaults to True" in f.message for f in findings)


def test_resolved_interpret_contract_is_clean(tmp_path):
    src = textwrap.dedent("""\
        import jax
        from jax.experimental import pallas as pl
        from jax.experimental.pallas import tpu as pltpu

        def _body(x_ref, o_ref):
            o_ref[...] = x_ref[...]

        def kernel(x, *, interpret=None):
            if interpret is None:
                from repro.kernels.ops import _interpret_default
                interpret = _interpret_default()
            return pl.pallas_call(
                _body, out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
                compiler_params=pltpu.TPUCompilerParams(
                    dimension_semantics=("parallel",),
                    vmem_limit_bytes=64 * 1024 * 1024),
                interpret=interpret)(x)
        """)
    p = tmp_path / "good_kernel.py"
    p.write_text(src)
    assert jitlint.lint_file(str(p), relpath="good_kernel.py") == []


# ---------------------------------------------------------------------------
# regression probe 2: the PR 5 bug class — re-introducing a mid-head
# sharding split past make_rules' head-count degradation
# ---------------------------------------------------------------------------

def test_midhead_split_is_caught():
    from repro.analysis import contracts
    from repro.configs import get_config
    # kv_heads=2, head_dim=16 on a 4-way model axis: the flattened dim (32)
    # divides 4, the head count (2) does not — exactly the case per-dim
    # divisibility alone would wave through
    cfg = get_config("qwen3-8b").reduced(num_kv_heads=2)
    clean = contracts.check_param_contracts("qwen3-8b", "tp4", cfg=cfg)
    assert clean == [], "shipped rule table must degrade kv_heads cleanly"
    bad = contracts.check_param_contracts(
        "qwen3-8b", "tp4", overrides={"kv_heads": "model"}, cfg=cfg)
    assert any(f.rule == "mid-head-split" for f in bad)
    assert any("wk" in f.scope or "wv" in f.scope for f in bad)


def test_static_contract_matrix_clean_sample():
    """A cross-family sample of the full `make analyze` matrix: params +
    serve state over every geometry, plus the golden pins and the bf16
    upcast check, must report nothing."""
    from repro.analysis import contracts
    fs = contracts.run_static(archs=["qwen3-8b", "mamba2-1.3b",
                                     "deepseek-moe-16b", "zamba2-7b"])
    fs += contracts.check_bf16_upcasts()
    assert fs == [], "\n".join(f.render() for f in fs)


def test_golden_pins_catch_silent_degradation(monkeypatch):
    """Dropping a TP rule-table entry degrades everything to replication —
    still *valid*, so only the golden pins can catch it."""
    from repro.analysis import contracts
    from repro.distributed import sharding as SHARD
    real = SHARD.make_rules

    def dropped(cfg, mesh, kind, overrides=None):
        rules = real(cfg, mesh, kind, overrides)
        rules["heads"] = None  # silently un-TP the attention heads
        return rules

    monkeypatch.setattr(SHARD, "make_rules", dropped)
    fs = contracts.check_golden_pins()
    assert any(f.rule == "golden-pin" and "wq" in f.scope for f in fs)


# ---------------------------------------------------------------------------
# VMEM budget model: accept real shapes, reject impossible ones
# ---------------------------------------------------------------------------

def test_vmem_default_lane_clean():
    from repro.analysis import vmem
    fs = vmem.run_default(archs=["qwen3-8b", "deepseek-moe-16b"])
    assert fs == [], "\n".join(f.render() for f in fs)


def test_vmem_rejects_indivisible_and_oversized():
    from repro.analysis import vmem
    # deepseek's shared-expert K=2816 against the default 512 K-block
    p = vmem.masked_matmul.vmem_plan(8, 2816, 2048, block_k=512)
    assert not p.feasible and any("block_k" in v for v in p.violations)
    # blocks that simply cannot fit in the declared 64MiB budget
    p = vmem.masked_matmul.vmem_plan(2048, 8192, 8192, block_m=2048,
                                     block_n=8192, block_k=8192)
    assert not p.feasible
    assert any("VMEM" in w for w in p.why_infeasible())
    # and the resolver finds the largest legal divisor
    assert vmem.resolve_block(2816, 512) == 352
    assert vmem.resolve_block(2816, 512, multiple=8) == 352
    assert vmem.resolve_block(7, 512, multiple=8) is None


def test_vmem_sweep_reports_infeasible_cells():
    from repro.analysis import vmem
    plans, findings = vmem.sweep("deepseek-moe-16b")
    assert plans and findings
    assert all(f.rule == "vmem-budget" for f in findings)
    # the default `make analyze` lane for the same arch resolves blocks
    assert vmem.run_default(archs=["deepseek-moe-16b"]) == []
