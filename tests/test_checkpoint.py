"""Checkpoint store/manager: atomicity, auto-resume, failure recovery,
bitwise-reproducible restart of training."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.checkpoint.store import load_pytree, save_pytree


def _state(x=1.0):
    return {"params": {"w": jnp.full((4, 4), x), "b": jnp.zeros(3, jnp.bfloat16)},
            "step": jnp.asarray(7, jnp.int32)}


class TestStore:
    def test_roundtrip(self, tmp_path):
        s = _state(3.0)
        save_pytree(str(tmp_path / "ck"), s, extra={"step": 7})
        out = load_pytree(str(tmp_path / "ck"), s)
        np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                      np.asarray(s["params"]["w"]))
        assert out["params"]["b"].dtype == jnp.bfloat16

    def test_atomic_overwrite(self, tmp_path):
        p = str(tmp_path / "ck")
        save_pytree(p, _state(1.0))
        save_pytree(p, _state(2.0))
        out = load_pytree(p, _state())
        assert float(out["params"]["w"][0, 0]) == 2.0


class TestManager:
    def test_resume_latest(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), async_write=False)
        mgr.save(10, _state(1.0))
        mgr.save(20, _state(2.0))
        state, extra = mgr.restore(_state())
        assert extra["step"] == 20
        assert float(state["params"]["w"][0, 0]) == 2.0

    def test_corrupt_checkpoint_skipped(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), async_write=False)
        mgr.save(10, _state(1.0))
        mgr.save(20, _state(2.0))
        # corrupt the newest (simulates crash mid-publish on shared fs)
        os.remove(os.path.join(str(tmp_path), "step_20", "leaf_0.npy"))
        state, extra = mgr.restore(_state())
        assert extra["step"] == 10

    def test_retention_gc(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2, async_write=False)
        for s in (1, 2, 3, 4):
            mgr.save(s, _state(float(s)))
        assert mgr.steps() == [3, 4]


class TestTrainRestart:
    def test_restart_is_bitwise_identical(self, tmp_path):
        """Train 8 steps straight vs 4 + crash + resume 4: same final loss."""
        from repro.launch.train import train_loop
        d1, d2 = str(tmp_path / "a"), str(tmp_path / "b")
        _, losses_straight = train_loop("llama1-7b", 8, ckpt_dir=d1,
                                        smoke=True, ckpt_every=100,
                                        batch=2, seq_len=16, log_every=100)
        try:
            train_loop("llama1-7b", 8, ckpt_dir=d2, smoke=True, ckpt_every=4,
                       batch=2, seq_len=16, log_every=100, die_at_step=4)
        except SystemExit as e:
            assert e.code == 42
        _, losses_resumed = train_loop("llama1-7b", 8, ckpt_dir=d2,
                                       smoke=True, ckpt_every=4,
                                       batch=2, seq_len=16, log_every=100)
        np.testing.assert_allclose(losses_straight[-1], losses_resumed[-1],
                                   rtol=1e-5)

    def test_elastic_restore_reshards(self, tmp_path):
        """Save params, then restore with explicit (trivial) shardings —
        the elastic path: device_put with regenerated shardings."""
        from jax.sharding import NamedSharding, PartitionSpec as P
        mesh = jax.make_mesh((1,), ("data",))
        s = _state(5.0)
        save_pytree(str(tmp_path / "ck"), s)
        sh = jax.tree_util.tree_map(
            lambda _: NamedSharding(mesh, P()), s)
        out = load_pytree(str(tmp_path / "ck"), s, shardings=sh)
        assert out["params"]["w"].sharding == NamedSharding(mesh, P())
