"""Chunked prefill interleaved with decode: the unified step program.

Layered evidence that the chunk lane can be THE prefill path for pure
token-KV families:

  1. kernel property parity (Sq>1 mode): kernel vs the gather-semantics
     oracle vs dense ``_sdpa`` with the chunk lane's causal contract
     (query row i sits at position lengths - Sq + i), sweeping chunk
     sizes, page sizes {4, 8, 16}, GQA groups, ragged chunk boundaries
     (length == Sq, == capacity, unaligned), fp32 and int8 arenas;
  2. engine-level: the chunked drive (admit_chunked / build_schedule /
     decode_chunk) is greedy BIT-EXACT vs the waved ``generate``
     baseline — paged and dense pool, ragged final chunks, shared-prefix
     admission, and the self-speculative drafter (both arenas filled by
     the chunk lane);
  3. scheduler stream: ``Scheduler.run`` on a chunked engine emits the
     same tokens as the waved fallback across slot churn, with per-chunk
     TTFT attribution and TPOT covering decoded tokens only;
  4. eligibility: recurrent / hybrid / vision families resolve
     ``chunked_prefill=False`` and still serve on the waved path;
     forcing the flag raises;
  5. trace pins: zero prefill traces, ONE decode trace, zero retraces
     across changing prompt lengths and fill loads (the schedule is
     data, not shape) — via the static-analysis contract cells.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, st
from repro.analysis import contracts
from repro.configs import get_config
from repro.kernels import ops, ref
from repro.models.layers import KV_QSCALE, _sdpa
from repro.models.model import Model
from repro.serve import Engine, EngineConfig, Request
from repro.serve.scheduler import Scheduler

SCALE = 0.25


# ---------------------------------------------------------------------------
# kernel: Sq>1 chunk-lane mode vs gather oracle vs dense _sdpa
# ---------------------------------------------------------------------------

def _case_sq(seed, ps, G, sq, *, KV=2, hd=8, MB=4, int8=False):
    """Random chunk-lane instance honouring the Sq-mode length contract
    (length == 0, or >= Sq so every query row has a real position): row 0
    is empty, row 1 holds exactly one chunk (length == Sq, the first-chunk
    boundary), row 2 is at full capacity, the rest land at random ragged
    offsets; block tables map disjoint random pages, rest unmapped."""
    rng = np.random.default_rng(seed)
    B = 5
    cap = MB * ps
    assert sq <= cap
    lengths = np.array(
        [0, sq, cap] + list(rng.integers(sq, cap + 1, B - 3)), np.int64)
    perm = rng.permutation(B * MB + 3)
    bt = np.full((B, MB), B * MB + 3, np.int64)
    k = 0
    for b in range(B):
        nb = -(-int(lengths[b]) // ps)
        bt[b, :nb] = perm[k:k + nb]
        k += nb
    n_pages = B * MB + 3
    if int8:
        k_pages = jnp.asarray(
            rng.integers(-127, 128, (n_pages, ps, KV, hd)), jnp.int8)
        v_pages = jnp.asarray(
            rng.integers(-127, 128, (n_pages, ps, KV, hd)), jnp.int8)
    else:
        k_pages = jnp.asarray(rng.normal(size=(n_pages, ps, KV, hd)),
                              jnp.float32)
        v_pages = jnp.asarray(rng.normal(size=(n_pages, ps, KV, hd)),
                              jnp.float32)
    q = jnp.asarray(rng.normal(size=(B, sq, KV, G, hd)), jnp.float32)
    return (q, k_pages, v_pages, jnp.asarray(bt, jnp.int32),
            jnp.asarray(lengths, jnp.int32))

def _check_sq(q, k_pages, v_pages, bt, lengths, kv_qscale=None):
    got = ops.paged_attention(q, k_pages, v_pages, bt, lengths,
                              scale=SCALE, kv_qscale=kv_qscale)
    want = ref.paged_attention_ref(q, k_pages, v_pages, bt, lengths,
                                   scale=SCALE, kv_qscale=kv_qscale)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    # tie the oracle to the production _sdpa under the causal contract
    B, Sq, KV, G, hd = q.shape
    n_pages, ps = k_pages.shape[:2]
    MB = bt.shape[1]
    k_full = k_pages.at[bt].get(mode="fill", fill_value=0)
    v_full = v_pages.at[bt].get(mode="fill", fill_value=0)
    k_full = k_full.reshape(B, MB * ps, KV, hd).astype(jnp.float32)
    v_full = v_full.reshape(B, MB * ps, KV, hd).astype(jnp.float32)
    if kv_qscale is not None:
        k_full = k_full / kv_qscale
        v_full = v_full / kv_qscale
    qpos = lengths[:, None] - Sq + jnp.arange(Sq)[None, :]
    mask = jnp.arange(MB * ps)[None, None, :] <= qpos[:, :, None]
    sdpa = _sdpa(q, k_full, v_full, mask, SCALE)
    live = np.asarray(lengths) > 0
    np.testing.assert_allclose(np.asarray(got)[live],
                               np.asarray(sdpa)[live],
                               rtol=2e-5, atol=2e-5)
    return got


@given(st.sampled_from([4, 8, 16]), st.sampled_from([1, 2, 4]),
       st.sampled_from([2, 4, 5, 8]), st.integers(0, 10_000))
def test_sq_parity_fp32(ps, G, sq, seed):
    _check_sq(*_case_sq(seed, ps, G, sq))


@given(st.sampled_from([4, 8]), st.sampled_from([1, 4]),
       st.sampled_from([4, 5]), st.integers(0, 10_000))
def test_sq_parity_int8(ps, G, sq, seed):
    q, k8, v8, bt, lengths = _case_sq(seed, ps, G, sq, int8=True)
    _check_sq(q, k8, v8, bt, lengths, kv_qscale=KV_QSCALE)
    kf = k8.astype(jnp.float32) / KV_QSCALE
    vf = v8.astype(jnp.float32) / KV_QSCALE
    got8 = ops.paged_attention(q, k8, v8, bt, lengths,
                               scale=SCALE, kv_qscale=KV_QSCALE)
    gotf = ops.paged_attention(q, kf, vf, bt, lengths, scale=SCALE)
    np.testing.assert_allclose(np.asarray(got8), np.asarray(gotf),
                               rtol=2e-5, atol=2e-5)


def test_sq_last_row_matches_decode_mode():
    """Positional coupling between the two kernel modes: the LAST query
    row of an Sq block sits at position lengths - 1, i.e. exactly where
    the decode (Sq=1) mode puts its single query — outputs must agree."""
    q, kp, vp, bt, lengths = _case_sq(11, 8, 2, 4)
    out_sq = ops.paged_attention(q, kp, vp, bt, lengths, scale=SCALE)
    out_1 = ops.paged_attention(q[:, -1], kp, vp, bt, lengths, scale=SCALE)
    live = np.asarray(lengths) > 0
    np.testing.assert_allclose(np.asarray(out_sq)[live, -1],
                               np.asarray(out_1)[live],
                               rtol=2e-5, atol=2e-5)


def test_sq_length_zero_rows_are_zero():
    q, kp, vp, bt, lengths = _case_sq(0, 8, 2, 4)
    got = np.asarray(ops.paged_attention(q, kp, vp, bt, lengths, scale=SCALE))
    assert (got[np.asarray(lengths) == 0] == 0).all()
    assert np.isfinite(got).all()


def test_sq_unmapped_tail_matches_gather():
    """Ragged chunk whose table tail is unmapped (the idle-lane / frozen
    slot drop-write region): kernel must reproduce the fill-zeros gather."""
    q, kp, vp, bt, lengths = _case_sq(7, 4, 1, 4)
    n_pages = kp.shape[0]
    bt = bt.at[:, 2:].set(n_pages)
    got = ops.paged_attention(q, kp, vp, bt, lengths, scale=SCALE)
    want = ref.paged_attention_ref(q, kp, vp, bt, lengths, scale=SCALE)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# engine: chunked drive is greedy bit-exact vs the waved baseline
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small():
    cfg = get_config("qwen3-8b").reduced()
    model = Model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


def _prompts(cfg, B, P, seed=1):
    return np.asarray(jax.random.randint(
        jax.random.PRNGKey(seed), (B, P), 0, cfg.vocab_size), np.int32)


def _chunked_generate(eng, prompts, max_new):
    """Drive the unified step program to completion: admit every prompt
    into the fill queue, then loop build_schedule/decode_chunk/harvest
    until all slots finish and the queue drains."""
    B = len(prompts)
    for b in range(B):
        eng.admit_chunked(np.asarray(prompts[b]), b, max_new)
    rows = {b: [] for b in range(B)}
    for _ in range(200):
        sched, _ = eng.build_schedule()
        toks, valid = eng.decode_chunk(schedule=sched)
        t, v, fin, _pos = eng.harvest(toks, valid)
        for b in range(B):
            rows[b].extend(t[v[:, b], b].tolist())
        if fin[:B].all() and not eng.fill_pending:
            break
    else:
        raise AssertionError("chunked drive did not converge")
    return np.asarray([rows[b][:max_new] for b in range(B)])


@pytest.mark.parametrize("paged", [True, False],
                         ids=["paged", "dense-pool"])
def test_chunked_generate_bitexact(small, paged):
    """P=11 over chunk_size=4 forces a ragged final chunk (the overlap
    re-anchor path); tokens must equal the waved generate bit-for-bit."""
    model, params = small
    cfg = model.cfg
    B, P, G = 4, 11, 6
    prompts = _prompts(cfg, B, P)
    eng_w = Engine(model, params, EngineConfig(
        n_slots=B, max_len=P + G, chunk=G - 1, prefill_buckets=(P,),
        paged=paged))
    out_w = eng_w.generate(prompts, G)
    eng_c = Engine(model, params, EngineConfig(
        n_slots=B, max_len=P + G, chunk=4, prefill_buckets=(P,),
        paged=paged, chunk_size=4))
    assert eng_c.chunked_prefill  # auto-on for a pure token-KV family
    out_c = _chunked_generate(eng_c, prompts, G)
    np.testing.assert_array_equal(out_c, out_w)
    assert eng_c.trace_counts["prefill"] == 0, \
        "no prefill program may exist on the chunked path"


def test_chunked_spec_decode_bitexact(small):
    """Self-speculative drafter: the chunk lane fills BOTH arenas (target
    + drafter) and the first token lands in row 0 of its macro step."""
    model, params = small
    cfg = model.cfg
    B, P, G, k = 3, 10, 7, 2
    prompts = _prompts(cfg, B, P)
    draft = model.init(jax.random.PRNGKey(2))
    mk = lambda ch: Engine(model, params, EngineConfig(
        n_slots=B, max_len=P + G + k, chunk=ch, prefill_buckets=(P,),
        draft_k=k, chunk_size=4), draft_params=draft)
    out_w = mk(G - 1).generate(prompts, G)
    out_c = _chunked_generate(mk(6), prompts, G)
    np.testing.assert_array_equal(out_c, out_w)


def test_chunked_shared_prefix_admission(small):
    """admit_chunked maps refcounted prefix pages without a prefill pass:
    page usage must reflect sharing and tokens must stay bit-exact."""
    model, params = small
    cfg = model.cfg
    B, P, G, ps = 3, 11, 6, 16
    pref = _prompts(cfg, 1, ps, seed=3)[0]
    full = np.stack([np.concatenate([pref, p])
                     for p in _prompts(cfg, B, P)])
    mk = lambda **kw: Engine(model, params, EngineConfig(
        n_slots=B, max_len=ps + P + G, prefill_buckets=(ps + P,),
        page_size=ps, **kw))
    eng_w = mk(chunk=G - 1)
    eng_w.register_prefix(pref)
    out_w = eng_w.generate(full, G)
    eng_c = mk(chunk=5, chunk_size=4)
    eng_c.register_prefix(pref)
    fp0 = eng_c.free_pages
    out_c = _chunked_generate(eng_c, full, G)
    np.testing.assert_array_equal(out_c, out_w)
    pages_per_req = -(-(P + G - 1) // ps)  # suffix only: prefix is shared
    assert fp0 - eng_c.free_pages == B * pages_per_req
    assert eng_c.stats["shared_tokens_saved"] == B * ps


# ---------------------------------------------------------------------------
# scheduler: chunked stream parity + TTFT / TPOT attribution
# ---------------------------------------------------------------------------

def _stream(cfg, n=9, seed=6):
    rng = np.random.default_rng(seed)
    return [Request(rid,
                    rng.integers(0, cfg.vocab_size,
                                 int(rng.integers(3, 20))).astype(np.int32),
                    int(rng.integers(2, 8)))
            for rid in range(n)]


def _drive(model, params, reqs, *, chunked, paged, draft=None, k=0):
    eng = Engine(model, params, EngineConfig(
        n_slots=4, max_len=32, chunk=6, prefill_buckets=(8, 16, 32),
        paged=paged, chunked_prefill=chunked, chunk_size=5, draft_k=k),
        draft_params=draft)
    comps = Scheduler(eng).run(
        [Request(r.rid, r.tokens.copy(), r.max_new) for r in reqs])
    return comps


@pytest.mark.parametrize("paged", [True, False],
                         ids=["paged", "dense-pool"])
def test_scheduler_stream_bitexact(small, paged):
    """9 mixed-length requests through 4 slots: slot churn, mid-stream
    admission, frozen slots — same tokens chunked vs waved fallback."""
    model, params = small
    reqs = _stream(model.cfg)
    w = {c.rid: c.tokens.tolist()
         for c in _drive(model, params, reqs, chunked=False, paged=paged)}
    c = {c.rid: c.tokens.tolist()
         for c in _drive(model, params, reqs, chunked=True, paged=paged)}
    assert set(w) == set(c) == set(range(9))
    assert w == c


def test_scheduler_stream_spec_bitexact(small):
    model, params = small
    reqs = _stream(model.cfg)
    draft = model.init(jax.random.PRNGKey(2))
    w = {c.rid: c.tokens.tolist()
         for c in _drive(model, params, reqs, chunked=False, paged=True,
                         draft=draft, k=2)}
    c = {c.rid: c.tokens.tolist()
         for c in _drive(model, params, reqs, chunked=True, paged=True,
                         draft=draft, k=2)}
    assert w == c


def test_chunked_ttft_tpot_attribution(small):
    """Every completion records a positive TTFT (attributed to the first
    token's row within its chunk), an admission timestamp no later than
    the first token (so ttft_s - admit_s is the admission-of-first-chunk
    -> first-emitted-token latency), and TPOT entries for decoded tokens
    ONLY — the first token belongs to TTFT, so len(tpot) == tokens - 1.
    The deterministic counterpart ttft_rows charges whole unified steps
    at their traced width (chunk_size lane rows + n_slots decode lanes),
    so it is a positive multiple of that width; the waved fallback
    charges the request's whole padded wave."""
    model, params = small
    reqs = _stream(model.cfg)
    comps = _drive(model, params, reqs, chunked=True, paged=True)
    assert sorted(c.rid for c in comps) == list(range(9))
    step_rows = 5 + 4  # chunk_size + n_slots, the traced step width
    for c in comps:
        assert c.ttft_s > 0.0
        assert 0.0 <= c.admit_s < c.ttft_s
        assert c.ttft_rows > 0 and c.ttft_rows % step_rows == 0
        assert len(c.tpot_s) == len(c.tokens) - 1
        assert all(t > 0.0 for t in c.tpot_s)
    for c in _drive(model, params, reqs, chunked=False, paged=True):
        # a wave of B requests padded to bucket P charges >= B * P rows
        assert c.ttft_rows >= 8  # smallest bucket, wave of one


# ---------------------------------------------------------------------------
# eligibility: non-token-KV families stay on the waved path
# ---------------------------------------------------------------------------

def test_oversized_chunk_pins_waved_fallback(small):
    """A chunk that cannot fit the cache extent (chunk_size > max_len)
    cannot stream any prompt: auto mode must resolve to the waved
    fallback instead of erroring, and forcing chunked_prefill raises."""
    model, params = small
    mk = lambda **kw: Engine(model, params, EngineConfig(
        n_slots=2, max_len=12, chunk=4, prefill_buckets=(8,),
        chunk_size=16, **kw))
    assert not mk().chunked_prefill
    with pytest.raises(ValueError, match="chunk_size"):
        mk(chunked_prefill=True)


def test_hybrid_family_pins_waved_fallback():
    """A hybrid (attention + recurrent) family cannot stream its prompt
    through the chunk lane: chunked_prefill must auto-resolve False,
    forcing it must raise, and the waved scheduler path must still serve
    greedy-correct completions."""
    cfg = get_config("zamba2-7b").reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    mk = lambda **kw: Engine(model, params, EngineConfig(
        n_slots=2, max_len=32, chunk=4, prefill_buckets=(8, 16), **kw))
    eng = mk()
    assert not eng.chunked_prefill
    with pytest.raises(ValueError, match="chunked prefill"):
        mk(chunked_prefill=True)
    reqs = _stream(cfg, n=3, seed=1)
    comps = Scheduler(eng).run(reqs)
    assert sorted(c.rid for c in comps) == [0, 1, 2]
    assert eng.trace_counts["prefill"] >= 1  # served by the waved path
    for c in comps:
        assert len(c.tokens) == reqs[c.rid].max_new


# ---------------------------------------------------------------------------
# trace pins: the unified step program never retraces across fill loads
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cell", sorted(contracts.CHUNKED_TRACE_CELLS))
def test_chunked_trace_pins(cell):
    measured, findings = contracts.run_chunked_trace_cell(cell)
    assert not findings, [f.message for f in findings]
    assert measured == contracts.EXPECTED_CHUNKED_TRACES[cell]
