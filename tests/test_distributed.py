"""SPMD correctness on a multi-device CPU mesh (subprocess: tests in this
process must keep seeing exactly 1 device)."""
import json
import subprocess
import sys
import textwrap

import pytest

from _forced_host import forced_cpu_env


def _run(code: str, devices: int = 8) -> str:
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True,
                         env=forced_cpu_env(devices), timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    """Same batch, same init: (4 data x 2 model) mesh loss == 1-device loss."""
    out = _run("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.configs.base import TrainConfig
        from repro.launch.steps import init_train_state, make_train_step
        from repro.distributed.sharding import param_shardings, input_shardings
        from repro.models.model import Model, input_specs

        cfg = get_config("llama1-7b").reduced(d_model=64, num_layers=2, d_ff=128)
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab_size)
        batch = {"tokens": toks, "labels": toks}
        tc = TrainConfig(total_steps=2, warmup_steps=1)
        step = make_train_step(model, tc)
        state = init_train_state(model, params, tc)

        # single device
        s1, m1 = jax.jit(step)(state, batch)

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        with mesh:
            p_sh = param_shardings(mesh, cfg, params, "train")
            st_sh = {"params": p_sh,
                     "opt": {"mu": p_sh, "nu": p_sh, "step": NamedSharding(mesh, P())},
                     "step": NamedSharding(mesh, P())}
            b_sh = jax.tree_util.tree_map(
                lambda _: NamedSharding(mesh, P("data", None)), batch)
            state_s = jax.device_put(state, st_sh)
            batch_s = jax.device_put(batch, b_sh)
            s2, m2 = jax.jit(step, in_shardings=(st_sh, b_sh))(state_s, batch_s)
        d = abs(float(m1["loss"]) - float(m2["loss"]))
        assert d < 1e-4, d
        # params also match
        import numpy as np
        w1 = np.asarray(s1["params"]["blocks"]["mlp"]["wg"]["w"])
        w2 = np.asarray(jax.device_get(s2["params"]["blocks"]["mlp"]["wg"]["w"]))
        assert np.allclose(w1, w2, atol=1e-5)
        print("SPMD_OK", d)
    """)
    assert "SPMD_OK" in out


@pytest.mark.slow
def test_sharded_prune_matches_single_device():
    """Wanda++ pruning under a mesh produces the same masks as 1 device —
    the paper's method is distribution-invariant."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.configs.base import PruneConfig
        from repro.core.pruner import prune_model
        from repro.data import calibration_batch
        from repro.models.model import Model

        cfg = get_config("llama1-7b").reduced(d_model=64, num_layers=2, d_ff=128)
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        calib = calibration_batch(cfg.vocab_size, 8, 16)
        pcfg = PruneConfig(method="wanda++", pattern="2:4", ro_iters=1,
                           ro_samples=4, n_calib=8)
        p1, _ = prune_model(model, params, calib, pcfg)

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        with mesh:
            p2, _ = prune_model(model, params, calib, pcfg)
        w1 = np.asarray(p1["blocks"]["mlp"]["wg"]["w"])
        w2 = np.asarray(jax.device_get(p2["blocks"]["mlp"]["wg"]["w"]))
        assert np.allclose(w1, w2, atol=1e-4)
        print("PRUNE_SPMD_OK")
    """)
    assert "PRUNE_SPMD_OK" in out


def test_sharding_rules_divisibility_fallback():
    """kv_heads=8 on a 16-way model axis must degrade to replication, not
    crash — same for qwen2-vl's 12 heads."""
    out = _run("""
        import jax
        from repro.configs import get_config
        from repro.distributed.sharding import param_shardings
        from repro.models.model import Model
        import jax.numpy as jnp

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        for arch in ("qwen3-8b", "qwen2-vl-2b", "mamba2-1.3b", "zamba2-7b"):
            cfg = get_config(arch)
            model = Model(cfg, param_dtype=jnp.bfloat16)
            shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
            sh = param_shardings(mesh, cfg, shapes, "train")
            # every sharding must evenly divide its leaf
            for leaf, s in zip(jax.tree_util.tree_leaves(shapes),
                               jax.tree_util.tree_leaves(
                                   sh, is_leaf=lambda x: hasattr(x, "spec"))):
                s.shard_shape(leaf.shape)  # raises if invalid
        print("RULES_OK")
    """, devices=8)
    assert "RULES_OK" in out


@pytest.mark.slow
def test_elastic_restore_across_meshes():
    """Checkpoint written on a (2,4) mesh restores onto (8,1) and (1,8)."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint.store import save_pytree, load_pytree
        from repro.configs import get_config
        from repro.distributed.sharding import param_shardings
        from repro.models.model import Model

        cfg = get_config("llama1-7b").reduced(d_model=64, num_layers=2, d_ff=128)
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        d = tempfile.mkdtemp()

        mesh_a = jax.make_mesh((2, 4), ("data", "model"))
        sh_a = param_shardings(mesh_a, cfg, params, "train")
        params_a = jax.device_put(params, sh_a)
        save_pytree(d + "/ck", params_a)

        mesh_b = jax.make_mesh((1, 8), ("data", "model"))
        sh_b = param_shardings(mesh_b, cfg, params, "train")
        params_b = load_pytree(d + "/ck", params, shardings=sh_b)
        w0 = np.asarray(jax.device_get(params["blocks"]["mlp"]["wg"]["w"]))
        wb = np.asarray(jax.device_get(params_b["blocks"]["mlp"]["wg"]["w"]))
        assert np.array_equal(w0, wb)
        print("ELASTIC_OK")
    """)
    assert "ELASTIC_OK" in out
