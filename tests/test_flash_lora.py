"""Flash attention vs dense oracle (shapes/dtypes sweep) + LoRA adapters."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.flash import flash_attention
from repro.models.layers import _sdpa, default_positions


def _qkv(B, Sq, Skv, KV, G, hd, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, Sq, KV, G, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, Skv, KV, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, Skv, KV, hd), jnp.float32).astype(dtype)
    return q, k, v


class TestFlash:
    @pytest.mark.parametrize("dims", [(1, 32, 1, 1, 8), (2, 64, 2, 4, 16),
                                      (2, 128, 4, 1, 32)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("chunk", [8, 32])
    def test_causal_vs_dense(self, dims, dtype, chunk):
        B, S, KV, G, hd = dims
        q, k, v = _qkv(B, S, S, KV, G, hd, dtype)
        pos = default_positions(B, S)
        mask = pos[:, None, :] <= pos[:, :, None]
        scale = 1.0 / np.sqrt(hd)
        want = _sdpa(q, k, v, mask, scale)
        got = flash_attention(q, k, v, pos, pos, scale, chunk)
        tol = 1e-5 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=tol, atol=tol)

    def test_bidirectional(self):
        B, S, KV, G, hd = 2, 64, 2, 2, 16
        q, k, v = _qkv(B, S, S, KV, G, hd, jnp.float32)
        scale = 1.0 / np.sqrt(hd)
        want = _sdpa(q, k, v, None, scale)
        got = flash_attention(q, k, v, None, None, scale, 16)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_gradients_match(self):
        B, S, KV, G, hd = 2, 32, 2, 2, 8
        q, k, v = _qkv(B, S, S, KV, G, hd, jnp.float32)
        pos = default_positions(B, S)
        mask = pos[:, None, :] <= pos[:, :, None]
        scale = 1.0 / np.sqrt(hd)
        gf = jax.grad(lambda *a: jnp.sum(flash_attention(*a, pos, pos, scale, 8) ** 2),
                      argnums=(0, 1, 2))(q, k, v)
        gr = jax.grad(lambda *a: jnp.sum(_sdpa(*a, mask, scale) ** 2),
                      argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(gf, gr):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-3, atol=1e-4)


class TestLoRA:
    def _model(self):
        from repro.configs import get_config
        from repro.models.model import Model
        cfg = get_config("llama1-7b").reduced(num_layers=2, d_model=64, d_ff=128)
        model = Model(cfg)
        return model, model.init(jax.random.PRNGKey(0))

    def test_zero_init_is_identity(self):
        from repro.core.lora import add_lora
        model, params = self._model()
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                  model.cfg.vocab_size)
        l0, _ = model.forward(params, {"tokens": toks})
        lp = add_lora(params, jax.random.PRNGKey(2), rank=4)
        l1, _ = model.forward(lp, {"tokens": toks})
        np.testing.assert_allclose(np.asarray(l0), np.asarray(l1), atol=1e-6)

    def test_merge_matches_adapter_forward(self):
        from repro.core.lora import add_lora, merge_lora
        model, params = self._model()
        lp = add_lora(params, jax.random.PRNGKey(2), rank=4)
        # make B nonzero so the adapters actually do something
        lp["blocks"]["attn"]["wq"]["lora_b"] = 0.01 * jax.random.normal(
            jax.random.PRNGKey(3), lp["blocks"]["attn"]["wq"]["lora_b"].shape)
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                  model.cfg.vocab_size)
        l_adapter, _ = model.forward(lp, {"tokens": toks})
        merged = merge_lora(lp)
        assert "lora_a" not in merged["blocks"]["attn"]["wq"]
        l_merged, _ = model.forward(merged, {"tokens": toks})
        np.testing.assert_allclose(np.asarray(l_adapter), np.asarray(l_merged),
                                   rtol=1e-4, atol=1e-5)

    def test_trainable_mask_only_lora(self):
        from repro.core.lora import add_lora, lora_trainable
        model, params = self._model()
        lp = add_lora(params, jax.random.PRNGKey(2), rank=4)
        tr = lora_trainable(lp)
        flags = [(any("lora" in str(k) for k in path), v) for path, v in
                 jax.tree_util.tree_flatten_with_path(tr)[0]]
        for is_lora, v in flags:
            assert v == is_lora
