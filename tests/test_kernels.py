"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret=True)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.masks import nm_mask as core_nm
from repro.kernels import ops, ref


def _rand(shape, dtype, seed=0):
    x = jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)
    return x.astype(dtype)


class TestNMMaskKernel:
    @pytest.mark.parametrize("shape", [(8, 16), (64, 128), (256, 512), (128, 1024)])
    @pytest.mark.parametrize("nm", [(2, 4), (4, 8)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_vs_ref(self, shape, nm, dtype):
        n, m = nm
        w = _rand(shape, dtype, 1)
        xn = jnp.abs(_rand((shape[1],), jnp.float32, 2))
        g = jnp.abs(_rand(shape, jnp.float32, 3))
        got = ops.nm_mask(w, xn, g, alpha=100.0, n=n, m=m)
        want = ref.nm_mask_ref(w, xn, g, alpha=100.0, n=n, m=m)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_no_grad_variant(self):
        w = _rand((64, 64), jnp.float32, 1)
        xn = jnp.abs(_rand((64,), jnp.float32, 2))
        got = ops.nm_mask(w, xn, None)
        want = ref.nm_mask_ref(w, xn, None)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_matches_core_mask(self):
        """Kernel == core/masks.py == what the pruner applies."""
        w = _rand((32, 64), jnp.float32, 5)
        xn = jnp.abs(_rand((64,), jnp.float32, 6))
        from repro.core.scores import wanda_score
        s = wanda_score(w, xn)
        np.testing.assert_array_equal(
            np.asarray(core_nm(s, 2, 4)).astype(np.int8),
            np.asarray(ops.nm_mask(w, xn, None)))


class TestSparseMatmul24:
    @pytest.mark.parametrize("mkn", [(4, 128, 128), (128, 256, 128),
                                     (256, 512, 256), (64, 1024, 512)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_vs_ref(self, mkn, dtype):
        M, K, N = mkn
        w = _rand((K, N), dtype, 1)
        mask = core_nm(jnp.abs(w.astype(jnp.float32).T), 2, 4).T
        ws = jnp.where(mask, w, 0)
        vals, idx = ops.compact24(ws)
        x = _rand((M, K), dtype, 2)
        got = ops.sparse_matmul24(x, vals, idx)
        want = ref.sparse_matmul24_ref(x, vals, idx)
        tol = 1e-4 if dtype == jnp.float32 else 5e-2
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=tol, atol=tol)

    def test_compact_roundtrip(self):
        w = _rand((512, 128), jnp.float32, 3)
        mask = core_nm(jnp.abs(w.T), 2, 4).T
        ws = jnp.where(mask, w, 0)
        assert ops.sparsity_check24(ws)
        vals, idx = ops.compact24(ws)
        # idx packs four 2-bit entries per byte: (K/8, N) uint8
        assert vals.shape == (256, 128)
        assert idx.shape == (64, 128) and idx.dtype == jnp.uint8
        np.testing.assert_allclose(
            np.asarray(ref.decompress24_ref(vals, idx, 512)), np.asarray(ws))
        # compare-select decompression is BIT-exact (scatter oracle above,
        # +0.0 zeros like the pruner's jnp.where)
        assert np.array_equal(np.asarray(ops.decompress24(vals, idx)),
                              np.asarray(ws))

    def test_equals_dense_matmul(self):
        """Compacted path == dense matmul on the sparse weights."""
        w = _rand((256, 128), jnp.float32, 4)
        mask = core_nm(jnp.abs(w.T), 2, 4).T
        ws = jnp.where(mask, w, 0)
        vals, idx = ops.compact24(ws)
        x = _rand((32, 256), jnp.float32, 5)
        np.testing.assert_allclose(np.asarray(ops.sparse_matmul24(x, vals, idx)),
                                   np.asarray(x @ ws), rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("M", [1, 5, 130])
    def test_ragged_m(self, M):
        """Decode batch widths need not divide block_m: pad/slice wrapper."""
        w = _rand((128, 128), jnp.float32, 6)
        mask = core_nm(jnp.abs(w.T), 2, 4).T
        ws = jnp.where(mask, w, 0)
        vals, idx = ops.compact24(ws)
        x = _rand((M, 128), jnp.float32, 7)
        np.testing.assert_allclose(np.asarray(ops.sparse_matmul24(x, vals, idx)),
                                   np.asarray(x @ ws), rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_returns_input_dtype(self, dtype):
        """No silent f32 upcast of bf16 serve activations."""
        w = _rand((128, 128), dtype, 8)
        mask = core_nm(jnp.abs(w.astype(jnp.float32).T), 2, 4).T
        vals, idx = ops.compact24(jnp.where(mask, w, 0))
        y = ops.sparse_matmul24(_rand((8, 128), dtype, 9), vals, idx)
        assert y.dtype == dtype

    def test_fused_bias(self):
        w = _rand((128, 256), jnp.float32, 10)
        mask = core_nm(jnp.abs(w.T), 2, 4).T
        ws = jnp.where(mask, w, 0)
        vals, idx = ops.compact24(ws)
        b = _rand((256,), jnp.float32, 11)
        x = _rand((16, 128), jnp.float32, 12)
        got = ops.sparse_matmul24(x, vals, idx, bias=b)
        np.testing.assert_allclose(np.asarray(got), np.asarray(x @ ws + b),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(ref.sparse_matmul24_ref(x, vals, idx,
                                                                bias=b)),
            rtol=1e-5, atol=1e-5)

    def test_int8_weight_dequant(self):
        """int8 vals dequantize in-kernel (w_qscale), like kv_qscale in
        paged_attention: int8 quant stacks on top of the 2:4 compaction."""
        rng = np.random.default_rng(13)
        K, N, scale = 128, 128, 16.0
        v8 = rng.integers(-127, 128, (K // 2, N)).astype(np.int8)
        idx2 = np.stack([rng.permutation(4)[:2] for _ in range(K // 2 // 2 * N)]
                        ).reshape(K // 4, N, 2).transpose(0, 2, 1)
        idx2 = np.sort(idx2, axis=1).reshape(K // 2, N)
        packed = ops._pack24_idx(jnp.asarray(idx2))
        vals = jnp.asarray(v8)
        x = _rand((8, K), jnp.float32, 14)
        got = ops.sparse_matmul24(x, vals, packed, w_qscale=scale)
        want = ref.sparse_matmul24_ref(x, vals, packed, w_qscale=scale)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)


class TestMaskedMatmul:
    @pytest.mark.parametrize("mkn", [(128, 512, 256), (8, 128, 128)])
    def test_vs_ref(self, mkn):
        M, K, N = mkn
        x = _rand((M, K), jnp.float32, 1)
        w = _rand((K, N), jnp.float32, 2)
        mask = core_nm(jnp.abs(w.T), 2, 4).T
        got = ops.masked_matmul(x, w, mask)
        want = ref.masked_matmul_ref(x, w, mask)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
