"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret=True)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.masks import nm_mask as core_nm
from repro.kernels import ops, ref


def _rand(shape, dtype, seed=0):
    x = jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)
    return x.astype(dtype)


class TestNMMaskKernel:
    @pytest.mark.parametrize("shape", [(8, 16), (64, 128), (256, 512), (128, 1024)])
    @pytest.mark.parametrize("nm", [(2, 4), (4, 8)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_vs_ref(self, shape, nm, dtype):
        n, m = nm
        w = _rand(shape, dtype, 1)
        xn = jnp.abs(_rand((shape[1],), jnp.float32, 2))
        g = jnp.abs(_rand(shape, jnp.float32, 3))
        got = ops.nm_mask(w, xn, g, alpha=100.0, n=n, m=m)
        want = ref.nm_mask_ref(w, xn, g, alpha=100.0, n=n, m=m)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_no_grad_variant(self):
        w = _rand((64, 64), jnp.float32, 1)
        xn = jnp.abs(_rand((64,), jnp.float32, 2))
        got = ops.nm_mask(w, xn, None)
        want = ref.nm_mask_ref(w, xn, None)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_matches_core_mask(self):
        """Kernel == core/masks.py == what the pruner applies."""
        w = _rand((32, 64), jnp.float32, 5)
        xn = jnp.abs(_rand((64,), jnp.float32, 6))
        from repro.core.scores import wanda_score
        s = wanda_score(w, xn)
        np.testing.assert_array_equal(
            np.asarray(core_nm(s, 2, 4)).astype(np.int8),
            np.asarray(ops.nm_mask(w, xn, None)))


class TestSparseMatmul24:
    @pytest.mark.parametrize("mkn", [(4, 128, 128), (128, 256, 128),
                                     (256, 512, 256), (64, 1024, 512)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_vs_ref(self, mkn, dtype):
        M, K, N = mkn
        w = _rand((K, N), dtype, 1)
        mask = core_nm(jnp.abs(w.astype(jnp.float32).T), 2, 4).T
        ws = jnp.where(mask, w, 0)
        vals, idx = ops.compact24(ws)
        x = _rand((M, K), dtype, 2)
        got = ops.sparse_matmul24(x, vals, idx)
        want = ref.sparse_matmul24_ref(x, vals, idx)
        tol = 1e-4 if dtype == jnp.float32 else 5e-2
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=tol, atol=tol)

    def test_compact_roundtrip(self):
        w = _rand((512, 128), jnp.float32, 3)
        mask = core_nm(jnp.abs(w.T), 2, 4).T
        ws = jnp.where(mask, w, 0)
        assert ops.sparsity_check24(ws)
        vals, idx = ops.compact24(ws)
        assert vals.shape == (256, 128) and idx.dtype == jnp.int8
        np.testing.assert_allclose(
            np.asarray(ref.decompress24_ref(vals, idx, 512)), np.asarray(ws))

    def test_equals_dense_matmul(self):
        """Compacted path == dense matmul on the sparse weights."""
        w = _rand((256, 128), jnp.float32, 4)
        mask = core_nm(jnp.abs(w.T), 2, 4).T
        ws = jnp.where(mask, w, 0)
        vals, idx = ops.compact24(ws)
        x = _rand((32, 256), jnp.float32, 5)
        np.testing.assert_allclose(np.asarray(ops.sparse_matmul24(x, vals, idx)),
                                   np.asarray(x @ ws), rtol=1e-4, atol=1e-4)


class TestMaskedMatmul:
    @pytest.mark.parametrize("mkn", [(128, 512, 256), (8, 128, 128)])
    def test_vs_ref(self, mkn):
        M, K, N = mkn
        x = _rand((M, K), jnp.float32, 1)
        w = _rand((K, N), jnp.float32, 2)
        mask = core_nm(jnp.abs(w.T), 2, 4).T
        got = ops.masked_matmul(x, w, mask)
        want = ref.masked_matmul_ref(x, w, mask)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
