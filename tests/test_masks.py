"""Mask + score unit and property tests (hypothesis, optional — see shim)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import masks as M
from repro.core import scores as SC

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


class TestNM:
    @pytest.mark.parametrize("n,m", [(2, 4), (4, 8), (1, 4), (3, 8)])
    def test_exact_n_of_m(self, n, m):
        s = jnp.asarray(np.random.default_rng(0).normal(size=(64, 32 * m)))
        mask = M.nm_mask(s, n, m)
        counts = mask.reshape(64, -1, m).sum(-1)
        assert (counts == n).all()

    def test_keeps_top_scores(self):
        s = jnp.asarray([[9.0, 1.0, 8.0, 2.0, 0.1, 0.2, 0.4, 0.3]])
        mask = M.nm_mask(s, 2, 4)
        np.testing.assert_array_equal(
            np.asarray(mask[0]), [1, 0, 1, 0, 0, 0, 1, 1])

    def test_ties_exact_count(self):
        s = jnp.ones((8, 16))  # all equal: tie-break by index must hold
        mask = M.nm_mask(s, 2, 4)
        assert (mask.reshape(8, 4, 4).sum(-1) == 2).all()

    @given(st.integers(0, 2 ** 31 - 1), st.sampled_from([(2, 4), (4, 8)]))
    def test_property_counts(self, seed, nm):
        n, m = nm
        s = jnp.asarray(np.random.default_rng(seed).normal(size=(16, 8 * m)))
        mask = M.nm_mask(s, n, m)
        assert (mask.reshape(16, -1, m).sum(-1) == n).all()

    @given(st.integers(0, 2 ** 31 - 1))
    def test_property_monotone(self, seed):
        """Raising one kept weight's score never unkeeps it."""
        rng = np.random.default_rng(seed)
        s = rng.normal(size=(4, 16)) ** 2
        mask = np.asarray(M.nm_mask(jnp.asarray(s), 2, 4)).astype(bool)
        i, j = rng.integers(4), rng.integers(16)
        if mask[i, j]:
            s2 = s.copy()
            s2[i, j] += 10.0
            mask2 = np.asarray(M.nm_mask(jnp.asarray(s2), 2, 4)).astype(bool)
            assert mask2[i, j]


class TestUnstructured:
    @given(st.integers(0, 2 ** 31 - 1),
           st.sampled_from([0.25, 0.5, 0.6, 0.7, 0.8]))
    def test_row_sparsity(self, seed, sp):
        s = jnp.asarray(np.random.default_rng(seed).normal(size=(32, 128)))
        mask = M.unstructured_mask(s, sp)
        keep = int(round(128 * (1 - sp)))
        assert (mask.sum(-1) == keep).all()


class TestRow:
    def test_row_structured(self):
        s = jnp.asarray(np.random.default_rng(0).normal(size=(64, 32)))
        mask = M.row_mask(s, 0.5)
        rows = np.asarray(mask).all(axis=1) | (~np.asarray(mask)).all(axis=1)
        assert rows.all()  # every row fully kept or fully dropped
        assert np.asarray(mask).all(axis=1).sum() == 32


class TestScores:
    def test_wanda_matches_paper_eq1(self):
        w = jnp.asarray([[1.0, -2.0], [3.0, 0.5]])  # (out, in)
        xn = jnp.asarray([2.0, 1.0])
        s = SC.wanda_score(w, xn)
        np.testing.assert_allclose(np.asarray(s), [[2.0, 2.0], [6.0, 0.5]])

    def test_rgs_alpha_blend(self):
        w = jnp.ones((2, 2))
        xn = jnp.zeros(2)
        g = jnp.asarray([[1.0, 2.0], [3.0, 4.0]])
        s = SC.rgs_score(w, xn, g, alpha=100.0)
        np.testing.assert_allclose(np.asarray(s), 100.0 * np.asarray(g))

    def test_to_oi_roundtrip(self):
        w = jnp.arange(24).reshape(2, 3, 4).astype(jnp.float32)
        assert (SC.from_oi(SC.to_oi(w)) == w).all()
