"""Per-architecture smoke tests (deliverable f) + model-level invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models.model import Model


def _batch(cfg, key, Bsz=2, S=32):
    if cfg.family == "audio":
        return {"frames": jax.random.normal(key, (Bsz, S, cfg.d_model)),
                "labels": jnp.zeros((Bsz, S), jnp.int32),
                "mask": jnp.ones((Bsz, S), bool)}
    if cfg.family == "vlm":
        P = cfg.vision_patches
        return {"vision_embeds": jax.random.normal(key, (Bsz, P, cfg.d_model)),
                "tokens": jnp.ones((Bsz, S - P), jnp.int32),
                "labels": jnp.ones((Bsz, S - P), jnp.int32)}
    toks = jax.random.randint(key, (Bsz, S), 0, cfg.vocab_size)
    return {"tokens": toks, "labels": toks}


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS + ["llama1-7b"])
def test_arch_smoke(arch):
    """Reduced config: forward + one train step on CPU; shapes + no NaNs."""
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))

    logits, aux = jax.jit(model.forward)(params, batch)
    S_exp = 32
    assert logits.shape[0] == 2 and logits.shape[-1] == cfg.vocab_size
    assert bool(jnp.isfinite(logits).all()), "NaN/Inf in logits"

    from repro.configs.base import TrainConfig
    from repro.launch.steps import init_train_state, make_train_step
    tc = TrainConfig(total_steps=2, warmup_steps=1, remat=True)
    step = jax.jit(make_train_step(model, tc))
    state = init_train_state(model, params, tc)
    state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"])), "NaN loss"
    assert int(state["step"]) == 1


@pytest.mark.parametrize("arch", ["qwen3-8b", "deepseek-moe-16b",
                                  "mamba2-1.3b", "zamba2-7b", "qwen2-vl-2b"])
def test_decode_matches_forward(arch):
    """Incremental decode with cache == full forward, token by token."""
    import dataclasses
    cfg = get_config(arch).reduced()
    if cfg.family == "moe":
        # make routing dropless (cf = E/k): prefill capacity-drops are a real
        # GShard semantic that single-token decode cannot reproduce
        cfg = dataclasses.replace(
            cfg, moe_capacity_factor=cfg.num_experts / cfg.top_k)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    Bsz, S = 2, 8
    key = jax.random.PRNGKey(1)

    if cfg.family == "vlm":
        pytest.skip("vlm decode continues a vision-prefixed seq; covered in smoke")
    toks = jax.random.randint(key, (Bsz, S), 0, cfg.vocab_size)
    logits_full, _ = model.forward(params, {"tokens": toks})

    cache = model.init_cache(Bsz, S)
    dec = jax.jit(lambda p, i, c: model.decode_step(p, i, c))
    outs = []
    for t in range(S):
        lg, cache = dec(params, {"token": toks[:, t], "pos": jnp.int32(t)}, cache)
        outs.append(lg)
    logits_inc = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(logits_inc), np.asarray(logits_full),
                               rtol=2e-2, atol=2e-3)


def test_mamba_chunked_equals_tiny_chunks():
    """SSD chunked scan is chunk-size invariant (Q=4 vs Q=S)."""
    import dataclasses
    cfg = get_config("mamba2-1.3b").reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
    l1, _ = model.forward(params, {"tokens": toks})
    cfg2 = dataclasses.replace(cfg, ssm_chunk=4)
    l2, _ = Model(cfg2).forward(params, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-4,
                               atol=1e-4)


def test_flash_vs_dense_attention_in_model():
    """Force the flash path (low threshold) and compare to dense SDPA."""
    from repro.models import layers
    cfg = get_config("qwen3-8b").reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, cfg.vocab_size)
    dense_logits, _ = model.forward(params, {"tokens": toks})
    old = layers.FLASH_MIN_SEQ
    try:
        layers.FLASH_MIN_SEQ = 16
        flash_logits, _ = model.forward(params, {"tokens": toks})
    finally:
        layers.FLASH_MIN_SEQ = old
    np.testing.assert_allclose(np.asarray(dense_logits),
                               np.asarray(flash_logits), rtol=1e-3, atol=1e-3)


def test_param_count_matches_init():
    for arch in ["qwen3-8b", "deepseek-moe-16b", "mamba2-1.3b"]:
        cfg = get_config(arch).reduced()
        model = Model(cfg)
        shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        n_init = sum(int(np.prod(l.shape))
                     for l in jax.tree_util.tree_leaves(shapes))
        n_analytic = cfg.param_count()
        # analytic excludes norms/1-D params: allow 5% slack
        assert abs(n_init - n_analytic) / n_init < 0.05, (arch, n_init, n_analytic)


def test_hybrid_shared_block_actually_shared():
    """Zamba2: exactly one shared attn block in the params."""
    cfg = get_config("zamba2-7b").reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    assert "shared_attn" in params
    assert params["shared_attn"]["attn"]["wq"]["w"].ndim == 2  # unstacked
