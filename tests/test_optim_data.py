"""Optimizers, schedules, gradient compression, synthetic data pipeline."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, strategies as st

from repro.configs.base import TrainConfig
from repro.data.calibration import SyntheticLM, synthetic_lm_stream
from repro.optim import (adamw_init, adamw_update, clip_by_global_norm,
                         cosine_warmup, topk_compress_update)

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")


class TestAdamW:
    def test_converges_quadratic(self):
        tc = TrainConfig(learning_rate=0.1, weight_decay=0.0)
        params = {"w": jnp.asarray([5.0, -3.0])}
        state = adamw_init(params)
        f = lambda p: jnp.sum((p["w"] - 1.0) ** 2)
        for _ in range(300):
            g = jax.grad(f)(params)
            params, state = adamw_update(params, g, state, tc, 0.1)
        assert float(f(params)) < 1e-3

    def test_trainable_filter_freezes(self):
        tc = TrainConfig()
        params = {"a": jnp.ones(3), "b": jnp.ones(3)}
        state = adamw_init(params)
        g = {"a": jnp.ones(3), "b": jnp.ones(3)}
        new, _ = adamw_update(params, g, state, tc, 0.1,
                              trainable={"a": True, "b": False})
        assert not np.allclose(np.asarray(new["a"]), 1.0)
        np.testing.assert_array_equal(np.asarray(new["b"]), 1.0)

    def test_grad_mask_preserves_sparsity(self):
        tc = TrainConfig(weight_decay=0.0)
        w = jnp.asarray([1.0, 0.0, 2.0, 0.0])
        mask = (w != 0)
        params = {"w": w}
        state = adamw_init(params)
        for i in range(5):
            g = {"w": jnp.ones(4)}
            params, state = adamw_update(params, g, state, tc, 0.1,
                                         grad_mask={"w": mask})
        np.testing.assert_array_equal(np.asarray(params["w"][1::2]), 0.0)

    def test_bf16_states(self):
        params = {"w": jnp.ones(4)}
        st_ = adamw_init(params, jnp.bfloat16)
        assert st_["mu"]["w"].dtype == jnp.bfloat16


class TestClipSchedule:
    @given(st.floats(0.1, 10.0))
    def test_clip_norm_bound(self, max_norm):
        g = {"w": jnp.full((10,), 5.0)}
        clipped, gn = clip_by_global_norm(g, max_norm)
        new_norm = float(jnp.linalg.norm(clipped["w"]))
        assert new_norm <= max_norm * 1.01

    def test_cosine_warmup_shape(self):
        lrs = [float(cosine_warmup(jnp.asarray(s), 1.0, 10, 100))
               for s in range(100)]
        assert lrs[0] < lrs[9]            # warmup rises
        assert lrs[15] > lrs[90]          # cosine decays
        assert min(lrs) >= 0.099          # min_frac floor


class TestCompression:
    def test_error_feedback_conserves_mass(self):
        g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=64)
                              .astype(np.float32))}
        comp, err = topk_compress_update(g, None, ratio=0.25)
        # compressed + error == original (nothing lost)
        total = comp["w"].astype(jnp.float32) + err["w"]
        np.testing.assert_allclose(np.asarray(total), np.asarray(g["w"]),
                                   rtol=1e-6)
        nz = float((comp["w"] != 0).mean())
        assert nz <= 0.3

    def test_error_accumulates_into_next_step(self):
        g = {"w": jnp.asarray([1.0, 0.1, 0.1, 0.1])}
        comp1, err1 = topk_compress_update(g, None, ratio=0.25)
        # small entries deferred...
        assert float(err1["w"][1]) != 0.0
        comp2, _ = topk_compress_update(g, err1, ratio=0.25)
        # ...and eventually sent (error feedback grows them)
        assert float(jnp.abs(comp2["w"][1:]).max()) >= 0.0


class TestData:
    def test_deterministic(self):
        a = SyntheticLM(256, seed=1).sample(4, 32, stream_seed=5)
        b = SyntheticLM(256, seed=1).sample(4, 32, stream_seed=5)
        np.testing.assert_array_equal(a, b)

    def test_streams_disjoint(self):
        a = SyntheticLM(256, seed=1).sample(4, 32, stream_seed=1)
        b = SyntheticLM(256, seed=1).sample(4, 32, stream_seed=2)
        assert not np.array_equal(a, b)

    def test_skip_ahead_replays_exactly(self):
        s1 = synthetic_lm_stream(256, 2, 16, seed=0, start_step=0)
        batches = [next(s1) for _ in range(5)]
        s2 = synthetic_lm_stream(256, 2, 16, seed=0, start_step=3)
        b3 = next(s2)
        np.testing.assert_array_equal(np.asarray(batches[3]["tokens"]),
                                      np.asarray(b3["tokens"]))

    def test_zipfian_unigrams(self):
        toks = SyntheticLM(512, seed=0).sample(64, 128)
        counts = np.bincount(toks.ravel(), minlength=512)
        # head tokens much more frequent than tail
        assert counts[:16].sum() > 5 * counts[-256:].sum()
