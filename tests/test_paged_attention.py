"""Pallas paged-attention decode kernel: hardened parity suite.

Three layers of evidence that the kernel (kernels/paged_attention.py) can
be THE paged decode path:

  1. property parity (via the optional-hypothesis shim): kernel vs the
     retained gather reference and vs the dense ``_sdpa`` oracle, sweeping
     page sizes, GQA group counts, ragged per-slot lengths (incl. 0 and
     == capacity), fp32 and int8 arenas, unmapped (frozen-slot) tables;
  2. model-level: ``decode_step(paged_kernel=True)`` logits track the
     gather path within fp32 reassociation noise;
  3. end-to-end: a greedy ``Engine`` decode is BIT-EXACT (token-for-token)
     kernel vs gather, for the one-wave path and a mixed-length
     continuous-batching stream, fp32 and int8 KV, two page sizes.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, st
from repro.configs import get_config
from repro.kernels import ops, ref
from repro.models.layers import KV_QSCALE, _sdpa
from repro.models.model import Model
from repro.serve import Engine, EngineConfig, Request
from repro.serve.scheduler import Scheduler

SCALE = 0.25


@pytest.fixture(scope="module")
def gqa():
    # num_kv_heads=2 => G=2: the e2e tests must exercise grouped queries
    cfg = get_config("qwen3-8b").reduced(num_kv_heads=2)
    model = Model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


def _prompts(cfg, B, P, seed=1):
    return np.asarray(jax.random.randint(
        jax.random.PRNGKey(seed), (B, P), 0, cfg.vocab_size), np.int32)


def _case(seed, ps, G, *, KV=2, hd=8, MB=4, int8=False):
    """Random paged-decode instance with ragged lengths: row 0 is empty,
    row 1 holds a single token, row 2 is at full capacity, the rest are
    random; block tables map disjoint random pages, rest unmapped."""
    rng = np.random.default_rng(seed)
    B = 5
    cap = MB * ps
    n_pages = B * MB + 3
    lengths = np.array(
        [0, 1, cap] + list(rng.integers(1, cap + 1, B - 3)), np.int64)
    perm = rng.permutation(n_pages)
    bt = np.full((B, MB), n_pages, np.int64)
    k = 0
    for b in range(B):
        nb = -(-int(lengths[b]) // ps)
        bt[b, :nb] = perm[k:k + nb]
        k += nb
    if int8:
        k_pages = rng.integers(-127, 128, (n_pages, ps, KV, hd))
        v_pages = rng.integers(-127, 128, (n_pages, ps, KV, hd))
        k_pages = jnp.asarray(k_pages, jnp.int8)
        v_pages = jnp.asarray(v_pages, jnp.int8)
    else:
        k_pages = jnp.asarray(rng.normal(size=(n_pages, ps, KV, hd)),
                              jnp.float32)
        v_pages = jnp.asarray(rng.normal(size=(n_pages, ps, KV, hd)),
                              jnp.float32)
    q = jnp.asarray(rng.normal(size=(B, KV, G, hd)), jnp.float32)
    return (q, k_pages, v_pages, jnp.asarray(bt, jnp.int32),
            jnp.asarray(lengths, jnp.int32))


def _check(q, k_pages, v_pages, bt, lengths, kv_qscale=None):
    got = ops.paged_attention(q, k_pages, v_pages, bt, lengths,
                              scale=SCALE, kv_qscale=kv_qscale)
    want = ref.paged_attention_ref(q, k_pages, v_pages, bt, lengths,
                                   scale=SCALE, kv_qscale=kv_qscale)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    # tie the oracle itself to the production _sdpa on the gathered view
    B, KV, G, hd = q.shape
    n_pages, ps = k_pages.shape[:2]
    MB = bt.shape[1]
    k_full = k_pages.at[bt].get(mode="fill", fill_value=0)
    v_full = v_pages.at[bt].get(mode="fill", fill_value=0)
    k_full = k_full.reshape(B, MB * ps, KV, hd).astype(jnp.float32)
    v_full = v_full.reshape(B, MB * ps, KV, hd).astype(jnp.float32)
    if kv_qscale is not None:
        k_full = k_full / kv_qscale
        v_full = v_full / kv_qscale
    mask = (jnp.arange(MB * ps)[None, :] < lengths[:, None])[:, None, :]
    sdpa = _sdpa(q[:, None], k_full, v_full, mask, SCALE)[:, 0]
    live = np.asarray(lengths) > 0
    np.testing.assert_allclose(np.asarray(got)[live],
                               np.asarray(sdpa)[live],
                               rtol=2e-5, atol=2e-5)
    return got


# ---------------------------------------------------------------------------
# property parity: kernel vs gather reference vs dense _sdpa
# ---------------------------------------------------------------------------

@given(st.sampled_from([4, 8, 16]), st.sampled_from([1, 2, 4]),
       st.integers(0, 10_000))
def test_parity_fp32(ps, G, seed):
    _check(*_case(seed, ps, G))


@given(st.sampled_from([4, 8]), st.sampled_from([1, 4]),
       st.integers(0, 10_000))
def test_parity_int8(ps, G, seed):
    q, k8, v8, bt, lengths = _case(seed, ps, G, int8=True)
    _check(q, k8, v8, bt, lengths, kv_qscale=KV_QSCALE)
    # int8 vs the fp32 values it quantized: within dequant tolerance
    kf = (k8.astype(jnp.float32) / KV_QSCALE)
    vf = (v8.astype(jnp.float32) / KV_QSCALE)
    got8 = ops.paged_attention(q, k8, v8, bt, lengths,
                               scale=SCALE, kv_qscale=KV_QSCALE)
    gotf = ops.paged_attention(q, kf, vf, bt, lengths, scale=SCALE)
    np.testing.assert_allclose(np.asarray(got8), np.asarray(gotf),
                               rtol=2e-5, atol=2e-5)


def test_length_zero_rows_are_zero():
    q, kp, vp, bt, lengths = _case(0, 8, 2)
    got = np.asarray(ops.paged_attention(q, kp, vp, bt, lengths, scale=SCALE))
    assert (got[np.asarray(lengths) == 0] == 0).all()
    assert np.isfinite(got).all()


def test_unmapped_blocks_read_as_zero_kv():
    """Frozen-slot semantics: a fully-unmapped table with length > 0 must
    reproduce the gather's mode="fill" zeros (logit 0 enters the softmax,
    the page is NOT skipped)."""
    q, kp, vp, bt, lengths = _case(3, 4, 2)
    B, MB = bt.shape
    n_pages = kp.shape[0]
    bt_frozen = jnp.full_like(bt, n_pages)  # released slot: table cleared
    lengths = jnp.maximum(lengths, 1)
    got = ops.paged_attention(q, kp, vp, bt_frozen, lengths, scale=SCALE)
    want = ref.paged_attention_ref(q, kp, vp, bt_frozen, lengths, scale=SCALE)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    # zero K everywhere -> uniform weights over the valid positions -> 0 V
    assert np.abs(np.asarray(got)).max() < 1e-6


def test_partially_unmapped_table_matches_gather():
    """A table whose tail blocks are unmapped while lengths reach into
    them (the drop-write region of a frozen slot mid-table)."""
    q, kp, vp, bt, lengths = _case(7, 4, 1)
    n_pages = kp.shape[0]
    bt = bt.at[:, 2:].set(n_pages)  # unmap blocks 2+; lengths unchanged
    got = ops.paged_attention(q, kp, vp, bt, lengths, scale=SCALE)
    want = ref.paged_attention_ref(q, kp, vp, bt, lengths, scale=SCALE)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# model-level: decode_step kernel vs gather
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kv_dtype", [None, "int8"])
def test_decode_step_kernel_tracks_gather(gqa, kv_dtype):
    base_model, params = gqa
    cfg = base_model.cfg
    model = Model(cfg, kv_dtype=kv_dtype)
    B, P, ps, MB = 3, 8, 4, 4
    toks = jnp.asarray(_prompts(cfg, B, P, seed=5))
    _, _, (k_s, v_s) = model.forward(params, {"tokens": toks},
                                     return_cache=True)
    n_pages = B * MB
    pk, pv = model.init_paged_cache(n_pages, ps)
    if pk.dtype == jnp.int8:
        qz = lambda a: jnp.clip(jnp.round(a.astype(jnp.float32) * KV_QSCALE),
                                -127, 127).astype(jnp.int8)
        k_s, v_s = qz(k_s), qz(v_s)
    bt = jnp.arange(n_pages, dtype=jnp.int32).reshape(B, MB)
    pos = jnp.arange(P, dtype=jnp.int32)[None, :]
    page = jnp.take_along_axis(bt, jnp.broadcast_to(pos // ps, (B, P)), axis=1)
    off = jnp.broadcast_to(pos % ps, (B, P))
    pk = pk.at[:, page, off].set(k_s.astype(pk.dtype))
    pv = pv.at[:, page, off].set(v_s.astype(pv.dtype))
    inp = {"token": jnp.asarray([3, 7, 11], jnp.int32),
           "pos": jnp.full((B,), P, jnp.int32), "block_table": bt}
    lg_gather, _ = model.decode_step(params, inp, (pk, pv),
                                     paged_kernel=False)
    lg_kernel, _ = model.decode_step(params, inp, (pk, pv),
                                     paged_kernel=True)
    np.testing.assert_allclose(np.asarray(lg_kernel), np.asarray(lg_gather),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(
        np.asarray(jnp.argmax(lg_kernel, -1)),
        np.asarray(jnp.argmax(lg_gather, -1)))


# ---------------------------------------------------------------------------
# end-to-end: greedy Engine decode is bit-exact kernel vs gather
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kv_dtype", [None, "int8"])
@pytest.mark.parametrize("page_size", [4, 8])
def test_engine_generate_bitexact_kernel_vs_gather(gqa, kv_dtype, page_size):
    base_model, params = gqa
    cfg = base_model.cfg
    model = Model(cfg, kv_dtype=kv_dtype) if kv_dtype else base_model
    B, P, G = 4, 8, 6
    prompts = _prompts(cfg, B, P)
    mk = lambda kernel: Engine(
        model, params,
        EngineConfig(n_slots=B, max_len=32, chunk=G - 1, prefill_buckets=(P,),
                     paged=True, page_size=page_size, paged_kernel=kernel))
    out_k = mk(True).generate(prompts, G)
    out_g = mk(False).generate(prompts, G)
    np.testing.assert_array_equal(out_k, out_g)


def test_engine_stream_bitexact_kernel_vs_gather(gqa):
    """Mixed-length continuous-batching stream (slot churn, frozen slots,
    ragged per-slot positions): identical tokens kernel vs gather."""
    model, params = gqa
    cfg = model.cfg
    rng = np.random.default_rng(6)
    reqs = [Request(rid,
                    rng.integers(0, cfg.vocab_size,
                                 int(rng.integers(4, 14))).astype(np.int32),
                    int(rng.integers(1, 8)))
            for rid in range(9)]
    mk = lambda kernel: Engine(
        model, params,
        EngineConfig(n_slots=4, max_len=32, chunk=4, prefill_buckets=(8, 16),
                     paged=True, page_size=8, paged_kernel=kernel))
    out = {}
    for kernel in (False, True):
        comps = Scheduler(mk(kernel)).run(reqs)
        out[kernel] = {c.rid: list(c.tokens) for c in comps}
    assert out[True] == out[False]
