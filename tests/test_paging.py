"""Paged KV-cache pool: allocator properties, paged-vs-dense bit-exact
decode parity, shared-prefix reuse, page-exhaustion requeue — plus the
serve-path percentile and top-k tie fixes."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, st
from repro.configs import get_config
from repro.models.layers import KV_QSCALE
from repro.models.model import Model
from repro.serve import (Engine, EngineConfig, PagesExhausted, Request,
                         SamplingConfig, sample_tokens)
from repro.serve import paging as PAGE
from repro.serve.scheduler import Scheduler, percentile
from test_serve import assert_greedy_continuation


@pytest.fixture(scope="module")
def dense():
    cfg = get_config("qwen3-8b").reduced()
    model = Model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


def _prompts(cfg, B, P, seed=1):
    return np.asarray(jax.random.randint(
        jax.random.PRNGKey(seed), (B, P), 0, cfg.vocab_size), np.int32)


# ---------------------------------------------------------------------------
# percentile: nearest-rank ceil(p*n) (satellite: off-by-one fix)
# ---------------------------------------------------------------------------

def test_percentile_nearest_rank():
    xs = list(range(1, 21))  # 20 samples: 1..20
    assert percentile(xs, 0.50) == 10  # rank ceil(.5*20)=10 -> 10th value
    assert percentile(xs, 0.95) == 19  # NOT the max: rank 19, not 20
    assert percentile(xs, 1.00) == 20
    assert percentile(xs, 0.0) == 1
    assert percentile([], 0.95) == 0.0
    assert percentile([7.0], 0.95) == 7.0
    # 100 samples: p95 must be the 95th value, p50 the 50th
    ys = list(range(100))
    assert percentile(ys, 0.95) == 94
    assert percentile(ys, 0.50) == 49
    # 0.07 * 100 == 7.000000000000001 in floats: rank must still be 7
    assert percentile(list(range(1, 101)), 0.07) == 7


# ---------------------------------------------------------------------------
# top-k sampling: ties must not inflate k (satellite fix)
# ---------------------------------------------------------------------------

def test_topk_ties_mask_to_exactly_k():
    # every logit tied: candidate set must still be exactly top_k wide, and
    # lax.top_k's lowest-index tie-break makes it {0, 1, ..., k-1}
    logits = jnp.zeros((16, 32))
    sc = SamplingConfig(temperature=1.0, top_k=4)
    for s in range(8):
        toks = np.asarray(sample_tokens(logits, jax.random.PRNGKey(s), sc))
        assert (toks < 4).all(), f"tie leaked past top_k: {toks}"


def test_topk_partial_tie_with_kth_value():
    # top_k=2 over [5, 5, 5, 0, ...]: the k-th value (5) is tied with index 2,
    # which must be EXCLUDED — only indices {0, 1} may ever be sampled
    row = np.zeros(16, np.float32)
    row[:3] = 5.0
    logits = jnp.asarray(np.tile(row, (8, 1)))
    sc = SamplingConfig(temperature=1.0, top_k=2)
    seen = set()
    for s in range(16):
        toks = np.asarray(sample_tokens(logits, jax.random.PRNGKey(s), sc))
        seen.update(toks.tolist())
    assert seen <= {0, 1}, f"effective k exceeded top_k: sampled {seen}"
    # deterministic under a fixed key
    a = sample_tokens(logits, jax.random.PRNGKey(3), sc)
    b = sample_tokens(logits, jax.random.PRNGKey(3), sc)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# page allocator properties (via the optional-hypothesis shim)
# ---------------------------------------------------------------------------

def _rand_wave(rng, n_slots, max_blocks, k):
    slots = rng.choice(n_slots, size=k, replace=False).astype(np.int32)
    n_blocks = rng.integers(1, max_blocks + 1, size=k).astype(np.int32)
    return jnp.asarray(slots), jnp.asarray(n_blocks)


@given(st.integers(1, 4), st.integers(2, 5), st.integers(0, 1000))
def test_alloc_release_roundtrip_restores_free_count(n_slots, max_blocks, seed):
    rng = np.random.default_rng(seed)
    n_pages = n_slots * max_blocks
    state = PAGE.init_pages(n_pages, n_slots, max_blocks)
    k = int(rng.integers(1, n_slots + 1))
    slots, n_blocks = _rand_wave(rng, n_slots, max_blocks, k)
    state, ok = PAGE.alloc(state, slots, n_blocks)
    assert bool(ok)
    PAGE.check_invariants(state)
    used = int(np.asarray(n_blocks).sum())
    assert int(np.asarray((state.ref == 0).sum())) == n_pages - used
    # no page mapped twice across live slots (check_invariants also asserts
    # per-slot uniqueness and exact refcounts)
    bt = np.asarray(state.block_tables)
    mapped = bt[bt < n_pages]
    assert len(set(mapped.tolist())) == len(mapped)
    state = PAGE.release(state, slots)
    PAGE.check_invariants(state)
    assert int(np.asarray((state.ref == 0).sum())) == n_pages, \
        "release must return every page to the free list"
    assert (np.asarray(state.block_tables) == n_pages).all()


@given(st.integers(0, 500))
def test_alloc_exhaustion_leaves_state_unchanged(seed):
    rng = np.random.default_rng(seed)
    state = PAGE.init_pages(3, 4, 4)  # 3 pages, requests can want up to 8
    slots = jnp.asarray([0, 1], jnp.int32)
    n_blocks = jnp.asarray([int(rng.integers(1, 5)), 4], jnp.int32)
    before = jax.tree_util.tree_map(np.asarray, state)
    state, ok = PAGE.alloc(state, slots, n_blocks)
    if int(np.asarray(n_blocks).sum()) > 3:
        assert not bool(ok)
        after = jax.tree_util.tree_map(np.asarray, state)
        for a, b in zip(jax.tree_util.tree_leaves(before),
                        jax.tree_util.tree_leaves(after)):
            np.testing.assert_array_equal(a, b)
    else:
        assert bool(ok)
        PAGE.check_invariants(state)


def test_alloc_padding_rows_and_shared_refcounts():
    state = PAGE.init_pages(8, 4, 4)
    state, pages, ok = PAGE.reserve(state, 2)  # shared-prefix hold
    assert bool(ok)
    shared = jnp.asarray(np.asarray(pages), jnp.int32)
    # two real rows sharing the 2-page prefix + one padding row (slot 4)
    slots = jnp.asarray([0, 2, 4], jnp.int32)
    n_blocks = jnp.asarray([3, 4, 4], jnp.int32)
    n_shared = jnp.asarray([2, 2, 0], jnp.int32)
    state, ok = PAGE.alloc(state, slots, n_blocks, n_shared, shared)
    assert bool(ok)
    PAGE.check_invariants(state, shared_pages=np.asarray(pages))
    ref = np.asarray(state.ref)
    for p in np.asarray(pages):
        assert ref[p] == 3, "hold + two mappings"  # shared across live slots
    bt = np.asarray(state.block_tables)
    assert (bt[0][:2] == np.asarray(pages)).all()
    assert (bt[2][:2] == np.asarray(pages)).all()
    assert (bt[1] == 8).all() and (bt[3] == 8).all()  # untouched slots
    # padding row allocated nothing: 2 reserved + 1 + 2 fresh pages in use
    assert int((ref == 0).sum()) == 8 - 2 - 3
    state = PAGE.release(state, jnp.asarray([0, 2], jnp.int32))
    PAGE.check_invariants(state, shared_pages=np.asarray(pages))
    ref = np.asarray(state.ref)
    for p in np.asarray(pages):
        assert ref[p] == 1, "registry hold must survive slot release"
    assert int((ref == 0).sum()) == 6


# ---------------------------------------------------------------------------
# adversarial allocator: misuse must be a no-op or detectably wrong, never
# silent free-list corruption (see the invariant notes in serve/paging.py)
# ---------------------------------------------------------------------------

def test_double_release_is_a_noop():
    """Releasing a slot twice: the first release cleared its table rows, so
    the second decrement scatter drops entirely — refcounts and the free
    list are untouched."""
    state = PAGE.init_pages(8, 4, 2)
    state, ok = PAGE.alloc(state, jnp.asarray([0, 1], jnp.int32),
                           jnp.asarray([2, 2], jnp.int32))
    assert bool(ok)
    state = PAGE.release(state, jnp.asarray([0], jnp.int32))
    before = jax.tree_util.tree_map(np.asarray, state)
    state = PAGE.release(state, jnp.asarray([0], jnp.int32))  # double
    for a, b in zip(jax.tree_util.tree_leaves(before),
                    jax.tree_util.tree_leaves(
                        jax.tree_util.tree_map(np.asarray, state))):
        np.testing.assert_array_equal(a, b)
    PAGE.check_invariants(state)
    assert int(np.asarray((state.ref == 0).sum())) == 8 - 2
    # slot 1's pages survive a stranger's double release
    assert (np.asarray(state.block_tables)[1] < 8).all()


def test_unreserve_while_mapped_is_detected():
    """Dropping a shared page's registry hold is legal while slots map it
    (ref stays == mappings); dropping it AGAIN would zero the ref under a
    live mapping — the free list would hand the page out twice. The floor
    keeps ref at 0 (not negative) and check_invariants flags the state."""
    state = PAGE.init_pages(4, 2, 2)
    state, pages, ok = PAGE.reserve(state, 1)
    assert bool(ok)
    state, ok = PAGE.alloc(state, jnp.asarray([0], jnp.int32),
                           jnp.asarray([1], jnp.int32),
                           jnp.asarray([1], jnp.int32), pages)
    assert bool(ok)
    PAGE.check_invariants(state, shared_pages=np.asarray(pages))
    state = PAGE.unreserve(state, pages)  # evict: hold dropped, mapping live
    PAGE.check_invariants(state)  # ref == mappings, no hold: consistent
    state = PAGE.unreserve(state, pages)  # BUG: second drop under a mapping
    assert (np.asarray(state.ref) >= 0).all(), "floor must hold"
    with pytest.raises(AssertionError):
        PAGE.check_invariants(state)


def test_alloc_after_exhaustion_then_recovery():
    """An exhausted alloc refuses whole (ok=False, state unchanged); the
    same request succeeds once a release returns pages."""
    state = PAGE.init_pages(2, 2, 2)
    state, ok = PAGE.alloc(state, jnp.asarray([0], jnp.int32),
                           jnp.asarray([2], jnp.int32))
    assert bool(ok)
    before = jax.tree_util.tree_map(np.asarray, state)
    state, ok = PAGE.alloc(state, jnp.asarray([1], jnp.int32),
                           jnp.asarray([1], jnp.int32))
    assert not bool(ok)
    for a, b in zip(jax.tree_util.tree_leaves(before),
                    jax.tree_util.tree_leaves(
                        jax.tree_util.tree_map(np.asarray, state))):
        np.testing.assert_array_equal(a, b)
    state = PAGE.release(state, jnp.asarray([0], jnp.int32))
    state, ok = PAGE.alloc(state, jnp.asarray([1], jnp.int32),
                           jnp.asarray([1], jnp.int32))
    assert bool(ok)
    PAGE.check_invariants(state)


# ---------------------------------------------------------------------------
# paged vs dense: bit-exact decode parity (acceptance criterion)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kv_dtype", [None, "int8"])
def test_paged_decode_step_bitexact_vs_dense(dense, kv_dtype):
    """Same KV content, dense (B, max_len) layout vs paged arena + block
    tables: decode_step logits must be EXACTLY equal (float KV) — the paged
    gather (``paged_kernel=False``, the parity reference retained behind the
    Pallas decode kernel) is a relayout, not a different computation.
    Kernel-vs-gather parity lives in tests/test_paged_attention.py."""
    base_model, params = dense
    cfg = base_model.cfg
    model = Model(cfg, kv_dtype=kv_dtype)
    B, P, ps, MB = 3, 8, 4, 4  # max_len = MB * ps = 16
    toks = jnp.asarray(_prompts(cfg, B, P, seed=5))
    _, _, (k_s, v_s) = model.forward(params, {"tokens": toks},
                                     return_cache=True)

    dense_cache = model.init_cache(B, MB * ps)
    if dense_cache[0].dtype == jnp.int8:
        q = lambda a: jnp.clip(jnp.round(a.astype(jnp.float32) * KV_QSCALE),
                               -127, 127).astype(jnp.int8)
        k_s, v_s = q(k_s), q(v_s)
    ck = dense_cache[0].at[:, :, :P].set(k_s.astype(dense_cache[0].dtype))
    cv = dense_cache[1].at[:, :, :P].set(v_s.astype(dense_cache[1].dtype))

    n_pages = B * MB
    pk, pv = model.init_paged_cache(n_pages, ps)
    bt = jnp.arange(n_pages, dtype=jnp.int32).reshape(B, MB)
    pos = jnp.arange(P, dtype=jnp.int32)[None, :]
    page = jnp.take_along_axis(bt, jnp.broadcast_to(pos // ps, (B, P)), axis=1)
    off = jnp.broadcast_to(pos % ps, (B, P))
    pk = pk.at[:, page, off].set(k_s.astype(pk.dtype))
    pv = pv.at[:, page, off].set(v_s.astype(pv.dtype))

    tok = jnp.asarray([3, 7, 11], jnp.int32)
    posv = jnp.full((B,), P, jnp.int32)
    lg_dense, _ = model.decode_step(params, {"token": tok, "pos": posv},
                                    (ck, cv))
    lg_paged, _ = model.decode_step(
        params, {"token": tok, "pos": posv, "block_table": bt}, (pk, pv),
        paged_kernel=False)
    np.testing.assert_array_equal(np.asarray(lg_dense), np.asarray(lg_paged))


@pytest.mark.parametrize("family", ["dense", "moe"])
def test_paged_engine_matches_dense_engine(family, dense):
    if family == "moe":
        cfg = get_config("deepseek-moe-16b").reduced()
        cfg = dataclasses.replace(
            cfg, moe_capacity_factor=cfg.num_experts / cfg.top_k)
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
    else:
        model, params = dense
    cfg = model.cfg
    B, P, G = 4, 8, 6
    prompts = _prompts(cfg, B, P)
    mk = lambda paged: Engine(
        model, params,
        EngineConfig(n_slots=B, max_len=32, chunk=G - 1, prefill_buckets=(P,),
                     paged=paged, page_size=8))
    out_d = mk(False).generate(prompts, G)
    out_p = mk(True).generate(prompts, G)
    np.testing.assert_array_equal(out_d, out_p)
    for b in range(B):
        assert_greedy_continuation(model, params, prompts[b], out_p[b])


def test_paged_scheduler_stream_matches_dense(dense):
    """Mixed-length continuous-batching stream: paged and dense pools must
    produce identical per-request tokens (greedy)."""
    model, params = dense
    cfg = model.cfg
    rng = np.random.default_rng(2)
    reqs = [Request(rid,
                    rng.integers(0, cfg.vocab_size,
                                 int(rng.integers(4, 14))).astype(np.int32),
                    int(rng.integers(1, 8)))
            for rid in range(9)]
    mk = lambda paged: Engine(
        model, params,
        EngineConfig(n_slots=4, max_len=32, chunk=4, prefill_buckets=(8, 16),
                     paged=paged, page_size=8))
    out = {}
    for paged in (False, True):
        eng = mk(paged)
        comps = Scheduler(eng).run(reqs)
        out[paged] = {c.rid: list(c.tokens) for c in comps}
        if paged:
            PAGE.check_invariants(eng.pstate)
            assert eng.free_pages == eng.cfg.pool_pages, "pages leaked"
    assert out[False] == out[True]


# ---------------------------------------------------------------------------
# shared-prefix reuse
# ---------------------------------------------------------------------------

def test_shared_prefix_stream(dense):
    """Requests sharing a registered system-prompt prefix: admission maps
    the prefetched pages (skipping their prefill), outputs stay the exact
    greedy continuation, refcounts track live mappings, nothing leaks."""
    model, params = dense
    cfg = model.cfg
    rng = np.random.default_rng(4)
    prefix = rng.integers(0, cfg.vocab_size, 16).astype(np.int32)
    eng = Engine(model, params,
                 EngineConfig(n_slots=4, max_len=48, chunk=4,
                              prefill_buckets=(8, 16), paged=True,
                              page_size=8, n_pages=24))
    assert eng.register_prefix(prefix) == 16
    assert eng.free_pages == 22
    reqs = []
    for rid in range(5):
        suff = rng.integers(0, cfg.vocab_size,
                            int(rng.integers(3, 9))).astype(np.int32)
        reqs.append(Request(rid, np.concatenate([prefix, suff]),
                            int(rng.integers(2, 6))))
    reqs.append(Request(5, rng.integers(0, cfg.vocab_size, 7).astype(np.int32),
                        3))  # one fresh request mixed in

    def check(_c):
        PAGE.check_invariants(eng.pstate, shared_pages=eng.prefix_pages)

    comps = Scheduler(eng).run(reqs, progress=check)
    assert sorted(c.rid for c in comps) == list(range(6))
    # the 5 prefix requests skipped 16 prefill tokens each
    assert eng.stats["shared_tokens_saved"] == 5 * 16
    for c in comps:
        r = reqs[c.rid]
        assert len(c.tokens) == r.max_new
        assert_greedy_continuation(model, params, r.tokens, c.tokens)
    # drained: only the registry's hold remains
    assert eng.free_pages == 22
    ref = np.asarray(eng.pstate.ref)
    assert (ref[np.asarray(eng.prefix_pages)] == 1).all()


def test_shared_prefix_refcount_while_live(dense):
    """While two prefix-sharing requests are live, the prefix pages must be
    mapped by both slots (ref == 2 mappings + 1 hold) — and a prompt equal
    to the bare prefix falls back to fresh prefill (needs a suffix token)."""
    model, params = dense
    cfg = model.cfg
    rng = np.random.default_rng(9)
    prefix = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
    eng = Engine(model, params,
                 EngineConfig(n_slots=2, max_len=32, chunk=4,
                              prefill_buckets=(8, 16), paged=True,
                              page_size=8, n_pages=10))
    eng.register_prefix(prefix)
    p1 = np.concatenate([prefix, rng.integers(0, cfg.vocab_size, 3).astype(np.int32)])
    p2 = np.concatenate([prefix, rng.integers(0, cfg.vocab_size, 5).astype(np.int32)])
    eng.admit_wave([p1, p2], [0, 1], [4, 4])
    ref = np.asarray(eng.pstate.ref)
    assert ref[int(eng.prefix_pages[0])] == 3  # hold + 2 live mappings
    PAGE.check_invariants(eng.pstate, shared_pages=eng.prefix_pages)
    assert eng._shared_len(prefix) == 0, "bare-prefix prompt has no suffix"
    eng.release([0, 1])
    assert np.asarray(eng.pstate.ref)[int(eng.prefix_pages[0])] == 1


def test_register_prefix_validation(dense):
    model, params = dense
    eng_dense = Engine(model, params,
                       EngineConfig(n_slots=2, max_len=16, paged=False,
                                    prefill_buckets=(8,)))
    with pytest.raises(ValueError, match="paged"):
        eng_dense.register_prefix(np.zeros(8, np.int32))
    eng = Engine(model, params,
                 EngineConfig(n_slots=2, max_len=16, paged=True, page_size=8,
                              prefill_buckets=(8,)))
    assert eng.register_prefix(np.zeros(4, np.int32)) == 0  # < one page
    with pytest.raises(ValueError, match="no room"):
        eng.register_prefix(np.zeros(16, np.int32))
    assert eng.register_prefix(np.zeros(8, np.int32)) == 8
    free = eng.free_pages
    # re-registering the same tokens is idempotent: no new pages taken
    assert eng.register_prefix(np.zeros(8, np.int32)) == 8
    assert eng.free_pages == free
    assert len(eng._prefixes) == 1


# ---------------------------------------------------------------------------
# multi-prefix registry: concurrent prefixes, LRU eviction, fallback
# ---------------------------------------------------------------------------

def test_two_prefixes_share_pages(dense):
    """Two registered prefixes live at once: each admission maps ITS
    prefix's refcounted pages, longest match wins, and a drained stream
    leaves exactly the two registry holds."""
    model, params = dense
    cfg = model.cfg
    rng = np.random.default_rng(13)
    ps = 8
    A = rng.integers(0, cfg.vocab_size, 2 * ps).astype(np.int32)
    B = rng.integers(0, cfg.vocab_size, ps).astype(np.int32)
    eng = Engine(model, params,
                 EngineConfig(n_slots=4, max_len=48, chunk=4,
                              prefill_buckets=(8, 16), paged=True,
                              page_size=ps, n_pages=28))
    assert eng.register_prefix(A) == 2 * ps
    assert eng.register_prefix(B) == ps
    assert eng.free_pages == 28 - 3
    mk = lambda rid, pre: Request(
        rid, np.concatenate(
            [pre, rng.integers(0, cfg.vocab_size, 4).astype(np.int32)]), 3)
    reqs = [mk(0, A), mk(1, B), mk(2, A), mk(3, B),
            Request(4, rng.integers(0, cfg.vocab_size, 6).astype(np.int32), 3)]
    # while a wave is live, each prefix's pages carry hold + its mappings
    eng.admit_wave([r.tokens for r in reqs[:4]], [0, 1, 2, 3],
                   [r.max_new for r in reqs[:4]])
    ref_arr = np.asarray(eng.pstate.ref)
    entries = list(eng._prefixes.values())
    assert [e.live for e in entries] == [2, 2]
    assert (ref_arr[entries[0].pages] == 3).all()  # hold + 2 mappings (A)
    assert (ref_arr[entries[1].pages] == 3).all()  # hold + 2 mappings (B)
    PAGE.check_invariants(eng.pstate, shared_pages=eng.prefix_pages)
    eng.release([0, 1, 2, 3])
    assert [e.live for e in eng._prefixes.values()] == [0, 0]
    # full stream drains correctly and every output is the greedy line
    comps = Scheduler(eng).run(reqs)
    assert eng.stats["shared_tokens_saved"] == 2 * (2 * ps) + 2 * ps
    for c in comps:
        assert_greedy_continuation(model, params, reqs[c.rid].tokens, c.tokens)
    PAGE.check_invariants(eng.pstate, shared_pages=eng.prefix_pages)
    ref_arr = np.asarray(eng.pstate.ref)
    assert (ref_arr[np.asarray(eng.prefix_pages)] == 1).all()
    assert eng.free_pages == 28 - 3


def test_longest_prefix_match_wins(dense):
    model, params = dense
    cfg = model.cfg
    rng = np.random.default_rng(21)
    ps = 8
    long = rng.integers(0, cfg.vocab_size, 2 * ps).astype(np.int32)
    short = long[:ps]  # a prefix OF the longer prefix
    eng = Engine(model, params,
                 EngineConfig(n_slots=2, max_len=48, paged=True, page_size=ps,
                              prefill_buckets=(8, 16), n_pages=16))
    eng.register_prefix(short)
    eng.register_prefix(long)
    tail = rng.integers(0, cfg.vocab_size, 3).astype(np.int32)
    assert eng._shared_len(np.concatenate([long, tail])) == 2 * ps
    assert eng._shared_len(np.concatenate([short, tail])) == ps
    assert eng._shared_len(tail) == 0
    # a prompt equal to the long prefix still leaves no suffix for the long
    # entry -- but the short one covers half of it
    assert eng._shared_len(long) == ps


def test_prefix_eviction_lru_and_fallback(dense):
    """Pool pressure evicts only idle prefixes, least-recently-used first;
    a request matching the evicted prefix transparently falls back to full
    prefill (still the exact greedy first token)."""
    model, params = dense
    cfg = model.cfg
    rng = np.random.default_rng(11)
    ps = 8
    eng = Engine(model, params,
                 EngineConfig(n_slots=2, max_len=32, chunk=2,
                              prefill_buckets=(8, 16, 32), paged=True,
                              page_size=ps, n_pages=5))
    A = rng.integers(0, cfg.vocab_size, ps).astype(np.int32)
    B = rng.integers(0, cfg.vocab_size, ps).astype(np.int32)
    assert eng.register_prefix(A) == ps
    assert eng.register_prefix(B) == ps
    assert eng.free_pages == 3
    # touch A (admission bumps its LRU stamp) so B becomes the LRU victim
    pA = np.concatenate([A, rng.integers(0, cfg.vocab_size, 4).astype(np.int32)])
    eng.admit_wave([pA], [0], [2])
    assert eng.stats["shared_tokens_saved"] == ps
    eng.release([0])
    assert eng.free_pages == 3 and eng.evictable_pages() == 2
    # 4 fresh blocks > 3 free: exactly one eviction needed -> B, not A
    big = rng.integers(0, cfg.vocab_size, 28).astype(np.int32)
    eng.admit_wave([big], [0], [4])
    assert eng.stats["prefix_evictions"] == 1
    assert [e.tokens.tolist() for e in eng._prefixes.values()] == [A.tolist()]
    PAGE.check_invariants(eng.pstate, shared_pages=eng.prefix_pages)
    eng.release([0])
    # B's tokens now fall back to full prefill -- and still decode greedily
    saved = eng.stats["shared_tokens_saved"]
    pB = np.concatenate([B, rng.integers(0, cfg.vocab_size, 4).astype(np.int32)])
    assert eng._shared_len(pB) == 0
    first = eng.admit_wave([pB], [1], [2])
    assert eng.stats["shared_tokens_saved"] == saved
    logits, _ = model.forward(params, {"tokens": jnp.asarray(pB[None])})
    assert int(first[0]) == int(jnp.argmax(logits[0, -1]))
    PAGE.check_invariants(eng.pstate, shared_pages=eng.prefix_pages)


def test_live_prefix_is_never_evicted(dense):
    """Eviction only reclaims refcount-0 (idle) prefixes: when the only
    reclaimable pages belong to a LIVE prefix, admission must refuse whole
    (PagesExhausted), leaving the registry and pool untouched."""
    model, params = dense
    cfg = model.cfg
    rng = np.random.default_rng(17)
    ps = 8
    eng = Engine(model, params,
                 EngineConfig(n_slots=2, max_len=32, paged=True, page_size=ps,
                              prefill_buckets=(8, 16, 32), n_pages=4))
    A = rng.integers(0, cfg.vocab_size, ps).astype(np.int32)
    eng.register_prefix(A)
    pA = np.concatenate([A, rng.integers(0, cfg.vocab_size, 4).astype(np.int32)])
    eng.admit_wave([pA], [0], [2])  # A.live == 1, 1 fresh page
    assert eng.free_pages == 2 and eng.evictable_pages() == 0
    big = rng.integers(0, cfg.vocab_size, 28).astype(np.int32)
    with pytest.raises(PagesExhausted):
        eng.admit_wave([big], [1], [4])
    assert eng.stats["prefix_evictions"] == 0
    assert len(eng._prefixes) == 1 and eng.free_pages == 2
    PAGE.check_invariants(eng.pstate, shared_pages=eng.prefix_pages)
    # ... and the pages free the moment the mapping slot releases
    eng.release([0])
    assert eng.evictable_pages() == 1
    eng.admit_wave([big], [1], [4])  # now evicts idle A
    assert eng.stats["prefix_evictions"] == 1 and not eng._prefixes


def test_admit_wave_keep_pids_shields_prefix(dense):
    """The scheduler budgets a whole admission round against its matched
    prefixes and passes them as ``keep_pids``: an earlier (fresh) wave
    under pool pressure must evict around them — even when the shielded
    prefix is the LRU victim."""
    model, params = dense
    cfg = model.cfg
    rng = np.random.default_rng(29)
    ps = 8
    eng = Engine(model, params,
                 EngineConfig(n_slots=2, max_len=32, paged=True, page_size=ps,
                              prefill_buckets=(8, 16, 32), n_pages=5))
    A = rng.integers(0, cfg.vocab_size, ps).astype(np.int32)
    B = rng.integers(0, cfg.vocab_size, ps).astype(np.int32)
    eng.register_prefix(A)  # older => the natural LRU victim
    eng.register_prefix(B)
    pid_a = next(e.pid for e in eng._prefixes.values()
                 if np.array_equal(e.tokens, A))
    big = rng.integers(0, cfg.vocab_size, 28).astype(np.int32)
    eng.admit_wave([big], [0], [4], keep_pids={pid_a})  # needs 4 > 3 free
    assert eng.stats["prefix_evictions"] == 1
    assert [np.array_equal(e.tokens, A) for e in eng._prefixes.values()] \
        == [True], "shielded LRU prefix must survive; the newer one goes"
    PAGE.check_invariants(eng.pstate, shared_pages=eng.prefix_pages)


def test_prefix_registry_survives_reset(dense):
    model, params = dense
    cfg = model.cfg
    rng = np.random.default_rng(23)
    ps = 8
    A = rng.integers(0, cfg.vocab_size, ps).astype(np.int32)
    B = rng.integers(0, cfg.vocab_size, 2 * ps).astype(np.int32)
    eng = Engine(model, params,
                 EngineConfig(n_slots=2, max_len=48, paged=True, page_size=ps,
                              prefill_buckets=(8,), n_pages=16))
    eng.register_prefix(A)
    eng.register_prefix(B)
    eng.reset()
    assert len(eng._prefixes) == 2
    assert eng.free_pages == 16 - 3
    tail = rng.integers(0, cfg.vocab_size, 3).astype(np.int32)
    assert eng._shared_len(np.concatenate([B, tail])) == 2 * ps
    PAGE.check_invariants(eng.pstate, shared_pages=eng.prefix_pages)


# ---------------------------------------------------------------------------
# page exhaustion -> requeue (admission can now fail and retry)
# ---------------------------------------------------------------------------

def test_page_exhaustion_requeues_until_done(dense):
    model, params = dense
    cfg = model.cfg
    rng = np.random.default_rng(1)
    # 6 pages of 8 = 48 cached tokens total; slots alone would admit 4
    eng = Engine(model, params,
                 EngineConfig(n_slots=4, max_len=32, chunk=4,
                              prefill_buckets=(8, 16), paged=True,
                              page_size=8, n_pages=6))
    reqs = [Request(rid,
                    rng.integers(0, cfg.vocab_size,
                                 int(rng.integers(6, 14))).astype(np.int32),
                    int(rng.integers(2, 7)))
            for rid in range(7)]
    sched = Scheduler(eng)
    comps = sched.run(reqs)
    assert sorted(c.rid for c in comps) == list(range(7))
    assert sched.peak_live < 4, "6 pages cannot hold 4 of these requests"
    assert eng.free_pages == 6
    for c in comps:
        r = reqs[c.rid]
        assert_greedy_continuation(model, params, r.tokens, c.tokens)


def test_admit_wave_overflow_raises(dense):
    model, params = dense
    eng = Engine(model, params,
                 EngineConfig(n_slots=4, max_len=32, paged=True, page_size=8,
                              n_pages=2, prefill_buckets=(16,)))
    with pytest.raises(PagesExhausted):
        eng.admit_wave([np.zeros(16, np.int32)], [0], [8])
    # nothing was admitted or leaked
    assert eng.free_pages == 2
    assert not np.asarray(eng.state.active).any()
