"""Correctness of the §Perf optimization features (EXPERIMENTS.md §Perf):
MoE dispatch grouping, int8 KV cache, nested remat, seq-shard constraint."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.model import Model


def test_moe_group_tokens_equivalence():
    """Grouped dispatch == ungrouped when routing is dropless (cf=E/k)."""
    cfg = get_config("deepseek-moe-16b").reduced()
    cfg = dataclasses.replace(cfg, moe_capacity_factor=cfg.num_experts / cfg.top_k)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab_size)
    l1, _ = model.forward(params, {"tokens": toks})
    cfg_g = dataclasses.replace(cfg, moe_group_tokens=8)
    l2, _ = Model(cfg_g).forward(params, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-4,
                               atol=1e-4)


def test_moe_group_tokens_capacity_semantics():
    """Grouped dispatch with default cf still hits exact output shapes and
    finite outputs (drops allowed, semantics preserved)."""
    cfg = dataclasses.replace(get_config("qwen3-moe-235b-a22b").reduced(),
                              moe_group_tokens=8)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab_size)
    loss, _ = model.loss(params, {"tokens": toks, "labels": toks})
    assert bool(jnp.isfinite(loss))


def test_int8_kv_cache_decode_close_to_bf16():
    """int8 KV decode stays close to the full-precision decode."""
    cfg = get_config("qwen3-8b").reduced()
    m_full = Model(cfg)
    m_q = Model(cfg, kv_dtype="int8")
    params = m_full.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab_size)

    def decode_all(model):
        cache = model.init_cache(2, 8)
        outs = []
        for t in range(8):
            lg, cache = model.decode_step(
                params, {"token": toks[:, t], "pos": jnp.int32(t)}, cache)
            outs.append(lg)
        return jnp.stack(outs, 1)

    lf = decode_all(m_full)
    lq = decode_all(m_q)
    assert m_q.init_cache(2, 8)[0].dtype == jnp.int8
    # logits track within quantization noise; argmax mostly agrees
    agree = float((lf.argmax(-1) == lq.argmax(-1)).mean())
    assert agree > 0.8, agree
    assert float(jnp.abs(lf - lq).mean()) < 0.15


def test_act_pspec_noop_on_single_device():
    """The sequence-sharding constraint is semantics-preserving."""
    from jax.sharding import PartitionSpec as P
    cfg = get_config("llama1-7b").reduced(num_layers=2, d_model=64, d_ff=128)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with mesh:
        l1, _ = model.forward(params, {"tokens": toks})
        l2, _ = jax.jit(lambda p, b: model.forward(
            p, b, act_pspec=P("data", "model", None)))(params, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-5,
                               atol=1e-5)


def test_last_only_prefill_logits():
    cfg = get_config("qwen3-8b").reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
    full, _ = model.forward(params, {"tokens": toks})
    last, _ = model.forward(params, {"tokens": toks}, last_only=True)
    assert last.shape == (2, 1, cfg.vocab_size)
    np.testing.assert_allclose(np.asarray(full[:, -1]), np.asarray(last[:, 0]),
                               rtol=1e-5, atol=1e-5)


def test_grad_compress_roundtrip_convergence():
    """Error feedback: compressed-gradient SGD still converges (quadratic)."""
    from repro.optim import topk_compress_update
    w = jnp.asarray([4.0, -2.0, 1.0, 3.0])
    err = None
    for _ in range(200):
        g = 2 * (w - 1.0)
        comp, err = topk_compress_update({"w": g}, err, ratio=0.25)
        w = w - 0.05 * comp["w"]
    np.testing.assert_allclose(np.asarray(w), 1.0, atol=1e-2)
