"""Wanda++ pruning engine: correctness + the paper's qualitative claims."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import PruneConfig, TrainConfig
from repro.core.pruner import (make_block_fn, model_sparsity_report,
                               prune_block, prune_model, tree_get)
from repro.core.regional import block_io_stats, regional_grad_rms
from repro.data import calibration_batch, eval_batch
from repro.models import blocks as B
from repro.models.model import Model


@pytest.fixture(scope="module")
def tiny_lm():
    cfg = get_config("llama1-7b").reduced(num_layers=2, d_model=64, d_ff=128)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    calib = calibration_batch(cfg.vocab_size, 16, 32)
    return model, params, calib


def _prune(model, params, calib, method, pattern="2:4", ro_iters=2, **kw):
    # ro_lr=1e-3 is the benchmark-scale RO step size (the paper's 3e-7 is a
    # no-op on a tiny non-converged model; see EXPERIMENTS.md §Repro sweep)
    kw.setdefault("ro_lr", 1e-3)
    pcfg = PruneConfig(method=method, pattern=pattern, ro_iters=ro_iters,
                       ro_samples=4, n_calib=calib.shape[0], **kw)
    return prune_model(model, params, calib, pcfg)


class TestSparsityInvariants:
    @pytest.mark.parametrize("method", ["magnitude", "wanda", "wanda++rgs",
                                        "wanda++ro", "wanda++", "sparsegpt"])
    def test_exact_24(self, tiny_lm, method):
        model, params, calib = tiny_lm
        pruned, _ = _prune(model, params, calib, method)
        rep = model_sparsity_report(model, pruned)
        for name, sp in rep.items():
            assert abs(sp - 0.5) < 1e-6, (name, sp)
        # every 4-group along d_in has exactly 2 zeros
        w = pruned["blocks"]["mlp"]["wg"]["w"][0]  # (d_in, d_out)
        z = (np.asarray(w.T).reshape(w.shape[1], -1, 4) == 0).sum(-1)
        assert (z >= 2).all()

    def test_unstructured_ratio(self, tiny_lm):
        model, params, calib = tiny_lm
        pruned, _ = _prune(model, params, calib, "wanda",
                           pattern="unstructured", sparsity=0.7)
        rep = model_sparsity_report(model, pruned)
        for name, sp in rep.items():
            assert abs(sp - 0.7) < 0.02, (name, sp)

    def test_embeddings_never_pruned(self, tiny_lm):
        model, params, calib = tiny_lm
        pruned, _ = _prune(model, params, calib, "wanda++")
        assert float((pruned["embed"] == 0).mean()) < 0.01
        assert float((pruned["head"] == 0).mean()) < 0.01


class TestRegionalGradients:
    def test_rgs_grad_matches_manual(self, tiny_lm):
        """Eq. 3: G = sqrt(mean_n grad_n^2), per-sample grads of ||f(x)||_2."""
        model, params, calib = tiny_lm
        cfg = model.cfg
        block_fn = make_block_fn(cfg)
        bp = jax.tree_util.tree_map(lambda a: a[0], params["blocks"])
        xs = jnp.take(params["embed"], calib[:4], axis=0)
        G = regional_grad_rms(block_fn, bp, xs, chunk=2)

        def loss_one(bp_, x1):
            out = block_fn(bp_, x1[None]).astype(jnp.float32)
            return jnp.sqrt((out ** 2).sum())

        gs = [jax.grad(loss_one)(bp, xs[i]) for i in range(4)]
        manual = jax.tree_util.tree_map(
            lambda *g: jnp.sqrt(sum(x.astype(jnp.float32) ** 2 for x in g) / 4), *gs)
        a = tree_get(G, ("attn", "wq", "w"))
        b = tree_get(manual, ("attn", "wq", "w"))
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4)

    def test_taps_match_manual_norm(self, tiny_lm):
        """||X_j||_2 tap equals the norm of the actual layer input."""
        model, params, calib = tiny_lm
        cfg = model.cfg
        block_fn = make_block_fn(cfg)
        bp = jax.tree_util.tree_map(lambda a: a[0], params["blocks"])
        xs = jnp.take(params["embed"], calib[:4], axis=0)
        _, xnorm = block_io_stats(block_fn, bp, xs)
        # manual: input to attn.wq is rmsnorm(ln1, x)
        from repro.models.layers import rmsnorm
        xin = rmsnorm(bp["ln1"], xs, cfg.norm_eps).reshape(-1, cfg.d_model)
        manual = jnp.linalg.norm(xin.astype(jnp.float32), axis=0)
        np.testing.assert_allclose(np.asarray(xnorm["attn.wq"]),
                                   np.asarray(manual), rtol=1e-4)


class TestRO:
    def test_ro_reduces_block_mse(self, tiny_lm):
        """RO losses decrease across rounds (the optimizer works)."""
        model, params, calib = tiny_lm
        pruned, reports = _prune(model, params, calib, "wanda++")
        for rep in reports:
            ro = rep.get("ro_losses")
            if ro:
                assert ro[-1] <= ro[0] * 1.05, ro

    def test_ro_improves_over_rgs_only(self, tiny_lm):
        """Wanda++ (with RO) <= Wanda++RGS on held-out loss (paper Table 1)."""
        model, params, calib = tiny_lm
        ev = eval_batch(model.cfg.vocab_size, 8, 32)
        p_rgs, _ = _prune(model, params, calib, "wanda++rgs")
        p_full, _ = _prune(model, params, calib, "wanda++", ro_iters=3)
        l_rgs = float(model.loss(p_rgs, ev)[0])
        l_full = float(model.loss(p_full, ev)[0])
        assert l_full <= l_rgs + 0.02, (l_full, l_rgs)


class TestMethodOrdering:
    def test_wanda_beats_magnitude_on_scaled_inputs(self):
        """Wanda's premise: with wildly-scaled input channels, |W|*||X||
        beats |W| (single linear layer reconstruction)."""
        key = jax.random.PRNGKey(0)
        d_in, d_out, n = 64, 64, 256
        w = jax.random.normal(key, (d_in, d_out)) / 8.0
        scales = jnp.exp(jax.random.normal(jax.random.PRNGKey(1), (d_in,)) * 2)
        x = jax.random.normal(jax.random.PRNGKey(2), (n, d_in)) * scales
        y = x @ w
        from repro.core import masks as M
        from repro.core import scores as SC
        xn = jnp.linalg.norm(x, axis=0)
        for pattern in ["2:4"]:
            m_mag = M.make_mask(SC.magnitude_score(w.T), pattern, 0.5)
            m_wanda = M.make_mask(SC.wanda_score(w.T, xn), pattern, 0.5)
            e_mag = float(((x @ jnp.where(m_mag.T, w, 0) - y) ** 2).mean())
            e_wanda = float(((x @ jnp.where(m_wanda.T, w, 0) - y) ** 2).mean())
            assert e_wanda < e_mag, (e_wanda, e_mag)


class TestHybridShared:
    def test_shared_block_pruned_once(self):
        cfg = get_config("zamba2-7b").reduced()
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        calib = calibration_batch(cfg.vocab_size, 8, 16)
        pruned, reports = _prune(model, params, calib, "wanda++", ro_iters=1)
        assert reports[0]["layer"] == "shared_attn"
        w = pruned["shared_attn"]["attn"]["wq"]["w"]
        assert abs(float((w == 0).mean()) - 0.5) < 1e-6


class TestMoEExpertStats:
    def test_expert_conditional_norms(self):
        """Expert taps have shape (E, d_in) and are expert-specific."""
        cfg = get_config("deepseek-moe-16b").reduced()
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        bp = jax.tree_util.tree_map(lambda a: a[0], params["blocks"])
        block_fn = make_block_fn(cfg)
        xs = jnp.take(params["embed"],
                      calibration_batch(cfg.vocab_size, 8, 16), axis=0)
        _, xnorm = block_io_stats(block_fn, bp, xs)
        assert xnorm["moe.wg"].shape == (cfg.num_experts, cfg.d_model)
        # routed tokens differ per expert => norms differ
        assert float(jnp.std(xnorm["moe.wg"].sum(-1))) > 0
