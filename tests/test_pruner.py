"""Wanda++ pruning engine: correctness + the paper's qualitative claims."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import PruneConfig, TrainConfig
from repro.core.pruner import (make_block_fn, model_sparsity_report,
                               prune_block, prune_model, tree_get)
from repro.core.regional import block_io_stats, regional_grad_rms
from repro.data import calibration_batch, eval_batch
from repro.models import blocks as B
from repro.models.model import Model


@pytest.fixture(scope="module")
def tiny_lm():
    cfg = get_config("llama1-7b").reduced(num_layers=2, d_model=64, d_ff=128)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    calib = calibration_batch(cfg.vocab_size, 16, 32)
    return model, params, calib


def _prune(model, params, calib, method, pattern="2:4", ro_iters=2, **kw):
    # ro_lr=1e-3 is the benchmark-scale RO step size (the paper's 3e-7 is a
    # no-op on a tiny non-converged model; see EXPERIMENTS.md §Repro sweep)
    kw.setdefault("ro_lr", 1e-3)
    pcfg = PruneConfig(method=method, pattern=pattern, ro_iters=ro_iters,
                       ro_samples=4, n_calib=calib.shape[0], **kw)
    return prune_model(model, params, calib, pcfg)


class TestSparsityInvariants:
    @pytest.mark.parametrize("method", ["magnitude", "wanda", "wanda++rgs",
                                        "wanda++ro", "wanda++", "sparsegpt"])
    def test_exact_24(self, tiny_lm, method):
        model, params, calib = tiny_lm
        pruned, _ = _prune(model, params, calib, method)
        rep = model_sparsity_report(model, pruned)
        for name, sp in rep.items():
            assert abs(sp - 0.5) < 1e-6, (name, sp)
        # every 4-group along d_in has exactly 2 zeros
        w = pruned["blocks"]["mlp"]["wg"]["w"][0]  # (d_in, d_out)
        z = (np.asarray(w.T).reshape(w.shape[1], -1, 4) == 0).sum(-1)
        assert (z >= 2).all()

    def test_unstructured_ratio(self, tiny_lm):
        model, params, calib = tiny_lm
        pruned, _ = _prune(model, params, calib, "wanda",
                           pattern="unstructured", sparsity=0.7)
        rep = model_sparsity_report(model, pruned)
        for name, sp in rep.items():
            assert abs(sp - 0.7) < 0.02, (name, sp)

    def test_embeddings_never_pruned(self, tiny_lm):
        model, params, calib = tiny_lm
        pruned, _ = _prune(model, params, calib, "wanda++")
        assert float((pruned["embed"] == 0).mean()) < 0.01
        assert float((pruned["head"] == 0).mean()) < 0.01


class TestRegionalGradients:
    def test_rgs_grad_matches_manual(self, tiny_lm):
        """Eq. 3: G = sqrt(mean_n grad_n^2), per-sample grads of ||f(x)||_2."""
        model, params, calib = tiny_lm
        cfg = model.cfg
        block_fn = make_block_fn(cfg)
        bp = jax.tree_util.tree_map(lambda a: a[0], params["blocks"])
        xs = jnp.take(params["embed"], calib[:4], axis=0)
        G = regional_grad_rms(block_fn, bp, xs, chunk=2)

        def loss_one(bp_, x1):
            out = block_fn(bp_, x1[None]).astype(jnp.float32)
            return jnp.sqrt((out ** 2).sum())

        gs = [jax.grad(loss_one)(bp, xs[i]) for i in range(4)]
        manual = jax.tree_util.tree_map(
            lambda *g: jnp.sqrt(sum(x.astype(jnp.float32) ** 2 for x in g) / 4), *gs)
        a = tree_get(G, ("attn", "wq", "w"))
        b = tree_get(manual, ("attn", "wq", "w"))
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4)

    def test_taps_match_manual_norm(self, tiny_lm):
        """||X_j||_2 tap equals the norm of the actual layer input."""
        model, params, calib = tiny_lm
        cfg = model.cfg
        block_fn = make_block_fn(cfg)
        bp = jax.tree_util.tree_map(lambda a: a[0], params["blocks"])
        xs = jnp.take(params["embed"], calib[:4], axis=0)
        _, xnorm = block_io_stats(block_fn, bp, xs)
        # manual: input to attn.wq is rmsnorm(ln1, x)
        from repro.models.layers import rmsnorm
        xin = rmsnorm(bp["ln1"], xs, cfg.norm_eps).reshape(-1, cfg.d_model)
        manual = jnp.linalg.norm(xin.astype(jnp.float32), axis=0)
        np.testing.assert_allclose(np.asarray(xnorm["attn.wq"]),
                                   np.asarray(manual), rtol=1e-4)


class TestRO:
    def test_ro_reduces_block_mse(self, tiny_lm):
        """RO losses decrease across rounds (the optimizer works)."""
        model, params, calib = tiny_lm
        pruned, reports = _prune(model, params, calib, "wanda++")
        for rep in reports:
            ro = rep.get("ro_losses")
            if ro:
                assert ro[-1] <= ro[0] * 1.05, ro

    def test_ro_improves_over_rgs_only(self, tiny_lm):
        """Wanda++ (with RO) <= Wanda++RGS on held-out loss (paper Table 1)."""
        model, params, calib = tiny_lm
        ev = eval_batch(model.cfg.vocab_size, 8, 32)
        p_rgs, _ = _prune(model, params, calib, "wanda++rgs")
        p_full, _ = _prune(model, params, calib, "wanda++", ro_iters=3)
        l_rgs = float(model.loss(p_rgs, ev)[0])
        l_full = float(model.loss(p_full, ev)[0])
        assert l_full <= l_rgs + 0.02, (l_full, l_rgs)


class TestROSparsityContract:
    """Regression: ro_fit used to order each round prune->RO, so the FINAL
    round's RMSprop updates landed after the last mask application and the
    returned block violated 2:4 (sparsity_check24 failed, compressed24=auto
    silently fell back to dense). ro_fit now masks updates, zeroes stale
    second-moment state on re-prune, and re-applies the prune after the
    final round."""

    def _block_setup(self, tiny_lm, ro_iters, ro_samples=4):
        model, params, calib = tiny_lm
        cfg = model.cfg
        block_fn = make_block_fn(cfg)
        bp = jax.tree_util.tree_map(lambda a: a[0], params["blocks"])
        xs = jnp.take(params["embed"], calib[:8], axis=0)
        pcfg = PruneConfig(method="wanda++", pattern="2:4", ro_iters=ro_iters,
                           ro_samples=ro_samples, n_calib=8, ro_lr=1e-3)
        prunable = B.prunable_table(cfg)
        G = regional_grad_rms(block_fn, bp, xs, chunk=4)
        dense_out, _ = block_io_stats(block_fn, bp, xs)

        def prune_fn(bp_):
            _, xn = block_io_stats(block_fn, bp_, xs)
            from repro.core.pruner import apply_prune
            return apply_prune(bp_, xn, G, pcfg, prunable, with_mask=True)

        return block_fn, bp, xs, dense_out, pcfg, prunable, prune_fn

    @pytest.mark.parametrize("ro_iters", [1, 2, 3])
    def test_ro_fit_output_is_exactly_24(self, tiny_lm, ro_iters):
        """ro_fit's returned block passes sparsity_check24 for every
        ro_iters value — including 1 (the old code's worst case: its only
        prune ran before its only round of dense updates)."""
        from repro.core import ro as RO
        from repro.kernels.ops import sparsity_check24
        block_fn, bp, xs, dense_out, pcfg, prunable, prune_fn = \
            self._block_setup(tiny_lm, ro_iters)
        fitted, losses = RO.ro_fit(block_fn, bp, xs, dense_out, pcfg,
                                   jax.random.PRNGKey(3), prune_fn)
        assert losses.shape == (ro_iters,)
        for name, path in prunable.items():
            w = tree_get(fitted, path)
            if w is None:
                continue
            assert sparsity_check24(w), f"{name} violates 2:4 after ro_fit"
            assert abs(float((w == 0).mean()) - 0.5) < 1e-6, name

    def test_legacy_bare_prune_fn_still_24(self, tiny_lm):
        """A legacy prune_fn returning a bare block (no keep-mask) must
        also yield an exactly-sparse result — the final re-prune alone
        guarantees it."""
        from repro.core import ro as RO
        from repro.core.pruner import apply_prune
        from repro.kernels.ops import sparsity_check24
        block_fn, bp, xs, dense_out, pcfg, prunable, _ = \
            self._block_setup(tiny_lm, ro_iters=1)
        G = regional_grad_rms(block_fn, bp, xs, chunk=4)

        def bare_prune_fn(bp_):
            _, xn = block_io_stats(block_fn, bp_, xs)
            return apply_prune(bp_, xn, G, pcfg, prunable)

        fitted, _ = RO.ro_fit(block_fn, bp, xs, dense_out, pcfg,
                              jax.random.PRNGKey(3), bare_prune_fn)
        w = tree_get(fitted, prunable["attn.wq"])
        assert sparsity_check24(w)

    def test_two_round_determinism_vs_manual(self, tiny_lm):
        """Bit-exact pin of the full two-round contract: masked RMSprop
        updates, second-moment zeroing at re-pruned positions, and the
        final re-prune — against an independent per-sample loop."""
        from repro.core import ro as RO
        block_fn, bp, xs, dense_out, pcfg, prunable, prune_fn = \
            self._block_setup(tiny_lm, ro_iters=2)
        key = jax.random.PRNGKey(7)
        fitted, losses = RO.ro_fit(block_fn, bp, xs, dense_out, pcfg, key,
                                   prune_fn)

        # --- manual simulation (no lax.scan, explicit rmsprop math) ---
        tm = jax.tree_util.tree_map

        def loss_one(bp_, x1, y1):
            out = block_fn(bp_, x1[None])[0]
            d = out.astype(jnp.float32) - y1.astype(jnp.float32)
            return jnp.mean(d * d)

        vg = jax.value_and_grad(loss_one)
        m_bp = bp
        opt = tm(lambda p: jnp.zeros(p.shape, jnp.float32), bp)
        k = key
        m_losses = []
        for _ in range(pcfg.ro_iters):
            m_bp, keep = prune_fn(m_bp)
            opt = tm(lambda v, m: v * m.astype(v.dtype), opt, keep)
            k, sub = jax.random.split(k)
            xs_ro, dense_ro = RO.select_ro_inputs(sub, xs, dense_out,
                                                  pcfg.ro_samples)
            per_sample = []
            for i in range(pcfg.ro_samples):
                loss, g = vg(m_bp, xs_ro[i], dense_ro[i])
                per_sample.append(loss)
                g = tm(lambda gg, m: gg * m.astype(gg.dtype), g, keep)
                opt = tm(lambda v, gg: 0.99 * v
                         + 0.01 * jnp.square(gg.astype(jnp.float32)), opt, g)
                m_bp = tm(lambda p, gg, v: (p.astype(jnp.float32)
                                            - pcfg.ro_lr * gg.astype(jnp.float32)
                                            / (jnp.sqrt(v) + 1e-8)
                                            ).astype(p.dtype), m_bp, g, opt)
            m_losses.append(jnp.stack(per_sample).mean())
        m_bp, _ = prune_fn(m_bp)

        np.testing.assert_allclose(np.asarray(losses),
                                   np.asarray(jnp.stack(m_losses)),
                                   rtol=1e-6)
        for name, path in prunable.items():
            a, b = tree_get(fitted, path), tree_get(m_bp, path)
            if a is None:
                continue
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-6, err_msg=name)

    def test_state_zeroed_on_reprune(self):
        """zero_masked_state drops variance exactly at mask==0."""
        from repro.core import ro as RO
        st = {"w": jnp.arange(8, dtype=jnp.float32)}
        keep = {"w": jnp.array([1, 0, 1, 0, 1, 0, 1, 0], jnp.bool_)}
        out = RO.zero_masked_state(st, keep)["w"]
        np.testing.assert_array_equal(np.asarray(out),
                                      [0., 0., 2., 0., 4., 0., 6., 0.])

    def test_masked_update_freezes_pruned_entries(self):
        """rmsprop_update with a keep-mask moves neither the weight nor the
        second-moment state at pruned positions."""
        from repro.core import ro as RO
        p = {"w": jnp.ones(4, jnp.float32)}
        g = {"w": jnp.full((4,), 2.0, jnp.float32)}
        v = {"w": jnp.zeros(4, jnp.float32)}
        keep = {"w": jnp.array([1, 0, 1, 0], jnp.bool_)}
        np_, nv = RO.rmsprop_update(p, g, v, lr=0.1, mask=keep)
        assert float(np_["w"][1]) == 1.0 and float(np_["w"][3]) == 1.0
        assert float(nv["w"][1]) == 0.0 and float(nv["w"][3]) == 0.0
        assert float(np_["w"][0]) != 1.0 and float(nv["w"][0]) > 0.0


class TestMethodOrdering:
    def test_wanda_beats_magnitude_on_scaled_inputs(self):
        """Wanda's premise: with wildly-scaled input channels, |W|*||X||
        beats |W| (single linear layer reconstruction)."""
        key = jax.random.PRNGKey(0)
        d_in, d_out, n = 64, 64, 256
        w = jax.random.normal(key, (d_in, d_out)) / 8.0
        scales = jnp.exp(jax.random.normal(jax.random.PRNGKey(1), (d_in,)) * 2)
        x = jax.random.normal(jax.random.PRNGKey(2), (n, d_in)) * scales
        y = x @ w
        from repro.core import masks as M
        from repro.core import scores as SC
        xn = jnp.linalg.norm(x, axis=0)
        for pattern in ["2:4"]:
            m_mag = M.make_mask(SC.magnitude_score(w.T), pattern, 0.5)
            m_wanda = M.make_mask(SC.wanda_score(w.T, xn), pattern, 0.5)
            e_mag = float(((x @ jnp.where(m_mag.T, w, 0) - y) ** 2).mean())
            e_wanda = float(((x @ jnp.where(m_wanda.T, w, 0) - y) ** 2).mean())
            assert e_wanda < e_mag, (e_wanda, e_mag)


class TestHybridShared:
    def test_shared_block_pruned_once(self):
        cfg = get_config("zamba2-7b").reduced()
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        calib = calibration_batch(cfg.vocab_size, 8, 16)
        pruned, reports = _prune(model, params, calib, "wanda++", ro_iters=1)
        assert reports[0]["layer"] == "shared_attn"
        w = pruned["shared_attn"]["attn"]["wq"]["w"]
        assert abs(float((w == 0).mean()) - 0.5) < 1e-6


class TestMoEExpertStats:
    def test_expert_conditional_norms(self):
        """Expert taps have shape (E, d_in) and are expert-specific."""
        cfg = get_config("deepseek-moe-16b").reduced()
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        bp = jax.tree_util.tree_map(lambda a: a[0], params["blocks"])
        block_fn = make_block_fn(cfg)
        xs = jnp.take(params["embed"],
                      calibration_batch(cfg.vocab_size, 8, 16), axis=0)
        _, xnorm = block_io_stats(block_fn, bp, xs)
        assert xnorm["moe.wg"].shape == (cfg.num_experts, cfg.d_model)
        # routed tokens differ per expert => norms differ
        assert float(jnp.std(xnorm["moe.wg"].sum(-1))) > 0
