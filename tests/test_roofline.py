"""Roofline machinery: HLO collective parser + analytic FLOPs validation
against XLA cost analysis on an *unrolled* (scan-free) small model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.distributed.roofline import (analytic_flops, collective_bytes,
                                        roofline_report, xla_cost)


class TestCollectiveParser:
    def test_parses_crafted_hlo(self):
        hlo = """
        HloModule m
        ENTRY e {
          %p = f32[128,256]{1,0} parameter(0)
          %ag = f32[1024,256]{1,0} all-gather(%p), dimensions={0}
          %ar = bf16[512]{0} all-reduce(%x), to_apply=%add
          %rs.1 = f32[64,256]{1,0} reduce-scatter(%y), dimensions={0}
          %a2a = f32[32,32]{1,0} all-to-all(%z), dimensions={1}
          %cp = u8[16]{0} collective-permute(%w)
          %start = f32[100]{0} all-reduce-start(%v)
          %done = f32[100]{0} all-reduce-done(%start)
        }
        """
        coll = collective_bytes(hlo)
        assert coll["all-gather"] == 1024 * 256 * 4
        assert coll["all-reduce"] == 512 * 2 + 100 * 4  # incl. -start, not -done
        assert coll["reduce-scatter"] == 64 * 256 * 4
        assert coll["all-to-all"] == 32 * 32 * 4
        assert coll["collective-permute"] == 16

    def test_roofline_bottleneck(self):
        rep = roofline_report({"flops": 1e12, "bytes accessed": 1e6}, {}, 1)
        assert rep["bottleneck"] == "compute_s"
        rep = roofline_report({"flops": 1e6, "bytes accessed": 1e12}, {}, 1)
        assert rep["bottleneck"] == "memory_s"


class TestAnalyticFlops:
    def test_matches_xla_on_unrolled_forward(self):
        """Scan-free tiny transformer: analytic fwd FLOPs within 25% of
        XLA's count (validates the scan-correction model)."""
        cfg = get_config("llama1-7b").reduced(
            num_layers=2, d_model=128, d_ff=256, vocab_size=512,
            num_heads=4, num_kv_heads=4, head_dim=32)
        from repro.models import blocks as B
        from repro.models.layers import default_positions
        import functools

        def fwd_unrolled(params, tokens):
            x = jnp.take(params["embed"], tokens, axis=0)
            pos = default_positions(*tokens.shape)
            for l in range(cfg.num_layers):
                bp = jax.tree_util.tree_map(lambda a: a[l], params["blocks"])
                x, _, _ = B.transformer_block(bp, x, cfg, pos)
            return x @ params["head"]

        from repro.models.model import Model
        model = Model(cfg)
        params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        toks = jax.ShapeDtypeStruct((4, 64), jnp.int32)
        compiled = jax.jit(fwd_unrolled).lower(params, toks).compile()
        xla_fl = float(xla_cost(compiled)["flops"])

        shape = ShapeConfig("t", 64, 4, "prefill")
        ours = analytic_flops(cfg, shape)
        assert abs(ours - xla_fl) / xla_fl < 0.25, (ours, xla_fl)

    def test_train_flops_3x_forward(self):
        cfg = get_config("qwen3-8b")
        sh_t = ShapeConfig("t", 4096, 256, "train")
        sh_p = ShapeConfig("p", 4096, 256, "prefill")
        ft = analytic_flops(cfg, sh_t, remat=False)
        fp = analytic_flops(cfg, sh_p)
        assert abs(ft / fp - 3.0) < 0.01
        assert analytic_flops(cfg, sh_t, remat=True) / fp == pytest.approx(4.0, rel=0.01)

    def test_moe_counts_active_only(self):
        cfg = get_config("qwen3-moe-235b-a22b")
        sh = ShapeConfig("p", 4096, 8, "prefill")
        fl = analytic_flops(cfg, sh)
        dense_equiv = 2.0 * cfg.param_count() * 8 * 4096
        active_equiv = 2.0 * cfg.active_param_count() * 8 * 4096
        assert fl < 0.5 * dense_equiv
        assert fl > 0.9 * active_equiv

    def test_decode_flops_linear_in_batch(self):
        cfg = get_config("qwen3-8b")
        f1 = analytic_flops(cfg, ShapeConfig("d", 32768, 64, "decode"))
        f2 = analytic_flops(cfg, ShapeConfig("d", 32768, 128, "decode"))
        assert f2 / f1 == pytest.approx(2.0, rel=0.01)
