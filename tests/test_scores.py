"""Score registry + online calibration: parity, mask validity, engine taps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import PruneConfig
from repro.core import scores as SC
from repro.core import masks as M
from repro.core.pruner import (apply_prune, make_block_fn, prune_block,
                               model_sparsity_report, reprune_from_stats,
                               tree_get)
from repro.core.regional import (_resolve_chunk, block_io_stats_full,
                                 make_tapped_elin, regional_grad_rms)
from repro.core.ro import ro_fit
from repro.data import calibration_batch
from repro.kernels.ops import sparsity_check24
from repro.models import blocks as B
from repro.models.model import Model
from repro.serve import Engine, EngineConfig, SamplingConfig


@pytest.fixture(scope="module")
def tiny_lm():
    cfg = get_config("llama1-7b").reduced(num_layers=2, d_model=64, d_ff=128)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    calib = calibration_batch(cfg.vocab_size, 8, 32)
    return model, params, calib


def _block_inputs(model, params, calib):
    cfg = model.cfg
    bp = jax.tree_util.tree_map(lambda a: a[0], params["blocks"])
    xs = jnp.take(params["embed"], calib, axis=0)
    return cfg, bp, xs


class TestRegistry:
    def test_every_method_registered(self):
        for m in ("magnitude", "wanda", "wanda++", "wanda++rgs", "wanda++ro",
                  "gblm", "stade", "connect"):
            assert m in SC.available()

    def test_unknown_score_raises(self):
        with pytest.raises(ValueError, match="unknown pruning score"):
            SC.get_score("wanda+++")

    def test_registry_wanda_bit_exact_vs_direct(self, tiny_lm):
        """apply_prune resolving 'wanda' through the registry must equal the
        hand-rolled wanda_score -> make_mask path bit for bit."""
        model, params, calib = tiny_lm
        cfg, bp, xs = _block_inputs(model, params, calib)
        block_fn = make_block_fn(cfg)
        _, stats = jax.jit(
            lambda b, x: block_io_stats_full(block_fn, b, x))(bp, xs)
        prunable = B.prunable_table(cfg)
        pcfg = PruneConfig(method="wanda", pattern="2:4")
        via_registry = apply_prune(bp, stats, None, pcfg, prunable)
        for name, path in prunable.items():
            w = tree_get(bp, path)
            if w is None:
                continue
            w_oi = SC.to_oi(w)
            xnorm = jnp.sqrt(stats[name]["sumsq"])
            mask = M.make_mask(SC.wanda_score(w_oi, xnorm), "2:4", 0.5)
            manual = SC.from_oi(jnp.where(mask, w_oi, 0))
            np.testing.assert_array_equal(
                np.asarray(tree_get(via_registry, path)), np.asarray(manual))

    @pytest.mark.parametrize("method", sorted(SC.SCORES))
    def test_every_score_yields_valid_24(self, tiny_lm, method):
        """Every registered score must drive make_mask to exact 2:4."""
        model, params, calib = tiny_lm
        cfg, bp, xs = _block_inputs(model, params, calib)
        block_fn = make_block_fn(cfg)
        _, stats = block_io_stats_full(block_fn, bp, xs)
        G = None
        if SC.get_score(method).grad is not None:
            G = regional_grad_rms(block_fn, bp, xs, chunk=4)
        prunable = B.prunable_table(cfg)
        pcfg = PruneConfig(method=method, pattern="2:4")
        pruned = apply_prune(bp, stats, G, pcfg, prunable)
        for name, path in prunable.items():
            w = tree_get(pruned, path)
            if w is None:
                continue
            w_oi = np.asarray(SC.to_oi(w))
            zeros = (w_oi.reshape(*w_oi.shape[:-1], -1, 4) == 0).sum(-1)
            assert (zeros >= 2).all(), (method, name)

    def test_missing_stats_raise(self, tiny_lm):
        """A score whose declared needs aren't met must fail loudly."""
        model, params, calib = tiny_lm
        cfg, bp, _ = _block_inputs(model, params, calib)
        prunable = B.prunable_table(cfg)
        pcfg = PruneConfig(method="stade", pattern="2:4")
        with pytest.raises(ValueError, match="needs stats"):
            apply_prune(bp, None, None, pcfg, prunable)

    def test_24_survives_ro_fit(self, tiny_lm):
        """The prune -> RO -> re-prune loop must return weights that still
        pass the serving engine's strict 2:4 check."""
        model, params, calib = tiny_lm
        cfg, bp, xs = _block_inputs(model, params, calib)
        block_fn = make_block_fn(cfg)
        _, stats = block_io_stats_full(block_fn, bp, xs)
        prunable = B.prunable_table(cfg)
        pcfg = PruneConfig(method="wanda++ro", pattern="2:4", ro_iters=2,
                           ro_samples=4, ro_lr=1e-3)
        dense_out = block_fn(bp, xs)
        prune_fn = lambda b: apply_prune(b, stats, None, pcfg, prunable,
                                         with_mask=True)
        fitted, _ = ro_fit(block_fn, bp, xs, dense_out, pcfg,
                           jax.random.PRNGKey(0), prune_fn=prune_fn)
        for name, path in prunable.items():
            w = tree_get(fitted, path)
            if w is None:
                continue
            assert sparsity_check24(w), name


class TestChunkFallback:
    def test_resolve_chunk(self):
        assert _resolve_chunk(8, 4) == 4
        assert _resolve_chunk(12, 8) == 6
        assert _resolve_chunk(7, 4) == 1   # prime N degrades, never crashes
        assert _resolve_chunk(3, 8) == 3   # chunk > N clamps to N

    def test_prime_n_grad_exact(self, tiny_lm):
        """N=7 (prime) calibration windows: the RMS must use the exact
        denominator and match the chunk=1 reference."""
        model, params, calib = tiny_lm
        cfg, bp, xs = _block_inputs(model, params, calib)
        block_fn = make_block_fn(cfg)
        xs7 = xs[:7]
        G_a = regional_grad_rms(block_fn, bp, xs7, chunk=4)
        G_b = regional_grad_rms(block_fn, bp, xs7, chunk=1)
        a = np.asarray(tree_get(G_a, ("attn", "wq", "w")))
        b = np.asarray(tree_get(G_b, ("attn", "wq", "w")))
        np.testing.assert_allclose(a, b, rtol=1e-5)
        assert np.isfinite(a).all() and (a > 0).any()


class TestTappedElin:
    def test_occupancy_masks_garbage_slots(self):
        """Unrouted capacity slots carry garbage; occ must keep it out of the
        sums AND out of the token counts."""
        rng = np.random.default_rng(0)
        B_, E, C, In = 2, 3, 4, 8
        xin = rng.standard_normal((B_, E, C, In)).astype(np.float32)
        occ = rng.random((B_, E, C)) < 0.5
        garbage = np.where(occ[..., None], xin, 1e6)  # plant garbage

        taps = {}
        elin = make_tapped_elin(taps)
        w = rng.standard_normal((E, In, 5)).astype(np.float32)
        elin("mlp.wg", jnp.asarray(w), jnp.asarray(garbage),
             "beci,eij->becj", occ=jnp.asarray(occ))
        st = taps["mlp.wg"]

        xr = np.where(occ[..., None], xin, 0.0)
        np.testing.assert_allclose(np.asarray(st["sumsq"]),
                                   (xr ** 2).sum((0, 2)), rtol=1e-5)
        np.testing.assert_allclose(np.asarray(st["abssum"]),
                                   np.abs(xr).sum((0, 2)), rtol=1e-5)
        np.testing.assert_allclose(np.asarray(st["count"]),
                                   occ.sum((0, 2)).astype(np.float32))

    def test_no_occ_counts_every_slot(self):
        taps = {}
        elin = make_tapped_elin(taps)
        x = jnp.ones((2, 3, 4, 8))
        elin("wu", jnp.ones((3, 8, 5)), x, "beci,eij->becj")
        np.testing.assert_allclose(np.asarray(taps["wu"]["count"]),
                                   np.full((3,), 8.0))


class TestPruneReports:
    def test_compile_split_from_compute(self, tiny_lm):
        model, params, calib = tiny_lm
        cfg, bp, xs = _block_inputs(model, params, calib)
        block_fn = make_block_fn(cfg)
        prunable = B.prunable_table(cfg)
        pcfg = PruneConfig(method="wanda", pattern="2:4")
        _, report = prune_block(block_fn, bp, xs, pcfg, prunable,
                                jax.random.PRNGKey(0))
        assert report["compile_seconds"] > 0
        assert report["seconds"] > 0
        # AOT compile happens before the compute clock starts; on these tiny
        # shapes XLA compilation dwarfs the actual prune arithmetic
        assert report["seconds"] < report["compile_seconds"]

    def test_sparsity_report_values(self, tiny_lm):
        model, params, calib = tiny_lm
        pcfg = PruneConfig(method="wanda", pattern="2:4", n_calib=8,
                           calib_len=32)
        from repro.core.pruner import prune_model
        pruned, _ = prune_model(model, params, calib, pcfg)
        rep = model_sparsity_report(model, pruned)
        assert rep and all(isinstance(v, float) for v in rep.values())
        for name, sp in rep.items():
            assert abs(sp - 0.5) < 1e-6, (name, sp)


class TestEngineTaps:
    @pytest.fixture(scope="class")
    def tapped_setup(self, tiny_lm):
        model, params, _ = tiny_lm
        cfg = model.cfg
        S, GEN, B_ = 16, 4, 2
        ecfg = lambda taps: EngineConfig(n_slots=B_, max_len=S + GEN,
                                         chunk=GEN - 1, prefill_buckets=(S,),
                                         calib_taps=taps)
        eng = Engine(model, params, ecfg(True), SamplingConfig())
        ref = Engine(model, params, ecfg(False), SamplingConfig())
        prompts = np.asarray(
            calibration_batch(cfg.vocab_size, B_, S, seed=3))
        return model, params, eng, ref, prompts, GEN

    def test_greedy_parity_and_pinned_traces(self, tapped_setup):
        model, params, eng, ref, prompts, GEN = tapped_setup
        out = eng.generate(prompts, GEN)
        out_ref = ref.generate(prompts, GEN)
        np.testing.assert_array_equal(out, out_ref)
        assert eng.trace_counts == ref.trace_counts
        # second wave accumulates stats without retracing anything
        before = dict(eng.trace_counts)
        eng.generate(prompts, GEN)
        assert dict(eng.trace_counts) == before

    def test_snapshot_matches_offline_stats(self, tiny_lm):
        """Prefill-only traffic: the engine's live xnorm must equal the
        offline block-sequential calibration statistics on the same tokens."""
        model, params, _ = tiny_lm
        cfg = model.cfg
        S, B_ = 16, 4
        ecfg = EngineConfig(n_slots=B_, max_len=S + 1, chunk=1,
                            prefill_buckets=(S,), calib_taps=True)
        eng = Engine(model, params, ecfg, SamplingConfig())
        toks = calibration_batch(cfg.vocab_size, B_, S, seed=5)
        eng.generate(np.asarray(toks), 1)  # prefill only, no decode steps
        snap = eng.calibration_snapshot()
        assert int(snap["tokens"]) == B_ * S

        block_fn = make_block_fn(cfg)
        xs = jnp.take(params["embed"], toks, axis=0)
        for l in range(cfg.num_layers):
            bp = jax.tree_util.tree_map(lambda a: a[l], params["blocks"])
            out, stats = block_io_stats_full(block_fn, bp, xs)
            for name, d in stats.items():
                live = snap["xnorm"][name][l]
                np.testing.assert_allclose(
                    live, np.sqrt(np.asarray(d["sumsq"])), rtol=2e-3,
                    err_msg=f"layer {l} {name}")
            xs = out

    def test_reset_calibration_and_gating(self, tapped_setup):
        model, params, eng, ref, prompts, GEN = tapped_setup
        eng.reset_calibration()
        snap = eng.calibration_snapshot()
        assert snap["tokens"] == 0
        with pytest.raises(ValueError, match="calib_taps"):
            ref.calibration_snapshot()

    def test_snapshot_reprune_repack_roundtrip(self, tapped_setup):
        """The full online loop: live stats -> reprune_from_stats -> repack,
        with valid 2:4 everywhere and no retrace."""
        model, params, eng, ref, prompts, GEN = tapped_setup
        eng.generate(prompts, GEN)
        snap = eng.calibration_snapshot()
        assert snap["tokens"] > 0
        new = reprune_from_stats(model, params, snap["stats"],
                                 PruneConfig(method="wanda", pattern="2:4"))
        rep = model_sparsity_report(model, new)
        for name, sp in rep.items():
            assert abs(sp - 0.5) < 1e-6, (name, sp)
        before = dict(eng.trace_counts)
        eng.repack(new)
        out = eng.generate(prompts, GEN)
        assert dict(eng.trace_counts) == before
        fresh = Engine(model, new, EngineConfig(
            n_slots=prompts.shape[0], max_len=prompts.shape[1] + GEN,
            chunk=GEN - 1, prefill_buckets=(prompts.shape[1],)),
            SamplingConfig())
        np.testing.assert_array_equal(out, fresh.generate(prompts, GEN))

    def test_calib_taps_rejects_unsupported_families(self):
        cfg = get_config("mamba2-1.3b").reduced(num_layers=2, d_model=64)
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        with pytest.raises(ValueError, match="calib_taps"):
            Engine(model, params,
                   EngineConfig(n_slots=2, max_len=20, chunk=2,
                                prefill_buckets=(16,), calib_taps=True),
                   SamplingConfig())
