"""Serving engine: decode parity, slot invariants, sampling, trace counts,
and 2:4-pruned end-to-end serving."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.model import Model
from repro.serve import (Engine, EngineConfig, Request, SamplingConfig,
                         sample_tokens)
from repro.serve import slots as SLOT
from repro.serve.scheduler import Scheduler


@pytest.fixture(scope="module")
def dense():
    cfg = get_config("qwen3-8b").reduced()
    model = Model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def moe():
    cfg = get_config("deepseek-moe-16b").reduced()
    # dropless routing (cf = E/k): single-token decode cannot reproduce
    # prefill capacity drops, same caveat as test_decode_matches_forward
    cfg = dataclasses.replace(cfg,
                              moe_capacity_factor=cfg.num_experts / cfg.top_k)
    model = Model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


def _prompts(cfg, B, P, seed=1):
    return np.asarray(jax.random.randint(
        jax.random.PRNGKey(seed), (B, P), 0, cfg.vocab_size), np.int32)


def assert_greedy_continuation(model, params, prompt, gen_toks):
    """Every generated token must be the argmax continuation of the sequence
    so far — checked against ONE full forward over [prompt | generated]."""
    prompt = np.asarray(prompt)
    gen_toks = np.asarray(gen_toks)
    seq = np.concatenate([prompt, gen_toks])[None].astype(np.int32)
    logits, _ = model.forward(params, {"tokens": jnp.asarray(seq)})
    P = len(prompt)
    ref = np.asarray(jnp.argmax(logits[0], axis=-1))
    for i, t in enumerate(gen_toks):
        assert t == ref[P - 1 + i], (
            f"token {i}: engine {t} != full-forward argmax {ref[P - 1 + i]}")


# ---------------------------------------------------------------------------
# decode parity: jitted scan decode == full forward, dense + moe
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", ["dense", "moe"])
def test_generate_matches_full_forward(family, dense, moe, request):
    model, params = dense if family == "dense" else moe
    cfg = model.cfg
    B, P, G = 4, 8, 6
    prompts = _prompts(cfg, B, P)
    eng = Engine(model, params,
                 EngineConfig(n_slots=B, max_len=P + G, chunk=G - 1,
                              prefill_buckets=(P,)))
    out = eng.generate(prompts, G)
    assert out.shape == (B, G)
    assert eng.trace_counts["decode"] == 1
    for b in range(B):
        assert_greedy_continuation(model, params, prompts[b], out[b])


def test_decode_step_vector_pos_matches_scalar(dense):
    """Per-slot (B,) cache positions == scalar lockstep at equal values."""
    model, params = dense
    cfg = model.cfg
    B, P = 2, 8
    toks = jnp.asarray(_prompts(cfg, B, P))
    _, _, cache_s = model.forward(params, {"tokens": toks}, return_cache=True)
    cache0 = model.init_cache(B, P + 4)
    ck = jax.lax.dynamic_update_slice(cache0[0], cache_s[0], (0, 0, 0, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache0[1], cache_s[1], (0, 0, 0, 0, 0))
    tok = jnp.asarray([3, 7], jnp.int32)
    lg_scalar, _ = model.decode_step(params, {"token": tok,
                                              "pos": jnp.int32(P)}, (ck, cv))
    lg_vec, _ = model.decode_step(
        params, {"token": tok, "pos": jnp.full((B,), P, jnp.int32)}, (ck, cv))
    np.testing.assert_allclose(np.asarray(lg_scalar), np.asarray(lg_vec),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# slot manager: admit / evict / finish invariants + continuous batching
# ---------------------------------------------------------------------------

def test_slot_admit_release_unit():
    st = SLOT.init_slots(4)
    slots = jnp.asarray([1, 3], jnp.int32)
    st = SLOT.admit(st, slots, jnp.asarray([10, 11], jnp.int32),
                    jnp.asarray([5, 7], jnp.int32),
                    jnp.asarray([9, 12], jnp.int32))
    assert np.asarray(st.active).tolist() == [False, True, False, True]
    # free slots park their write index at FREE_POS so frozen-lane KV
    # writes drop instead of landing in freshly mapped pages
    F = SLOT.FREE_POS
    assert np.asarray(st.pos).tolist() == [F, 5, F, 7]
    SLOT.check_invariants(st)
    # out-of-range padding index is dropped, not clipped onto slot 3
    st2 = SLOT.admit(st, jnp.asarray([4], jnp.int32),
                     jnp.asarray([99], jnp.int32), jnp.asarray([1], jnp.int32),
                     jnp.asarray([2], jnp.int32))
    assert np.asarray(st2.last_token).tolist() == np.asarray(st.last_token).tolist()
    st3 = SLOT.release(st, jnp.asarray([1], jnp.int32))
    assert np.asarray(st3.active).tolist() == [False, False, False, True]
    assert np.asarray(st3.pos).tolist() == [F, F, F, 7]
    SLOT.check_invariants(st3)


def test_scheduler_continuous_batching(dense):
    """More requests than slots, mixed prompt/gen lengths: every completion
    is the exact greedy continuation, slots are reused, invariants hold."""
    model, params = dense
    cfg = model.cfg
    rng = np.random.default_rng(0)
    reqs = [Request(rid,
                    rng.integers(0, cfg.vocab_size,
                                 int(rng.integers(4, 14))).astype(np.int32),
                    int(rng.integers(1, 8)))
            for rid in range(9)]
    eng = Engine(model, params,
                 EngineConfig(n_slots=4, max_len=32, chunk=4,
                              prefill_buckets=(8, 16)))
    seen = []
    comps = Scheduler(eng).run(
        reqs, progress=lambda c: (seen.append(c.rid),
                                  SLOT.check_invariants(eng.state)))
    assert sorted(c.rid for c in comps) == list(range(9))
    assert seen == [c.rid for c in comps]
    # 9 requests through 4 slots forces admit-on-free slot reuse
    assert eng.trace_counts["decode"] == 1, "one decode program, ever"
    for c in comps:
        r = reqs[c.rid]
        assert len(c.tokens) == r.max_new
        assert c.ttft_s > 0 and len(c.tpot_s) == r.max_new - 1
        assert_greedy_continuation(model, params, r.tokens, c.tokens)
    # pool drained back to empty
    assert not np.asarray(eng.state.active).any()


def test_eos_terminates_early(dense):
    model, params = dense
    cfg = model.cfg
    prompt = _prompts(cfg, 1, 8, seed=3)[0]
    eng = Engine(model, params,
                 EngineConfig(n_slots=2, max_len=32, chunk=4,
                              prefill_buckets=(8,)))
    ref = Scheduler(eng).run([Request(0, prompt, 8)])[0].tokens
    # pick the first token value whose first occurrence is not at index 0
    k = next(i for i in range(1, len(ref)) if ref[i] not in ref[:i])
    eos = int(ref[k])
    eng2 = Engine(model, params,
                  EngineConfig(n_slots=2, max_len=32, chunk=4, eos_id=eos,
                               prefill_buckets=(8,)))
    out = Scheduler(eng2).run([Request(0, prompt, 8)])[0].tokens
    assert len(out) == k + 1 and out[-1] == eos
    np.testing.assert_array_equal(out, ref[: k + 1])


def test_generate_eos_masks_post_eos_tokens(dense):
    """Early-EOS batch through Engine.generate: frozen slots re-feed their
    last token on device, but those repeats must NOT leak to the caller —
    the returned rows stop at EOS and are padded with eos_id."""
    model, params = dense
    cfg = model.cfg
    B, P, G = 4, 8, 8
    prompts = _prompts(cfg, B, P, seed=11)
    mk = lambda eos: Engine(
        model, params,
        EngineConfig(n_slots=B, max_len=32, chunk=G - 1, prefill_buckets=(P,),
                     eos_id=eos))
    ref = mk(None).generate(prompts, G)
    # pick a token some row emits mid-stream for the first time: with that
    # as eos_id the row must freeze there while the others keep going
    eos = row = k = None
    for b in range(B):
        for i in range(1, G - 1):
            if ref[b, i] not in ref[b, :i]:
                eos, row, k = int(ref[b, i]), b, i
                break
        if eos is not None:
            break
    assert eos is not None
    out = mk(eos).generate(prompts, G)
    assert out.shape == (B, G)
    np.testing.assert_array_equal(out[row, : k + 1], ref[row, : k + 1])
    assert (out[row, k + 1:] == eos).all(), "post-EOS tokens leaked"
    for b in range(B):  # every row: exact up to its own EOS, padding after
        hits = np.where(ref[b] == eos)[0]
        stop = int(hits[0]) if len(hits) else G - 1
        np.testing.assert_array_equal(out[b, : stop + 1], ref[b, : stop + 1])
        assert (out[b, stop + 1:] == eos).all()


def test_oversized_request_rejected(dense):
    model, params = dense
    eng = Engine(model, params, EngineConfig(n_slots=2, max_len=16,
                                             prefill_buckets=(8, 16)))
    with pytest.raises(ValueError, match="cache slots"):
        eng.admit_wave([np.zeros(12, np.int32)], [0], [8])


# ---------------------------------------------------------------------------
# sampling
# ---------------------------------------------------------------------------

def test_sample_tokens_rows_pinned_to_slot_key():
    """A row's draw depends only on (key, row index): the same leading rows
    must sample the same tokens whether the batch is 4 or 8 wide — wave
    padding or a mesh's batch layout can widen a batch, but must never
    shift a live row's stream. (This is the host-side half of the sharded
    determinism story; tests/test_serve_distributed.py pins the meshed
    engine against the single-device one end to end.)"""
    from repro.serve.sampling import slot_keys

    logits = jax.random.normal(jax.random.PRNGKey(2), (8, 32))
    key = jax.random.PRNGKey(9)
    sc = SamplingConfig(temperature=0.8, top_k=8, top_p=0.9)
    wide = np.asarray(sample_tokens(logits, key, sc))
    narrow = np.asarray(sample_tokens(logits[:4], key, sc))
    np.testing.assert_array_equal(wide[:4], narrow)
    # the per-row keys themselves are width-independent and distinct
    k8 = np.asarray(slot_keys(key, 8))
    k4 = np.asarray(slot_keys(key, 4))
    np.testing.assert_array_equal(k8[:4], k4)
    assert len({tuple(k) for k in k8}) == 8, "slot keys must be distinct"


def test_sample_tokens_topk_membership():
    logits = jax.random.normal(jax.random.PRNGKey(0), (64, 32))
    sc = SamplingConfig(temperature=1.0, top_k=4)
    toks = sample_tokens(logits, jax.random.PRNGKey(1), sc)
    top4 = np.asarray(jax.lax.top_k(logits, 4)[1])
    for b, t in enumerate(np.asarray(toks)):
        assert t in top4[b]


def test_sample_tokens_topp_membership():
    """Every sampled token lies in the smallest prefix of the prob-sorted
    vocab whose mass reaches top_p (computed independently in numpy)."""
    logits = jax.random.normal(jax.random.PRNGKey(0), (64, 32))
    sc = SamplingConfig(temperature=1.0, top_p=0.7)
    toks = np.asarray(sample_tokens(logits, jax.random.PRNGKey(1), sc))
    lg = np.asarray(logits, np.float64)
    probs = np.exp(lg - lg.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    for b, t in enumerate(toks):
        order = np.argsort(-probs[b], kind="stable")
        before = np.cumsum(probs[b][order]) - probs[b][order]
        nucleus = set(order[before < 0.7].tolist())
        assert t in nucleus, f"row {b}: {t} outside the 0.7 nucleus"


def test_topp_ties_keep_lowest_index_first():
    """All logits tied, top_p just over k/V: the nucleus must be exactly the
    first ceil(p*V) indices — ties never inflate the kept set (the same
    exact-ties discipline as top-k)."""
    V = 16
    logits = jnp.zeros((8, V))
    sc = SamplingConfig(temperature=1.0, top_p=4.5 / V)
    seen = set()
    for s in range(24):
        toks = np.asarray(sample_tokens(logits, jax.random.PRNGKey(s), sc))
        seen.update(toks.tolist())
    assert seen <= {0, 1, 2, 3, 4}, f"tie leaked past the nucleus: {seen}"
    assert seen == {0, 1, 2, 3, 4}, "nucleus under-filled"


def test_topp_composes_with_topk():
    # top_k=4 first, then top_p renormalized over the 4 survivors: with one
    # dominant logit and p tiny, only the argmax may ever be sampled
    row = np.array([0., 10., 0., 0., 1., 1., 1., 1.], np.float32)
    logits = jnp.asarray(np.tile(row, (8, 1)))
    sc = SamplingConfig(temperature=1.0, top_k=4, top_p=0.5)
    for s in range(8):
        toks = np.asarray(sample_tokens(logits, jax.random.PRNGKey(s), sc))
        assert (toks == 1).all()


def test_topp_zero_rejected():
    # top_p -> 0 degenerates toward greedy, so exactly 0 must not silently
    # flip to "disabled" (full softmax)
    with pytest.raises(ValueError, match="top_p"):
        SamplingConfig(temperature=1.0, top_p=0.0)


def test_topp_engine_deterministic(dense):
    model, params = dense
    cfg = model.cfg
    prompts = _prompts(cfg, 4, 8)
    mk = lambda seed: Engine(
        model, params,
        EngineConfig(n_slots=4, max_len=32, chunk=7, prefill_buckets=(8,)),
        SamplingConfig(temperature=0.9, top_p=0.8, seed=seed))
    a = mk(5).generate(prompts, 8)
    np.testing.assert_array_equal(a, mk(5).generate(prompts, 8))


def test_sampling_deterministic_under_fixed_key(dense):
    model, params = dense
    cfg = model.cfg
    prompts = _prompts(cfg, 4, 8)
    mk = lambda seed: Engine(
        model, params,
        EngineConfig(n_slots=4, max_len=32, chunk=7, prefill_buckets=(8,)),
        SamplingConfig(temperature=0.8, top_k=20, seed=seed))
    a = mk(3).generate(prompts, 8)
    b = mk(3).generate(prompts, 8)
    np.testing.assert_array_equal(a, b)
    c = mk(4).generate(prompts, 8)
    assert not np.array_equal(a, c), "different seed, same stream?"


# ---------------------------------------------------------------------------
# the no-per-token-host-round-trip guarantee
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cell", ["dense-paged", "dense-pool",
                                  "compressed24", "masked24"])
def test_single_trace_single_sync_per_generation(cell):
    """One prefill trace, ONE decode program, one block_until_ready per
    generation, zero retraces on the second wave — for the paged, dense-pool
    and both 2:4 serving paths. The pinned counts live in
    repro.analysis.contracts (the single source of truth; `make analyze`
    checks the same cells), this test just runs one cell each."""
    from repro.analysis import contracts
    measured, findings = contracts.run_trace_cell(cell)
    assert not findings, "\n".join(f.render() for f in findings)
    expected = contracts.EXPECTED_TRACES[cell]
    assert {k: measured[k] for k in expected} == expected


# ---------------------------------------------------------------------------
# pruned serving end-to-end
# ---------------------------------------------------------------------------

def test_pruned_24_serving_end_to_end(dense):
    """Wanda++ 2:4-pruned smoke model through the engine: sparsity exact,
    logits finite, outputs still the pruned model's greedy continuation."""
    from repro.configs.base import PruneConfig
    from repro.core.pruner import model_sparsity_report, prune_model
    from repro.data import calibration_batch

    model, params = dense
    cfg = model.cfg
    pcfg = PruneConfig(method="wanda++", pattern="2:4", n_calib=4,
                       calib_len=16, ro_iters=1, ro_samples=2)
    calib = calibration_batch(cfg.vocab_size, pcfg.n_calib, pcfg.calib_len)
    pruned, _ = prune_model(model, params, calib, pcfg)

    rep = model_sparsity_report(model, pruned)
    for name, frac in rep.items():
        assert abs(frac - 0.5) < 1e-6, f"{name}: sparsity {frac} != 0.5"

    B, P, G = 4, 8, 6
    prompts = _prompts(cfg, B, P)
    eng = Engine(model, pruned,
                 EngineConfig(n_slots=B, max_len=P + G, chunk=G - 1,
                              prefill_buckets=(P,)))
    out = eng.generate(prompts, G)
    assert out.shape == (B, G)
    seq = jnp.asarray(np.concatenate([prompts, out], axis=1))
    logits, _ = model.forward(pruned, {"tokens": seq})
    assert bool(jnp.isfinite(logits).all()), "non-finite logits from pruned model"
    for b in range(B):
        assert_greedy_continuation(model, pruned, prompts[b], out[b])
    # serving did not densify the weights
    rep_after = model_sparsity_report(model, pruned)
    assert rep_after == rep


# ---------------------------------------------------------------------------
# every decoder family constructs; encoder-only fails loudly, not wrongly
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["mamba2-1.3b", "zamba2-7b", "qwen2-vl-2b"])
def test_decoder_families_construct(arch):
    """The spec-driven engine builds for SSM / hybrid / VLM — the old
    per-family NotImplementedError gates are gone (decode parity for these
    families lives in tests/test_serve_families.py)."""
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    eng = Engine(model, None, EngineConfig(n_slots=2, max_len=32,
                                           prefill_buckets=(8,)))
    assert eng.spec.groups, "servable family must declare decode state"


def test_encoder_only_still_raises():
    cfg = get_config("hubert-xlarge").reduced()
    with pytest.raises(ValueError, match="no decode path"):
        Engine(Model(cfg), None)
