"""Mesh-sharded serving parity on a multi-device CPU mesh.

Subprocess pattern from tests/test_distributed.py: tests in THIS process
must keep seeing exactly 1 device, so every meshed engine runs in a child
with ``--xla_force_host_platform_device_count`` set. Each child builds the
same engine twice — single-device (mesh=None) and sharded over a 4x2
``(data, model)`` dev mesh — streams identical requests through both, and
asserts the token streams are EQUAL: greedy decode must be bit-exact, and
sampled decode must reproduce the per-slot key streams exactly
(serve/sampling.py pins draws to (key, slot), never to device layout).
"""
import subprocess
import sys
import textwrap

import pytest

from _forced_host import forced_cpu_env
from _hypothesis_compat import st

# Child-side helpers, prepended (flush-left) to every test's code: build a
# smoke engine and drive a mixed-length request stream through the
# continuous-batching scheduler (more requests than slots => slot release
# and reuse happen under sharding).
_PRELUDE = """\
import numpy as np, jax
from repro.configs import get_config
from repro.models.model import Model
from repro.launch.mesh import make_dev_mesh
from repro.serve import Engine, EngineConfig, Request, SamplingConfig
from repro.serve.scheduler import Scheduler

def make_engine(arch, mesh, paged, n_slots=4, max_len=32, sampling=None,
                page_size=8):
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, EngineConfig(
        n_slots=n_slots, max_len=max_len, chunk=4,
        prefill_buckets=(8, 16), paged=paged, page_size=page_size,
        mesh=mesh), sampling or SamplingConfig())
    return cfg, eng

def stream(cfg, eng, n_requests=10, prefix=None, seed=11):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_requests):
        body = rng.integers(0, cfg.vocab_size,
                            int(rng.integers(4, 17))).astype(np.int32)
        toks = body if prefix is None else np.concatenate([prefix, body])
        reqs.append(Request(i, toks, int(rng.integers(4, 9))))
    sched = Scheduler(eng)
    comps = sched.run(reqs)
    assert len(comps) == n_requests
    return {c.rid: c.tokens.tolist() for c in comps}, sched

"""


def _run(code: str, devices: int = 8) -> str:
    out = subprocess.run(
        [sys.executable, "-c", _PRELUDE + textwrap.dedent(code)],
        capture_output=True, text=True, env=forced_cpu_env(devices),
        timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


@pytest.mark.slow
def test_dense_pool_stream_matches_single_device():
    """Greedy continuous-batching stream through the DENSE per-slot pool:
    the 4x2-meshed engine (slots over data, KV heads over model) must emit
    bit-identical token streams, with slot release + reuse exercised (10
    requests through 4 slots)."""
    out = _run("""
        from repro.serve import slots as SLOT

        mesh = make_dev_mesh(4, 2)
        cfg, e1 = make_engine("qwen3-8b", None, paged=False)
        t1, _ = stream(cfg, e1)
        cfg, e2 = make_engine("qwen3-8b", mesh, paged=False)
        t2, sched = stream(cfg, e2)
        assert t1 == t2, "meshed dense-pool stream diverged"
        assert sched.peak_live == 4, "slot reuse never saturated the pool"
        SLOT.check_invariants(e2.state)
        assert not np.asarray(e2.state.active).any(), \\
            "slots not released after the stream drained"
        # released slots must be re-admittable: run the stream again
        t3, _ = stream(cfg, e2)
        assert t3 == t1, "slot reuse after release changed the stream"
        print("DENSE_MESH_OK")
    """)
    assert "DENSE_MESH_OK" in out


@pytest.mark.slow
def test_paged_pool_stream_matches_single_device():
    """Paged-arena stream (block tables over data, arena KV heads over
    model, pages replicated) with a registered shared prefix: token parity,
    allocator invariants, and the host free-page mirror must all hold on
    the mesh."""
    out = _run("""
        from repro.serve import paging as PAGE

        def run_one(mesh):
            cfg, eng = make_engine("qwen3-8b", mesh, paged=True)
            prefix = np.random.default_rng(5).integers(
                0, cfg.vocab_size, 8).astype(np.int32)
            assert eng.register_prefix(prefix) == 8
            toks, _ = stream(cfg, eng, prefix=prefix)
            return eng, toks

        e1, t1 = run_one(None)
        e2, t2 = run_one(make_dev_mesh(4, 2))
        assert t1 == t2, "meshed paged-pool stream diverged"
        assert e2.stats["shared_tokens_saved"] > 0, \\
            "shared-prefix pages were never mapped under the mesh"
        shared = e2.prefix_pages
        PAGE.check_invariants(e2.pstate, shared_pages=shared,
                              reserved=len(shared))
        # the host free-page mirror must track the sharded device free list
        ref = np.asarray(e2.pstate.ref)
        assert int((ref == 0).sum()) == e2.free_pages, \\
            (int((ref == 0).sum()), e2.free_pages)
        print("PAGED_MESH_OK")
    """)
    assert "PAGED_MESH_OK" in out


@pytest.mark.slow
def test_recurrent_families_match_single_device():
    """SSM (pure recurrent CacheSpec — nothing to page, SSD heads over
    model) and hybrid (paged attention KV + per-slot mamba leaves) streams
    are bit-exact under the mesh."""
    out = _run("""
        mesh = make_dev_mesh(4, 2)
        for arch in ("mamba2-1.3b", "zamba2-7b"):
            cfg, e1 = make_engine(arch, None, paged=True)
            t1, _ = stream(cfg, e1)
            cfg, e2 = make_engine(arch, mesh, paged=True)
            t2, _ = stream(cfg, e2)
            assert t1 == t2, f"{arch}: meshed stream diverged"
            print(arch, "ok")
        print("FAMILY_MESH_OK")
    """)
    assert "FAMILY_MESH_OK" in out


@pytest.mark.slow
def test_sampled_stream_matches_single_device():
    """Same seed => identical top-k/top-p draws on 1 device and on the
    mesh: sample_tokens folds the chunk key by SLOT INDEX, so the draw for
    (step, slot) is pinned regardless of how the mesh lays the batch out
    (and regardless of wave padding width)."""
    out = _run("""
        mesh = make_dev_mesh(4, 2)
        sc = SamplingConfig(temperature=0.9, top_k=8, top_p=0.9, seed=3)
        # one same-shape wave (generate) ...
        cfg = get_config("qwen3-8b").reduced()
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        rng = np.random.default_rng(7)
        prompts = rng.integers(0, cfg.vocab_size, (8, 16)).astype(np.int32)

        def gen(mesh):
            eng = Engine(model, params, EngineConfig(
                n_slots=8, max_len=32, chunk=15, prefill_buckets=(16,),
                mesh=mesh), sc)
            return eng.generate(prompts, 16)

        np.testing.assert_array_equal(gen(None), gen(mesh))
        # ... and a mixed-length scheduler stream (slot reuse reshuffles
        # which request sits in which slot; draws must still line up)
        cfg, e1 = make_engine("qwen3-8b", None, paged=True, sampling=sc)
        t1, _ = stream(cfg, e1)
        cfg, e2 = make_engine("qwen3-8b", mesh, paged=True, sampling=sc)
        t2, _ = stream(cfg, e2)
        assert t1 == t2, "sampled stream diverged under the mesh"
        print("SAMPLED_MESH_OK")
    """)
    assert "SAMPLED_MESH_OK" in out


# Deterministic seed grid for the allocator property below. With the CI
# container's shim, these ARE the hypothesis-style strategy examples; under
# real hypothesis (no .examples on a strategy) a fixed grid stands in —
# either way one subprocess replays every seed against the sharded arena.
_ALLOC_SEEDS = sorted(set(
    getattr(st.integers(0, 1 << 16), "examples", None)
    or [0, 7, 42, 1337, 65535]))[:8]


@pytest.mark.slow
def test_paged_allocator_invariants_sharded_arena():
    """Property: the refcounted page allocator keeps its invariants (no
    double-mapping, ref == mappings + holds, free pages mapped nowhere, the
    host free count mirrors the device) under randomized
    admit/evict/release/reserve/unreserve sequences when the PageState is
    SHARDED — block tables over data, the arena free list replicated — and
    every op runs as a jitted program with explicit in/out shardings, the
    way the engine runs them."""
    out = _run(f"""
        import jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.serve import paging as PAGE

        mesh = make_dev_mesh(4, 2)
        N_PAGES, N_SLOTS, MB = 24, 8, 4
        repl = NamedSharding(mesh, P())
        ps_sh = PAGE.PageState(ref=repl,
                               block_tables=NamedSharding(mesh, P("data")))
        alloc_j = jax.jit(PAGE.alloc, donate_argnums=(0,),
                          in_shardings=(ps_sh, repl, repl),
                          out_shardings=(ps_sh, repl))
        shared_j = jax.jit(PAGE.alloc, donate_argnums=(0,),
                           in_shardings=(ps_sh, repl, repl, repl, repl),
                           out_shardings=(ps_sh, repl))
        release_j = jax.jit(PAGE.release, donate_argnums=(0,),
                            in_shardings=(ps_sh, repl), out_shardings=ps_sh)
        reserve_j = jax.jit(PAGE.reserve, static_argnums=(1,),
                            donate_argnums=(0,), in_shardings=(ps_sh,),
                            out_shardings=(ps_sh, repl, repl))
        unreserve_j = jax.jit(PAGE.unreserve, donate_argnums=(0,),
                              in_shardings=(ps_sh, repl), out_shardings=ps_sh)

        for seed in {_ALLOC_SEEDS!r}:
            rng = np.random.default_rng(seed)
            state = jax.device_put(
                PAGE.init_pages(N_PAGES, N_SLOTS, MB), ps_sh)
            live, free = set(), N_PAGES
            reserved = []  # registry holds (tuples of pages), evictable
            for _ in range(24):
                op = rng.choice(["alloc", "shared", "release", "reserve",
                                 "unreserve"])
                if op == "alloc":
                    k = int(rng.integers(1, 3))
                    slots = [s for s in range(N_SLOTS) if s not in live]
                    rng.shuffle(slots)
                    slots = slots[:k]
                    if not slots:
                        continue
                    nb = rng.integers(1, MB + 1, len(slots)).astype(np.int32)
                    state, ok = alloc_j(state, jnp.asarray(slots, jnp.int32),
                                        jnp.asarray(nb))
                    if bool(ok):
                        live.update(slots)
                        free -= int(nb.sum())
                elif op == "shared" and reserved:
                    pages = reserved[int(rng.integers(len(reserved)))]
                    slots = [s for s in range(N_SLOTS) if s not in live][:2]
                    if not slots:
                        continue
                    nsh = len(pages)
                    nb = np.full(len(slots), min(MB, nsh + 1), np.int32)
                    state, ok = shared_j(
                        state, jnp.asarray(slots, jnp.int32),
                        jnp.asarray(nb),
                        jnp.full(len(slots), nsh, jnp.int32),
                        jnp.asarray(pages, jnp.int32))
                    if bool(ok):
                        live.update(slots)
                        free -= int((nb - nsh).sum())
                elif op == "release" and live:
                    picks = sorted(live)[:max(1, len(live) // 2)]
                    bt = np.asarray(state.block_tables)
                    shared_now = {{int(p) for ps in reserved for p in ps}}
                    n_own = sum(1 for s in picks
                                for p in bt[s][bt[s] < N_PAGES]
                                if int(p) not in shared_now)
                    state = release_j(state, jnp.asarray(picks, jnp.int32))
                    live.difference_update(picks)
                    free += n_own
                elif op == "reserve" and free >= 2:
                    state, pages, ok = reserve_j(state, 2)
                    if bool(ok):
                        reserved.append(tuple(int(p) for p in pages))
                        free -= 2
                elif op == "unreserve" and reserved:
                    # evict an idle registry hold (the engine guarantees no
                    # live slot maps it before unreserving; mirror that)
                    bt = np.asarray(state.block_tables)
                    mapped = {{int(p) for row in bt for p in row
                               if p < N_PAGES}}
                    idle = [ps for ps in reserved if not (set(ps) & mapped)]
                    if not idle:
                        continue
                    pages = idle[0]
                    state = unreserve_j(state, jnp.asarray(pages, jnp.int32))
                    reserved.remove(pages)
                    free += len(pages)
                shared = [p for ps in reserved for p in ps]
                PAGE.check_invariants(state, shared_pages=shared,
                                      reserved=len(shared))
                ref = np.asarray(state.ref)
                assert int((ref == 0).sum()) == free, \\
                    (seed, op, int((ref == 0).sum()), free)
        print("ALLOC_PROP_OK")
    """)
    assert "ALLOC_PROP_OK" in out


@pytest.mark.slow
def test_mesh_divisibility_degrades_with_warning():
    """Engine construction validates mesh divisibility up front: n_slots
    not divisible by the data axis, or kv_heads not divisible by the model
    axis, degrade that axis to replication with a RuntimeWarning (mirroring
    sharding.py's per-dim rule) — and the engine still decodes bit-exact
    instead of failing inside jit."""
    out = _run("""
        import warnings

        def gen(arch, mesh, n_slots, B):
            cfg = get_config(arch).reduced()
            model = Model(cfg)
            params = model.init(jax.random.PRNGKey(0))
            eng = Engine(model, params, EngineConfig(
                n_slots=n_slots, max_len=80, chunk=3,
                prefill_buckets=(8,), mesh=mesh))
            rng = np.random.default_rng(7)
            prompts = rng.integers(0, cfg.vocab_size, (B, 8)).astype(np.int32)
            vis = None
            if cfg.frontend == "vision":
                vis = rng.standard_normal(
                    (B, cfg.vision_patches, cfg.d_model)).astype(np.float32)
            return eng.generate(prompts, 4, vision=vis)

        # n_slots=6 on a 4-way data axis: slot state must replicate, warned
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            t = gen("qwen3-8b", make_dev_mesh(4, 2), n_slots=6, B=6)
        assert any("n_slots=6" in str(x.message) for x in w), \\
            [str(x.message) for x in w]
        np.testing.assert_array_equal(
            t, gen("qwen3-8b", None, n_slots=6, B=6))

        # kv_heads=2 on a 4-way model axis: KV dims replicate, warned
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            t = gen("qwen2-vl-2b", make_dev_mesh(2, 4), n_slots=4, B=4)
        assert any("num_kv_heads=2" in str(x.message) for x in w), \\
            [str(x.message) for x in w]
        np.testing.assert_array_equal(
            t, gen("qwen2-vl-2b", None, n_slots=4, B=4))
        print("DIVISIBILITY_OK")
    """)
    assert "DIVISIBILITY_OK" in out
