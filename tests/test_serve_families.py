"""Family-matrix serving: SSM / hybrid / VLM through the spec-driven engine.

Greedy scheduler-stream output must be bit-exact vs a per-request full
forward over [prompt | generated] — for recurrent families that proves the
snapshot-on-prefill / scatter-admit / zero-reset slot lifecycle, for VLM the
vision-prefix plumbing and the decode-time rotary offset. Runs forced-CPU
(`make test-serve-families`).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import state_spec as SSPEC
from repro.models.model import Model
from repro.serve import Engine, EngineConfig, Request
from repro.serve.scheduler import Scheduler


@pytest.fixture(scope="module", params=["mamba2-1.3b", "zamba2-7b"])
def recurrent(request):
    cfg = get_config(request.param).reduced()
    model = Model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def vlm():
    cfg = get_config("qwen2-vl-2b").reduced()
    model = Model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


def assert_greedy_vs_forward(model, params, prompt, gen_toks, vis=None):
    """Every generated token == the argmax continuation of ONE full forward
    over [vision? | prompt | generated]."""
    seq = np.concatenate([np.asarray(prompt), np.asarray(gen_toks)])
    inputs = {"tokens": jnp.asarray(seq[None].astype(np.int32))}
    P = 0
    if vis is not None:
        inputs["vision_embeds"] = jnp.asarray(np.asarray(vis)[None])
        P = vis.shape[0]
    logits, _ = model.forward(params, inputs)
    ref = np.asarray(jnp.argmax(logits[0], axis=-1))
    off = P + len(prompt) - 1
    for i, t in enumerate(np.asarray(gen_toks)):
        assert t == ref[off + i], (
            f"token {i}: engine {t} != full-forward argmax {ref[off + i]}")


def _stream(cfg, n=9, vis_patches=0, seed=0):
    rng = np.random.default_rng(seed)
    reqs = []
    for rid in range(n):
        toks = rng.integers(0, cfg.vocab_size,
                            int(rng.integers(4, 14))).astype(np.int32)
        vis = rng.standard_normal(
            (vis_patches, cfg.d_model)).astype(np.float32) \
            if vis_patches else None
        reqs.append(Request(rid, toks, int(rng.integers(1, 8)),
                            vision_embeds=vis))
    return reqs


# ---------------------------------------------------------------------------
# SSM / hybrid: scheduler-stream greedy parity incl. slot reuse
# ---------------------------------------------------------------------------

def test_recurrent_stream_matches_full_forward(recurrent):
    """9 mixed-length requests through 4 slots: slot reuse forces the
    snapshot/scatter-admit/zero-reset lifecycle on the recurrent leaves;
    every completion must be the exact greedy continuation."""
    model, params = recurrent
    cfg = model.cfg
    reqs = _stream(cfg)
    eng = Engine(model, params,
                 EngineConfig(n_slots=4, max_len=32, chunk=4,
                              prefill_buckets=(8, 16)))
    comps = Scheduler(eng).run(reqs)
    assert sorted(c.rid for c in comps) == list(range(9))
    assert eng.trace_counts["decode"] == 1, "one decode program, ever"
    for c in comps:
        r = reqs[c.rid]
        assert len(c.tokens) == r.max_new
        assert_greedy_vs_forward(model, params, r.tokens, c.tokens)
    assert not np.asarray(eng.state.active).any()
    # released slots' recurrent state is zero-reset, not left to churn
    for g in eng.spec.recurrent_groups:
        for leaf in eng.spec.unpack(eng.cache)[g.name]:
            assert np.abs(np.asarray(leaf)).max() == 0.0


def test_hybrid_paged_equals_dense_pool():
    """Zamba2 pages its shared-attention KV; the mamba leaves slot-scatter
    either way. Paged and dense pools must emit identical tokens."""
    cfg = get_config("zamba2-7b").reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    reqs = _stream(cfg, seed=3)
    mk = lambda paged: Engine(
        model, params,
        EngineConfig(n_slots=4, max_len=32, chunk=4, prefill_buckets=(8, 16),
                     paged=paged, page_size=8))
    eng_p = mk(True)
    assert eng_p.paged and eng_p.pstate is not None
    out = {}
    for paged, eng in ((True, eng_p), (False, mk(False))):
        comps = Scheduler(eng).run(reqs)
        out[paged] = {c.rid: list(c.tokens) for c in comps}
    assert out[True] == out[False]
    assert eng_p.free_pages == eng_p.cfg.pool_pages, "pages leaked"


def test_ssm_has_nothing_to_page():
    """A pure-recurrent spec ignores paged=True (no KV to page): no arena,
    no page accounting, and prefix registration is rejected."""
    cfg = get_config("mamba2-1.3b").reduced()
    model = Model(cfg)
    eng = Engine(model, model.init(jax.random.PRNGKey(0)),
                 EngineConfig(n_slots=2, max_len=32, paged=True,
                              prefill_buckets=(8,)))
    assert not eng.paged and eng.pstate is None
    with pytest.raises(ValueError, match="paged"):
        eng.register_prefix(np.zeros(16, np.int32))
    with pytest.raises(ValueError, match="page accounting"):
        eng.free_pages


def test_mamba_prefill_snapshot_matches_stepwise(recurrent):
    """Snapshot-on-prefill under bucket padding: the (ssm, conv) states the
    padded forward returns at seq_lens must equal decoding the same prompt
    token-by-token (the conv window must hold raw PRE-conv inputs)."""
    model, params = recurrent
    cfg = model.cfg
    toks = np.asarray(jax.random.randint(jax.random.PRNGKey(2), (2, 5),
                                         0, cfg.vocab_size), np.int32)
    _, _, states = model.forward(
        params, {"tokens": jnp.asarray(np.pad(toks, ((0, 0), (0, 11))))},
        return_cache=True, seq_lens=jnp.asarray([5, 5], jnp.int32))
    by_group = model.cache_spec.unpack(states)
    name = model.cache_spec.recurrent_groups[0].name
    ssm_snap, conv_snap = by_group[name]

    cache = model.init_cache(2, 16)
    for t in range(5):
        _, cache = model.decode_step(
            params, {"token": jnp.asarray(toks[:, t]), "pos": jnp.int32(t)},
            cache)
    ssm_ref, conv_ref = model.cache_spec.unpack(cache)[name]
    np.testing.assert_allclose(np.asarray(ssm_snap), np.asarray(ssm_ref),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(conv_snap), np.asarray(conv_ref),
                               rtol=2e-4, atol=2e-5)


# ---------------------------------------------------------------------------
# VLM: vision_embeds requests through the scheduler stream
# ---------------------------------------------------------------------------

def test_vlm_stream_matches_full_forward(vlm):
    model, params = vlm
    cfg = model.cfg
    reqs = _stream(cfg, vis_patches=cfg.vision_patches, seed=1)
    eng = Engine(model, params,
                 EngineConfig(n_slots=4, max_len=64, chunk=4,
                              prefill_buckets=(8, 16)))
    comps = Scheduler(eng).run(reqs)
    assert sorted(c.rid for c in comps) == list(range(9))
    for c in comps:
        r = reqs[c.rid]
        assert len(c.tokens) == r.max_new
        assert_greedy_vs_forward(model, params, r.tokens, c.tokens,
                                 vis=r.vision_embeds)


def test_vlm_request_without_vision_rejected(vlm):
    model, params = vlm
    eng = Engine(model, params, EngineConfig(n_slots=2, max_len=64,
                                             prefill_buckets=(8,)))
    with pytest.raises(ValueError, match="vision_embeds"):
        eng.admit_wave([np.zeros(4, np.int32)], [0], [2])


def test_vision_on_text_model_rejected():
    """The converse guard: vision_embeds on a non-vision model would be
    silently dropped by the forward while slot/page bookkeeping still
    counted its positions — reject loudly instead."""
    cfg = get_config("qwen3-8b").reduced()
    model = Model(cfg)
    eng = Engine(model, model.init(jax.random.PRNGKey(0)),
                 EngineConfig(n_slots=2, max_len=32, prefill_buckets=(8,)))
    vis = np.zeros((4, cfg.d_model), np.float32)
    with pytest.raises(ValueError, match="no vision frontend"):
        eng.admit_wave([np.zeros(4, np.int32)], [0], [2], vision=[vis])


def test_vlm_dense_pool_bucket_capped_by_vision(vlm):
    """Dense pool: the text bucket must be capped at max_len - n_patches —
    a fallback bucket of max_len would scatter n_patches + max_len KV
    positions into a max_len row (trace-time shape error)."""
    model, params = vlm
    cfg = model.cfg
    P = cfg.vision_patches
    rng = np.random.default_rng(5)
    eng = Engine(model, params,
                 EngineConfig(n_slots=2, max_len=P + 32, paged=False,
                              chunk=2, prefill_buckets=(16, 64)))
    # 20 text tokens: over the 16 bucket, so the fallback engages — it must
    # be P + 32 - P = 32, not P + 32
    toks = rng.integers(0, cfg.vocab_size, 20).astype(np.int32)
    vis = rng.standard_normal((P, cfg.d_model)).astype(np.float32)
    comps = Scheduler(eng).run([Request(0, toks, 4, vision_embeds=vis)])
    assert len(comps) == 1 and len(comps[0].tokens) == 4
    assert_greedy_vs_forward(model, params, toks, comps[0].tokens, vis=vis)


def test_vlm_budget_counts_vision_positions(vlm):
    """The vision prefix occupies cache positions: max_len and page budgets
    must count it, not just the text tokens."""
    model, params = vlm
    cfg = model.cfg
    P = cfg.vision_patches
    vis = np.zeros((P, cfg.d_model), np.float32)
    eng = Engine(model, params,
                 EngineConfig(n_slots=2, max_len=P + 6, page_size=4,
                              prefill_buckets=(8,)))
    assert eng.pages_needed(np.zeros(3, np.int32), 2, n_vis=P) == \
        -(-(P + 3 + 1) // 4)
    with pytest.raises(ValueError, match="cache slots"):
        eng.admit_wave([np.zeros(6, np.int32)], [0], [2], vision=[vis])


# ---------------------------------------------------------------------------
# encoder-only stays rejected
# ---------------------------------------------------------------------------

def test_encoder_only_rejected():
    cfg = get_config("hubert-xlarge").reduced()
    model = Model(cfg)
    with pytest.raises(ValueError, match="no decode path"):
        Engine(model, None)


# ---------------------------------------------------------------------------
# spec shapes stay honest
# ---------------------------------------------------------------------------

def test_cache_spec_layouts():
    for arch, kinds in [("qwen3-8b", {SSPEC.KV}),
                        ("mamba2-1.3b", {SSPEC.RECURRENT}),
                        ("zamba2-7b", {SSPEC.KV, SSPEC.RECURRENT}),
                        ("qwen2-vl-2b", {SSPEC.KV})]:
        spec = Model(get_config(arch).reduced()).cache_spec
        assert {g.kind for g in spec.groups} == kinds
    spec = Model(get_config("hubert-xlarge").reduced()).cache_spec
    assert not spec.groups, "encoder-only family must declare no decode state"
    # slot_state_bytes: dense KV row + fixed recurrent leaves
    z = Model(get_config("zamba2-7b").reduced())
    per = z.cache_spec.slot_state_bytes(32)
    assert per > 0
    cache = z.init_cache(1, 32)
    total = sum(x.size * x.dtype.itemsize
                for x in jax.tree_util.tree_leaves(cache))
    assert per == total
