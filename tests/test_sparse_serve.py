"""Compressed 2:4 serving: pack/unpack properties + engine parity suite.

Three layers of evidence that the compacted (vals + packed 2-bit idx)
weight path can be THE serve path for 2:4-pruned checkpoints:

  1. property roundtrips (via the optional-hypothesis shim): 2-bit
     pack/unpack is lossless, ``compact24`` -> ``decompress24`` is
     BIT-exact against the pruner's masked weights — including groups
     holding more than two zeros (the survivors pin to the nonzero
     positions first, then the remaining slots in position order), and
     stacked (L, K, N) parameter trees;
  2. backend-level: ``sparse24_lin`` / ``masked24_lin`` reproduce the
     default ``linear`` epilogues (bias, LoRA) exactly;
  3. end-to-end: greedy ``Engine`` decode is BIT-EXACT (token-for-token)
     across compressed / masked / dense engines, for the one-wave path,
     the Pallas-kernel path (interpret off-TPU), and a mixed-length
     continuous-batching stream — plus the storage-accounting and
     auto-detection contracts (random init never compresses; ``on``
     without a 2:4 checkpoint raises).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, st
from repro.configs import get_config
from repro.core.masks import nm_mask as core_nm
from repro.core.pruner import tree_get, tree_set
from repro.kernels import ops
from repro.models.blocks import compress_params24, prunable_table
from repro.models.layers import linear, masked24_lin, sparse24_lin
from repro.models.model import Model
from repro.serve import Engine, EngineConfig, Request
from repro.serve.scheduler import Scheduler


def _sparse24(seed, K, N, extra_zeros=0.0, dtype=jnp.float32):
    """Random exact-2:4 weight; ``extra_zeros`` forces some groups to hold
    more than two zeros (the pruner's mask keeps <= 2 survivors anyway)."""
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(K, N)).astype(np.float32)
    if extra_zeros:
        w[rng.random((K, N)) < extra_zeros] = 0.0
    m = core_nm(jnp.abs(jnp.asarray(w).T), 2, 4).T
    return jnp.where(m, jnp.asarray(w).astype(dtype), 0)


def _prune24(model, params):
    """Magnitude-2:4 every prunable stacked (L, K, N) projection."""
    blocks = params["blocks"]
    for _, path in prunable_table(model.cfg).items():
        if path[-1] != "w":
            continue
        w = tree_get(blocks, path)
        if w is None or w.ndim != 3 or w.shape[-2] % 8:
            continue
        mask = jax.vmap(lambda wl: core_nm(jnp.abs(wl.T), 2, 4).T)(w)
        blocks = tree_set(blocks, path, jnp.where(mask, w, 0))
    return dict(params, blocks=blocks)


@pytest.fixture(scope="module")
def pruned():
    cfg = get_config("llama1-7b").reduced()
    model = Model(cfg)
    params = _prune24(model, model.init(jax.random.PRNGKey(0)))
    return model, params


def _prompts(cfg, B, P, seed=1):
    return np.asarray(jax.random.randint(
        jax.random.PRNGKey(seed), (B, P), 0, cfg.vocab_size), np.int32)


# ---------------------------------------------------------------------------
# 1: pack/unpack + compaction properties
# ---------------------------------------------------------------------------

@given(st.integers(0, 10 ** 6))
def test_pack_unpack_roundtrip(seed):
    rng = np.random.default_rng(seed)
    idx2 = np.sort(np.stack(
        [rng.permutation(4)[:2] for _ in range(16 * 32)]), axis=1)
    idx2 = jnp.asarray(idx2.reshape(16, 32, 2).transpose(0, 2, 1)
                       .reshape(32, 32), jnp.int32)
    packed = ops._pack24_idx(idx2)
    assert packed.shape == (8, 32) and packed.dtype == jnp.uint8
    np.testing.assert_array_equal(np.asarray(ops.unpack24_idx(packed)),
                                  np.asarray(idx2))


@given(st.integers(0, 10 ** 6), st.floats(0.0, 0.8))
def test_compact_decompress_bitexact(seed, extra_zeros):
    ws = _sparse24(seed, 64, 32, extra_zeros=extra_zeros)
    assert ops.sparsity_check24(ws)
    vals, idx = ops.compact24(ws)
    assert vals.shape == (32, 32) and idx.shape == (8, 32)
    assert idx.dtype == jnp.uint8
    # bit-exact: +0.0 zeros, same as the pruner's jnp.where(mask, w, 0)
    assert np.array_equal(np.asarray(ops.decompress24(vals, idx)),
                          np.asarray(ws))


def test_compact_tiebreak_pins_nonzeros_first():
    """A group with > 2 zeros keeps its nonzeros first, then pads with the
    earliest zero positions — the layout contract the kernel decodes."""
    col = np.zeros((8, 1), np.float32)
    col[2, 0] = 5.0  # group 0: [0, 0, 5, 0]
    col[4, 0], col[5, 0] = 3.0, 4.0  # group 1: [3, 4, 0, 0]
    vals, idx = ops.compact24(jnp.asarray(col))
    np.testing.assert_array_equal(np.asarray(vals)[:, 0], [5.0, 0.0, 3.0, 4.0])
    np.testing.assert_array_equal(np.asarray(ops.unpack24_idx(idx))[:, 0],
                                  [2, 0, 0, 1])


@given(st.integers(0, 10 ** 6))
def test_compact_stacked_leading_dims(seed):
    """(L, K, N) stacks compact exactly like a per-layer loop."""
    ws = jnp.stack([_sparse24(seed + i, 32, 16) for i in range(3)])
    assert ops.sparsity_check24(ws)
    vals, idx = ops.compact24(ws)
    assert vals.shape == (3, 16, 16) and idx.shape == (3, 4, 16)
    for i in range(3):
        vi, ii = ops.compact24(ws[i])
        np.testing.assert_array_equal(np.asarray(vals[i]), np.asarray(vi))
        np.testing.assert_array_equal(np.asarray(idx[i]), np.asarray(ii))
    assert np.array_equal(np.asarray(ops.decompress24(vals, idx)),
                          np.asarray(ws))


def test_sparsity_check_rejects_dense():
    w = jnp.asarray(np.random.default_rng(0).normal(size=(32, 16)),
                    jnp.float32)
    assert not ops.sparsity_check24(w)
    assert not ops.sparsity_check24(w[:30])  # K % 4 != 0


def test_compressed_ratio_constants():
    assert ops.compressed24_ratio(4) == 0.53125  # f32 vals + 2-bit idx
    assert ops.compressed24_ratio(2) == 0.5625   # bf16 vals + 2-bit idx


# ---------------------------------------------------------------------------
# 2: lin backends reproduce the default linear epilogues
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("use_kernel", [False, True])
def test_lin_backends_match_linear(use_kernel):
    rng = np.random.default_rng(3)
    ws = _sparse24(3, 64, 32)
    p = {"w": ws, "b": jnp.asarray(rng.normal(size=(32,)), jnp.float32),
         "lora_a": jnp.asarray(rng.normal(size=(64, 4)), jnp.float32),
         "lora_b": jnp.asarray(rng.normal(size=(4, 32)), jnp.float32)}
    x = jnp.asarray(rng.normal(size=(2, 5, 64)), jnp.float32)
    want = linear(p, x)

    vals, idx = ops.compact24(ws)
    pc = {k: v for k, v in p.items() if k != "w"}
    pc.update(w24_vals=vals, w24_idx=idx)
    got = sparse24_lin(use_kernel)("wq", pc, x)
    tol = dict(rtol=1e-5, atol=1e-5) if use_kernel else dict(rtol=0, atol=0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **tol)

    pm = dict(p, mask24=(ws != 0).astype(jnp.int8))
    got_m = masked24_lin("wq", pm, x)
    np.testing.assert_allclose(np.asarray(got_m), np.asarray(want),
                               rtol=1e-6, atol=1e-6)

    # no 2:4 leaves -> both backends fall through to the dense linear
    np.testing.assert_array_equal(np.asarray(sparse24_lin(use_kernel)("wq", p, x)),
                                  np.asarray(want))
    np.testing.assert_array_equal(np.asarray(masked24_lin("wq", p, x)),
                                  np.asarray(want))


# ---------------------------------------------------------------------------
# 3: engine end-to-end
# ---------------------------------------------------------------------------

def _mk(model, params, mode, kernel=None, n_slots=4, chunk=5):
    return Engine(model, params, EngineConfig(
        n_slots=n_slots, max_len=32, chunk=chunk, prefill_buckets=(8,),
        paged=True, page_size=8, compressed24=mode,
        compressed24_kernel=kernel))


def test_engine_generate_bitexact_modes(pruned):
    model, params = pruned
    B, P, G = 4, 8, 6
    prompts = _prompts(model.cfg, B, P)
    out = {m: _mk(model, params, m).generate(prompts, G)
           for m in ("off", "auto", "on", "masked")}
    np.testing.assert_array_equal(out["auto"], out["off"])
    np.testing.assert_array_equal(out["on"], out["off"])
    np.testing.assert_array_equal(out["masked"], out["off"])


def test_engine_generate_bitexact_kernel_path(pruned):
    """compressed24_kernel=True routes the big projections through the
    Pallas sparse_matmul24 kernel (interpret off-TPU): same tokens."""
    model, params = pruned
    prompts = _prompts(model.cfg, 2, 8)
    out_k = _mk(model, params, "on", kernel=True, n_slots=2,
                chunk=3).generate(prompts, 4)
    out_d = _mk(model, params, "off", n_slots=2, chunk=3).generate(prompts, 4)
    np.testing.assert_array_equal(out_k, out_d)


def test_engine_stream_bitexact_modes(pruned):
    """Mixed-length continuous-batching stream (slot churn, ragged
    positions): identical completions compressed vs masked vs dense."""
    model, params = pruned
    cfg = model.cfg
    rng = np.random.default_rng(6)
    reqs = [Request(rid,
                    rng.integers(0, cfg.vocab_size,
                                 int(rng.integers(4, 9))).astype(np.int32),
                    int(rng.integers(1, 6)))
            for rid in range(7)]
    out = {}
    for mode in ("off", "auto", "masked"):
        comps = Scheduler(_mk(model, params, mode, chunk=4)).run(reqs)
        out[mode] = {c.rid: list(c.tokens) for c in comps}
    assert out["auto"] == out["off"]
    assert out["masked"] == out["off"]


def test_engine_compression_accounting(pruned):
    """Every prunable projection compresses; packed bytes hit the ratio."""
    model, params = pruned
    eng = _mk(model, params, "auto")
    n_prunable = sum(1 for _, path in prunable_table(model.cfg).items()
                     if path[-1] == "w")
    assert eng.compressed24 == n_prunable > 0
    packed = dense = 0
    for _, path in prunable_table(model.cfg).items():
        if path[-1] != "w":
            continue
        p = tree_get(eng.params["blocks"], path[:-1])
        assert "w24_vals" in p and p["w24_idx"].dtype == jnp.uint8
        packed += p["w24_vals"].nbytes + p["w24_idx"].nbytes
        dense += tree_get(params["blocks"], path).nbytes
    assert packed / dense == ops.compressed24_ratio(4)


def test_compress_params24_bitexact(pruned):
    """The build-time dense rematerialisation is BIT-exact: compressing
    then decompressing reproduces the pruned checkpoint leaf-for-leaf."""
    model, params = pruned
    out, n = compress_params24(model.cfg, params, keep_dense=True)
    assert n > 0
    for _, path in prunable_table(model.cfg).items():
        if path[-1] != "w":
            continue
        assert np.array_equal(np.asarray(tree_get(out["blocks"], path)),
                              np.asarray(tree_get(params["blocks"], path)))


def test_auto_is_noop_on_dense_checkpoint():
    """Random init never passes the 2:4 check: auto compresses nothing,
    and 'on' (which demands a sparse checkpoint) raises."""
    cfg = get_config("llama1-7b").reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    eng = _mk(model, params, "auto")
    assert eng.compressed24 == 0 and eng._lin is None
    with pytest.raises(ValueError, match="compressed24"):
        _mk(model, params, "on")
    with pytest.raises(ValueError, match="compressed24"):
        _mk(model, params, "bogus")
