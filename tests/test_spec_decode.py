"""Self-speculative decoding: a 2:4-pruned drafter proposes draft_k tokens
per macro step, the target verifies them in one batched forward.

The contract under test: greedy spec decode is BIT-EXACT against target-only
decode (the emission is always the target's own argmax chain — the drafter
only decides how many of those tokens land per device step), and sampled
spec decode with drafter == target accepts every proposal (exact rejection
sampling: acceptance probability p_t/p_d == 1 when the distributions match).
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.analysis.contracts import magnitude_prune24
from repro.configs import get_config
from repro.models.model import Model
from repro.models.state_spec import with_draft_group
from repro.serve import Engine, EngineConfig, Request, SamplingConfig
from repro.serve.scheduler import Scheduler


@pytest.fixture(scope="module")
def dense():
    cfg = get_config("qwen3-8b").reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    # the cheap 2:4 drafter: exact magnitude pruning passes sparsity_check24
    # so the engine serves it through the compressed24 path, same as a full
    # Wanda++ prune (whose output the RO regression tests pin to 2:4)
    draft = magnitude_prune24(cfg, params)
    return model, params, draft


def _prompts(cfg, B, P, seed=1):
    return np.asarray(jax.random.randint(
        jax.random.PRNGKey(seed), (B, P), 0, cfg.vocab_size), np.int32)


def _engine(model, params, *, B, P, G, draft=None, k=0, paged=True,
            sampling=SamplingConfig(), eos=None, chunk=None):
    cfg = EngineConfig(n_slots=B, max_len=P + G + k, chunk=chunk or G - 1,
                       prefill_buckets=(P,), paged=paged, draft_k=k,
                       eos_id=eos)
    return Engine(model, params, cfg, sampling, draft_params=draft)


# ---------------------------------------------------------------------------
# greedy spec decode == target-only, token for token
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("paged", [True, False], ids=["paged", "dense-pool"])
@pytest.mark.parametrize("k", [1, 3])
def test_greedy_spec_bit_exact(dense, paged, k):
    model, params, draft = dense
    B, P, G = 4, 8, 10
    prompts = _prompts(model.cfg, B, P)
    ref = _engine(model, params, B=B, P=P, G=G, paged=paged
                  ).generate(prompts, G)
    eng = _engine(model, params, B=B, P=P, G=G, draft=draft, k=k, paged=paged)
    assert eng.compressed24_draft > 0  # drafter really serves compacted 2:4
    out = eng.generate(prompts, G)
    np.testing.assert_array_equal(out, ref)
    # the whole spec wave still runs as ONE traced decode program
    assert eng.trace_counts["decode"] == 1


def test_greedy_spec_bit_exact_chunked(dense):
    """Chunk boundaries fall mid-wave (chunk < need): the accepted-length
    bookkeeping must carry pos/last_token across chunks exactly."""
    model, params, draft = dense
    B, P, G = 3, 8, 13
    prompts = _prompts(model.cfg, B, P)
    ref = _engine(model, params, B=B, P=P, G=G).generate(prompts, G)
    eng = _engine(model, params, B=B, P=P, G=G, draft=draft, k=2, chunk=4)
    np.testing.assert_array_equal(eng.generate(prompts, G), ref)


def test_greedy_spec_eos_parity(dense):
    """EOS truncation: spec decode must stop each row where target-only
    does, and pad identically."""
    model, params, draft = dense
    B, P, G = 4, 8, 12
    prompts = _prompts(model.cfg, B, P, seed=3)
    # pick an eos that actually fires mid-stream for at least one row
    probe = _engine(model, params, B=B, P=P, G=G).generate(prompts, G)
    eos = int(probe[0, G // 2])
    ref = _engine(model, params, B=B, P=P, G=G, eos=eos).generate(prompts, G)
    eng = _engine(model, params, B=B, P=P, G=G, draft=draft, k=3, eos=eos)
    np.testing.assert_array_equal(eng.generate(prompts, G), ref)


@pytest.mark.parametrize("paged", [True, False], ids=["paged", "dense-pool"])
def test_scheduler_stream_greedy_parity(dense, paged):
    """Mixed-length requests through the continuous-batching scheduler:
    every completion's token stream matches the target-only engine's."""
    model, params, draft = dense
    cfg = model.cfg
    B, P, G = 3, 8, 9
    rng = np.random.default_rng(11)
    reqs = [Request(i,
                    rng.integers(0, cfg.vocab_size,
                                 int(rng.integers(P // 2, P + 1))
                                 ).astype(np.int32),
                    int(rng.integers(G // 2, G + 1)))
            for i in range(7)]
    outs = {}
    for k in (0, 2):
        eng = _engine(model, params, B=B, P=P, G=G, paged=paged,
                      draft=draft if k else None, k=k, chunk=4)
        comps = Scheduler(eng).run(
            [Request(r.rid, r.tokens.copy(), r.max_new) for r in reqs])
        outs[k] = {c.rid: c.tokens for c in comps}
    assert set(outs[0]) == set(outs[2]) == {r.rid for r in reqs}
    for rid in outs[0]:
        np.testing.assert_array_equal(outs[2][rid], outs[0][rid])


# ---------------------------------------------------------------------------
# sampled spec decode: exact rejection sampling
# ---------------------------------------------------------------------------

def test_sampled_draft_equals_target_accepts_all(dense):
    """With draft_params == target params the processed distributions are
    identical, so acceptance p_t/p_d == 1 for every proposal: the wave must
    finish in the MINIMAL number of macro steps, every emitted row valid
    (mean accepted length == draft_k)."""
    model, params, _ = dense
    k = 3
    B, P = 4, 8
    need = 2 * (k + 1)  # decode tokens; exactly 2 macro steps if all accept
    G = need + 1
    sc = SamplingConfig(temperature=0.8, top_k=20, seed=5)
    eng = _engine(model, params, B=B, P=P, G=G, draft=params, k=k,
                  sampling=sc, chunk=need)
    prompts = _prompts(model.cfg, B, P, seed=7)
    eng.reset()
    eng.admit_wave(list(prompts), list(range(B)), [G] * B)
    toks, valid = eng.decode_chunk(need)
    t, v, fin, _ = eng.harvest(toks, valid)
    assert fin[:B].all(), "all-accept wave must finish in minimal steps"
    assert v[:, :B].all(), (
        "draft == target must accept every proposal (no rejected rows)")
    assert t.shape[0] == need


def test_sampled_spec_rows_are_valid_samples(dense):
    """With a real (pruned) drafter, sampled spec decode still emits
    exactly the budgeted number of tokens per slot — rejections cost device
    steps, never tokens."""
    model, params, draft = dense
    B, P, G = 4, 8, 10
    sc = SamplingConfig(temperature=1.0, top_k=30, seed=9)
    eng = _engine(model, params, B=B, P=P, G=G, draft=draft, k=2, sampling=sc)
    out = eng.generate(_prompts(model.cfg, B, P, seed=2), G)
    assert out.shape == (B, G)
    assert (out >= 0).all() and (out < model.cfg.vocab_size).all()


# ---------------------------------------------------------------------------
# spec plumbing contracts
# ---------------------------------------------------------------------------

def test_draft_group_spec_rejects_recurrent():
    cfg = get_config("mamba2-1.3b").reduced()
    with pytest.raises(ValueError, match="KV group"):
        with_draft_group(Model(cfg).cache_spec)


def test_engine_arg_validation(dense):
    model, params, draft = dense
    with pytest.raises(ValueError, match="draft_params"):
        _engine(model, params, B=2, P=8, G=4, k=2)
    with pytest.raises(ValueError, match="draft_k"):
        Engine(model, params,
               EngineConfig(n_slots=2, max_len=16, chunk=3,
                            prefill_buckets=(8,)),
               SamplingConfig(), draft_params=draft)


def test_admission_headroom_includes_draft_k(dense):
    """A request whose accepted sequence fits but whose drafter run-ahead
    does not must be refused at admission, naming the draft_k padding."""
    model, params, draft = dense
    B, P, G, k = 2, 8, 8, 3
    eng = Engine(model, params,
                 EngineConfig(n_slots=B, max_len=P + G, chunk=G - 1,
                              prefill_buckets=(P,), draft_k=k),
                 SamplingConfig(), draft_params=draft)
    prompts = _prompts(model.cfg, B, P)
    with pytest.raises(ValueError, match="draft_k"):
        eng.admit_wave(list(prompts), list(range(B)), [G] * B)
